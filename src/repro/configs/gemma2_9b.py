"""Config alias for --arch gemma2-9b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("gemma2-9b")
