"""Paper Table 1 + Fig. 3: Accuracy / Compression-Ratio per workload per
method, including KVServe-Unified (one robust config from the mixed search)
and KVServe-Aware (per-workload search).

Real measurements on the tiny reference model (relative accuracy) and real
byte-level CR.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import KVCache, measure_profile
from repro.core.quality import calibrate_head_scores, evaluate_quality, get_reference_model
from repro.core.strategy import BASELINES, StrategyConfig, enumerate_space
from repro.data.synthetic import WORKLOADS
from repro.profiling import BOConfig, run_bo


def _acc_cr(cfg, ref, head_scores, kv_samples, workloads=tuple(WORKLOADS),
            n_prompts=4, decode_tokens=12):
    q = evaluate_quality(cfg, workloads=workloads, ref=ref,
                         head_scores=head_scores, n_prompts=n_prompts,
                         decode_tokens=decode_tokens)
    p = measure_profile(cfg, kv_samples, head_scores=head_scores)
    return q, p.cr


def _bo_best(space, eval_fn, threshold, seed=0):
    res = run_bo(space, eval_fn,
                 BOConfig(acc_threshold=threshold, max_iters=40, seed=seed))
    return res.best.cfg if res.best else None


def run(smoke: bool = False) -> None:
    ref = get_reference_model()
    head_scores = calibrate_head_scores(ref=ref)
    kv_samples = [KVCache.random(4, 2, 192, 32, seed=s) for s in range(2)]
    qk = dict(n_prompts=2, decode_tokens=8) if smoke else {}

    t0 = time.perf_counter()
    methods = {"default": StrategyConfig(key_bits=16, value_bits=16),
               **{k: v for k, v in BASELINES.items()}}
    if smoke:
        methods = {"default": methods["default"],
                   "kivi": BASELINES["kivi"]}
    results = {}
    for name, cfg in methods.items():
        q, cr = _acc_cr(cfg, ref, head_scores, kv_samples, **qk)
        results[name] = (q, cr)
        row = " ".join(f"{w}={q[w]:.3f}" for w in q)
        emit(f"tab1_{name}", (time.perf_counter() - t0) * 1e6,
             f"cr={cr:.2f} {row} mean_acc={np.mean(list(q.values())):.3f}")
        t0 = time.perf_counter()
    if smoke:
        # the BO searches below re-evaluate quality per candidate — the
        # smoke path stops at the baseline table
        return

    # KVServe-Unified: one search over the mixed workloads
    space = enumerate_space("module")
    cache = {}
    def eval_mixed(cfg):
        key = cfg.key()
        if key not in cache:
            q, cr = _acc_cr(cfg, ref, head_scores, kv_samples)
            cache[key] = (float(np.mean(list(q.values()))), cr)
        return cache[key]
    best_uni = _bo_best(space, eval_mixed, threshold=0.90)
    if best_uni is not None:
        q, cr = _acc_cr(best_uni, ref, head_scores, kv_samples)
        emit("tab1_kvserve_unified", (time.perf_counter() - t0) * 1e6,
             f"cr={cr:.2f} " + " ".join(f"{w}={q[w]:.3f}" for w in q)
             + f" mean_acc={np.mean(list(q.values())):.3f}"
             + f" cfg={best_uni.short_name()}")

    # KVServe-Aware: per-workload searches
    t0 = time.perf_counter()
    aware = {}
    for w in WORKLOADS:
        cache_w = {}
        def eval_w(cfg, _w=w):
            key = cfg.key()
            if key not in cache_w:
                q = evaluate_quality(cfg, workloads=(_w,), ref=ref,
                                     head_scores=head_scores, n_prompts=4,
                                     decode_tokens=12)
                p = measure_profile(cfg, kv_samples, head_scores=head_scores)
                cache_w[key] = (q[_w], p.cr)
            return cache_w[key]
        best = _bo_best(space, eval_w, threshold=0.90, seed=hash(w) % 1000)
        if best is not None:
            acc, cr = eval_w(best)
            aware[w] = (acc, cr, best.short_name())
    if aware:
        mean_acc = np.mean([v[0] for v in aware.values()])
        mean_cr = np.mean([v[1] for v in aware.values()])
        emit("tab1_kvserve_aware", (time.perf_counter() - t0) * 1e6,
             " ".join(f"{w}={v[0]:.3f}/cr{v[1]:.1f}" for w, v in aware.items())
             + f" mean_acc={mean_acc:.3f} mean_cr={mean_cr:.2f}")


if __name__ == "__main__":
    run()
