"""Paper Fig. 15: end-to-end latency decomposed into prefill / compress /
communication / decompress / decode, per method."""
from __future__ import annotations

import time

from benchmarks.common import cached_profiles, emit
from repro.controller import ServiceAwareController
from repro.data.synthetic import WORKLOADS
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    NoCompressionPolicy,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)


def run(smoke: bool = False) -> None:
    profiles = cached_profiles()
    kivi = next(p for p in profiles if "kivi" in p.strategy.short_name())
    cachegen = next(p for p in profiles
                    if "cachegen" in p.strategy.short_name())
    trace = lambda: BandwidthTrace.constant(0.1 * GBPS)
    n = 12 if smoke else 30
    reqs = lambda: WorkloadMix(rate=2.0, seed=2, q_min=0.0).generate(n)

    policies = {
        "default": NoCompressionPolicy(),
        "kivi": StaticPolicy(kivi, "kivi"),
        "cachegen": StaticPolicy(cachegen, "cg"),
        "kvserve": KVServePolicy(ServiceAwareController(
            {w: profiles for w in WORKLOADS})),
    }
    for name, pol in policies.items():
        t0 = time.perf_counter()
        res = Simulator(SimConfig(), pol, trace(), reqs()).run()
        bd = res.breakdown()
        total = sum(bd.values())
        us = (time.perf_counter() - t0) * 1e6
        comm_share = 100 * bd["comm"] / max(total, 1e-12)
        emit(f"fig15_breakdown_{name}", us,
             f"prefill={bd['prefill']:.2f} compress={bd['compress']:.3f} "
             f"comm={bd['comm']:.2f} decompress={bd['decompress']:.3f} "
             f"decode={bd['decode']:.2f} comm_share={comm_share:.0f}%")


if __name__ == "__main__":
    run()
