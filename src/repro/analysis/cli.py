"""CLI driver: load sources, run every rule, apply suppressions.

Exit status: 0 when the tree is clean (suppressed findings are fine and
are reported as documentation), 1 on any unsuppressed finding or
parse/grammar error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from repro.analysis.core import Finding, Project, load_project


def _rules():
    from repro.analysis import ALL_RULES   # late: avoids a module cycle
    return ALL_RULES


def _apply_suppressions(project: Project, findings: List[Finding],
                        token_by_rule) -> None:
    by_rel = {f.rel: f for f in project.files}
    for fd in findings:
        token = token_by_rule.get(fd.rule)
        src = by_rel.get(fd.path)
        if token is None or src is None:
            continue   # grammar/parse findings are never suppressible
        for line in (fd.line, fd.line - 1):
            reason = src.suppressions.get(line, {}).get(token)
            if reason:
                fd.suppressed = True
                fd.reason = reason
                break


def run_paths(paths: Sequence[str], base: Optional[Path] = None
              ) -> Tuple[List[Finding], List[Finding]]:
    """Run every registered rule.  Returns (unsuppressed, suppressed)."""
    rules = _rules()
    project, findings = load_project(paths, (r.token for r in rules),
                                     base=base)
    for rule in rules:
        findings.extend(rule.check(project))
    _apply_suppressions(project, findings, {r.id: r.token for r in rules})
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    open_ = [f for f in findings if not f.suppressed]
    closed = [f for f in findings if f.suppressed]
    return open_, closed


def _finding_key(f) -> Tuple[str, str, str]:
    """Identity of a finding across runs: line numbers shift with
    unrelated edits, so the diff matches on (rule, path, message)."""
    rule = f.rule if hasattr(f, "rule") else f["rule"]
    path = f.path if hasattr(f, "path") else f["path"]
    message = f.message if hasattr(f, "message") else f["message"]
    return (rule, path, message)


def diff_baseline(open_: List[Finding], baseline_path: str
                  ) -> Tuple[List[Finding], int]:
    """Split the open findings against a previous ``--format=json``
    report.  Returns ``(new_findings, resolved_count)``: findings absent
    from the baseline, and baseline findings no longer present."""
    payload = json.loads(Path(baseline_path).read_text())
    known = {_finding_key(f) for f in payload.get("findings", [])}
    new = [f for f in open_ if _finding_key(f) not in known]
    resolved = len(known - {_finding_key(f) for f in open_})
    return new, resolved


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis "
                    "(host-sync, clock-accounting, units, kernel-contract, "
                    "ownership, determinism)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to scan (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print documented (suppressed) findings")
    ap.add_argument("--baseline", metavar="JSON",
                    help="previous --format=json report: only findings "
                         "NOT in it are reported/counted (diff mode); "
                         "exit 0 when no new findings")
    args = ap.parse_args(argv)

    open_, closed = run_paths(args.paths)
    resolved = None
    if args.baseline:
        open_, resolved = diff_baseline(open_, args.baseline)
    if args.format == "json":
        payload = {
            "findings": [f.to_json() for f in open_],
            "suppressed": [f.to_json() for f in closed],
            "counts": {"open": len(open_), "suppressed": len(closed)},
        }
        if resolved is not None:
            payload["baseline"] = {"new": len(open_), "resolved": resolved}
        print(json.dumps(payload, indent=1, sort_keys=True))
    else:
        for f in open_:
            print(f.render())
        if args.show_suppressed:
            for f in closed:
                print(f.render())
        if resolved is not None:
            print(f"# {len(open_)} new finding(s) vs baseline "
                  f"({resolved} resolved), {len(closed)} suppressed",
                  file=sys.stderr)
        else:
            print(f"# {len(open_)} finding(s), {len(closed)} suppressed",
                  file=sys.stderr)
    return 1 if open_ else 0
