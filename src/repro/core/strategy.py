"""Strategy space: the paper's unified ``BS = C(Q(T(X)))`` pipeline configs.

A :class:`StrategyConfig` fully determines one point in the searchable
strategy space (Sec. 5.1).  ``enumerate_space`` reproduces the paper's
Fig. 5-left growth: "module" granularity enumerates pipeline/module choices,
"hybrid" additionally sweeps fine-grained parameters (~10^4 candidates).
"""
from __future__ import annotations

import hashlib
import itertools
import json
from dataclasses import asdict, dataclass, field, replace
from typing import Dict, Iterator, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Vocabularies for each pipeline stage.
# ---------------------------------------------------------------------------
TRANSFORMS = ("none", "delta", "hadamard", "affine")
QUANTIZERS = ("uniform", "kivi", "cachegen", "mixhq", "duo")
GRANULARITIES = ("per_head", "per_channel", "per_token")
CODECS = ("none", "zstd1", "zstd3", "zstd10", "bitshuffle_zstd3")

BITS_CHOICES = (2, 3, 4, 6, 8)
GROUP_CHOICES = (32, 64, 128)
DELTA_GROUPS = (16, 64)

# Logical source precision of the KV cache on the wire (bf16 = 2 bytes).
SOURCE_BITS = 16
SOURCE_BYTES = 2
SCALE_BYTES = 2  # fp16 scale
ZP_BYTES = 2  # fp16 zero-point


@dataclass(frozen=True)
class StrategyConfig:
    """One point of the strategy space; hashable, JSON round-trippable."""

    transform: str = "none"  # none | delta | hadamard | affine
    delta_group: int = 64  # anchor spacing for the delta transform

    quantizer: str = "uniform"  # uniform | kivi | cachegen | mixhq | duo
    key_bits: int = 4
    value_bits: int = 4
    granularity: str = "per_channel"  # grouping pattern for uniform
    group_size: int = 64
    symmetric: bool = False

    # MixHQ (the paper's new quantizer component, Sec. 5.1)
    mixhq_high_bits: int = 8
    mixhq_low_bits: int = 2
    retrieval_frac: float = 0.25
    # MixHQ generalisations: layer-pyramid and token heavy-hitter dimensions.
    layer_pyramid: bool = False
    token_heavy_hitter_frac: float = 0.0

    # CacheGen layer tiers (earlier layers more sensitive -> more bits).
    tier_bits: Tuple[int, int, int] = (4, 3, 2)
    tier_fracs: Tuple[float, float] = (0.2, 0.3)  # remainder gets tier 3

    # DuoAttention-style pruning baseline.
    duo_sink: int = 4
    duo_recent: int = 128

    codec: str = "none"

    # ------------------------------------------------------------------
    def key(self) -> str:
        payload = json.dumps(asdict(self), sort_keys=True, default=str)
        return hashlib.sha1(payload.encode()).hexdigest()[:12]

    def short_name(self) -> str:
        if self.quantizer == "mixhq":
            q = f"mixhq{self.mixhq_high_bits}/{self.mixhq_low_bits}"
        elif self.quantizer == "cachegen":
            q = "cachegen" + "".join(str(b) for b in self.tier_bits)
        elif self.quantizer == "duo":
            q = f"duo{self.duo_recent}"
        else:
            q = f"{self.quantizer}{self.key_bits}/{self.value_bits}"
        return f"{self.transform}-{q}-{self.codec}"

    def to_json(self) -> str:
        return json.dumps(asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "StrategyConfig":
        d = json.loads(s)
        d["tier_bits"] = tuple(d["tier_bits"])
        d["tier_fracs"] = tuple(d["tier_fracs"])
        return StrategyConfig(**d)

    def validate(self) -> None:
        assert self.transform in TRANSFORMS, self.transform
        assert self.quantizer in QUANTIZERS, self.quantizer
        assert self.granularity in GRANULARITIES, self.granularity
        assert self.codec in CODECS, self.codec
        for b in (self.key_bits, self.value_bits):
            assert 1 <= b <= 16, b  # 16 == passthrough (identity)
        for b in (self.mixhq_high_bits, self.mixhq_low_bits):
            assert 1 <= b <= 8, b
        assert 0.0 <= self.retrieval_frac <= 1.0


# The uncompressed pass-through (cr=1, infinite throughput) — always a
# candidate so the controller can "bypass compression" (paper Sec. 7.2).
IDENTITY_STRATEGY = StrategyConfig(
    transform="none", quantizer="uniform", key_bits=16, value_bits=16, codec="none"
)


def is_identity(cfg: StrategyConfig) -> bool:
    return cfg.key_bits >= 16 and cfg.value_bits >= 16 and cfg.codec == "none"


def paged_eligible(cfg: StrategyConfig,
                   head_dim: Optional[int] = None) -> bool:
    """True when a strategy's compressed form can live directly in the
    paged arena's quantized page pool (DESIGN.md §12): plain symmetric
    per-token uniform quantization, no transform, no entropy codec, and
    equal 4- or 8-bit K/V — exactly the layout the fused dequant decode
    path consumes.  Everything else falls back to the materialized
    fp16-page injection path.  ``head_dim`` (when known) additionally
    requires the quant group to tile the channel axis."""
    return (
        cfg.quantizer == "uniform"
        and cfg.granularity == "per_token"
        and cfg.symmetric
        and cfg.transform == "none"
        and cfg.codec == "none"
        and cfg.key_bits == cfg.value_bits
        and cfg.key_bits in (4, 8)
        and not is_identity(cfg)
        and (head_dim is None or head_dim % cfg.group_size == 0)
    )


# ---------------------------------------------------------------------------
# Named baselines (paper Sec. 7.1): core algorithms mapped into the pipeline.
# ---------------------------------------------------------------------------
BASELINES: Dict[str, StrategyConfig] = {
    # CacheGen: delta against anchors + layer-tiered quant + entropy coding.
    "cachegen": StrategyConfig(
        transform="delta",
        delta_group=64,
        quantizer="cachegen",
        tier_bits=(4, 3, 2),
        tier_fracs=(0.2, 0.3),
        granularity="per_channel",
        group_size=64,
        codec="zstd3",
    ),
    # KIVI: asymmetric 2-bit; K per-channel / V per-token with group metadata.
    "kivi": StrategyConfig(
        transform="none",
        quantizer="kivi",
        key_bits=2,
        value_bits=2,
        group_size=32,
        symmetric=False,
        codec="none",
    ),
    # DuoAttention: retrieval heads full precision, streaming heads pruned to
    # sink+recent tokens.
    "duoattention": StrategyConfig(
        transform="none",
        quantizer="duo",
        retrieval_frac=0.25,
        duo_sink=4,
        duo_recent=128,
        codec="none",
    ),
    # MixHQ with a robust default (the paper's own component).
    "mixhq": StrategyConfig(
        transform="hadamard",
        quantizer="mixhq",
        mixhq_high_bits=8,
        mixhq_low_bits=2,
        retrieval_frac=0.25,
        group_size=64,
        codec="none",
    ),
}


# ---------------------------------------------------------------------------
# Space enumeration (Fig. 5 left).
# ---------------------------------------------------------------------------
def enumerate_space(level: str = "module") -> List[StrategyConfig]:
    """Enumerate the strategy space.

    level="pipeline": stage choices only (T x Q x C).
    level="module":   + bit-width module parameters (order 10^2).
    level="hybrid":   + fine-grained parameter tuning (order 10^4).
    """
    out: List[StrategyConfig] = []
    if level == "pipeline":
        # Stage *kind* choices only (T x Q), default parameters/codec.
        for t, q in itertools.product(TRANSFORMS, QUANTIZERS):
            out.append(StrategyConfig(transform=t, quantizer=q))
        return _dedup(out)

    bits = BITS_CHOICES if level == "hybrid" else (2, 4, 8)
    groups = GROUP_CHOICES if level == "hybrid" else (64,)
    fracs = (0.125, 0.25, 0.5) if level == "hybrid" else (0.25,)
    codecs = CODECS if level == "hybrid" else ("none", "zstd3")
    transforms = TRANSFORMS if level == "hybrid" else ("none", "delta", "hadamard")

    for t in transforms:
        dgs = DELTA_GROUPS if (t == "delta" and level == "hybrid") else (64,)
        for dg in dgs:
            for codec in codecs:
                # uniform: bits x granularity x group
                grans = GRANULARITIES if level == "hybrid" else ("per_channel",)
                for kb, vb in itertools.product(bits, bits):
                    for g in grans:
                        for gs in groups:
                            out.append(
                                StrategyConfig(
                                    transform=t, delta_group=dg, quantizer="uniform",
                                    key_bits=kb, value_bits=vb, granularity=g,
                                    group_size=gs, codec=codec,
                                )
                            )
                # kivi: bits x group
                for b in bits:
                    for gs in groups:
                        out.append(
                            StrategyConfig(
                                transform=t, delta_group=dg, quantizer="kivi",
                                key_bits=b, value_bits=b, group_size=gs, codec=codec,
                            )
                        )
                # cachegen tiers
                tier_opts = (
                    [(8, 4, 2), (6, 4, 2), (4, 3, 2), (4, 2, 2), (3, 2, 1)]
                    if level == "hybrid"
                    else [(4, 3, 2)]
                )
                for tb in tier_opts:
                    out.append(
                        StrategyConfig(
                            transform=t, delta_group=dg, quantizer="cachegen",
                            tier_bits=tb, codec=codec,
                        )
                    )
                # mixhq: high/low bits x retrieval fraction (+ generalisations)
                hb_opts = (8, 6, 4) if level == "hybrid" else (8,)
                lb_opts = (1, 2, 3) if level == "hybrid" else (2,)
                for hb, lb in itertools.product(hb_opts, lb_opts):
                    for rf in fracs:
                        for gs in groups:
                            out.append(
                                StrategyConfig(
                                    transform=t, delta_group=dg, quantizer="mixhq",
                                    mixhq_high_bits=hb, mixhq_low_bits=lb,
                                    retrieval_frac=rf, group_size=gs, codec=codec,
                                )
                            )
                            if level == "hybrid":
                                out.append(
                                    StrategyConfig(
                                        transform=t, delta_group=dg, quantizer="mixhq",
                                        mixhq_high_bits=hb, mixhq_low_bits=lb,
                                        retrieval_frac=rf, group_size=gs,
                                        layer_pyramid=True, codec=codec,
                                    )
                                )
                # duo pruning
                for rf in fracs:
                    out.append(
                        StrategyConfig(
                            transform=t, delta_group=dg, quantizer="duo",
                            retrieval_frac=rf, codec=codec,
                        )
                    )
    return _dedup(out)


def _dedup(cfgs: List[StrategyConfig]) -> List[StrategyConfig]:
    seen, out = set(), []
    for c in cfgs:
        k = c.key()
        if k not in seen:
            seen.add(k)
            out.append(c)
    return out


def space_sizes() -> Dict[str, int]:
    return {lvl: len(enumerate_space(lvl)) for lvl in ("pipeline", "module", "hybrid")}


# ---------------------------------------------------------------------------
# Analytic CR estimate (used for BO pruning; Observation 2 says relative CR
# rankings are stable, so a bits-accounting estimate orders candidates well).
# ---------------------------------------------------------------------------
def estimate_cr(cfg: StrategyConfig, num_layers: int = 8, kv_heads: int = 4,
                seq: int = 512, head_dim: int = 64) -> float:
    """Cheap data-free CR estimate from bits + metadata accounting."""
    n = num_layers * 2 * kv_heads * seq * head_dim
    orig_bits = n * SOURCE_BITS

    def _meta_bits(groups: int) -> float:
        zp = 0 if cfg.symmetric else ZP_BYTES * 8
        return groups * (SCALE_BYTES * 8 + zp)

    if cfg.quantizer == "uniform":
        kb, vb = min(cfg.key_bits, 16), min(cfg.value_bits, 16)
        payload = n / 2 * kb + n / 2 * vb
        if cfg.granularity == "per_head":
            groups = num_layers * 2 * kv_heads
        elif cfg.granularity == "per_channel":
            groups = num_layers * 2 * kv_heads * head_dim * max(seq // cfg.group_size, 1)
        else:  # per_token
            groups = num_layers * 2 * kv_heads * seq * max(head_dim // cfg.group_size, 1)
        meta = _meta_bits(groups)
    elif cfg.quantizer == "kivi":
        payload = n * cfg.key_bits
        groups_k = num_layers * kv_heads * head_dim * max(seq // cfg.group_size, 1)
        groups_v = num_layers * kv_heads * seq * max(head_dim // cfg.group_size, 1)
        meta = _meta_bits(groups_k + groups_v)
    elif cfg.quantizer == "cachegen":
        f1, f2 = cfg.tier_fracs
        b = (cfg.tier_bits[0] * f1 + cfg.tier_bits[1] * f2
             + cfg.tier_bits[2] * (1 - f1 - f2))
        payload = n * b
        groups = num_layers * 2 * kv_heads * head_dim * max(seq // cfg.group_size, 1)
        meta = _meta_bits(groups)
    elif cfg.quantizer == "mixhq":
        rf = cfg.retrieval_frac
        b = cfg.mixhq_high_bits * rf + cfg.mixhq_low_bits * (1 - rf)
        if cfg.layer_pyramid:
            b *= 0.85  # deeper layers shaved further
        payload = n * b
        groups = num_layers * 2 * kv_heads * head_dim * max(seq // cfg.group_size, 1)
        meta = _meta_bits(groups)
    elif cfg.quantizer == "duo":
        rf = cfg.retrieval_frac
        kept = min((cfg.duo_sink + cfg.duo_recent) / seq, 1.0)
        payload = n * SOURCE_BITS * (rf + (1 - rf) * kept)
        meta = 0.0
    else:  # pragma: no cover
        raise ValueError(cfg.quantizer)

    codec_gain = {
        "none": 1.0, "zstd1": 1.25, "zstd3": 1.35, "zstd10": 1.45,
        "bitshuffle_zstd3": 1.55,
    }[cfg.codec]
    transform_gain = {"none": 1.0, "delta": 1.1, "hadamard": 1.0, "affine": 1.02}[
        cfg.transform
    ]
    comp_bits = (payload / (codec_gain * transform_gain)) + meta
    return float(orig_bits / max(comp_bits, 1.0))
