"""In-house AdamW with cosine and WSD (minicpm) schedules.

Optimizer state shards exactly like the parameters (mu/nu trees share the
param logical axes), so no extra sharding rules are needed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | wsd | constant
    wsd_stable_frac: float = 0.8  # WSD: fraction of steps at peak LR
    grad_clip: float = 1.0


def schedule_lr(oc: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(oc.warmup_steps, 1), 1.0)
    if oc.schedule == "constant":
        frac = jnp.ones(())
    elif oc.schedule == "wsd":
        # Warmup -> Stable -> (linear) Decay, the minicpm schedule.
        stable_end = oc.total_steps * oc.wsd_stable_frac
        decay_len = jnp.maximum(oc.total_steps - stable_end, 1.0)
        frac = jnp.where(
            s <= stable_end, 1.0,
            jnp.maximum(1.0 - (s - stable_end) / decay_len, 0.0))
    else:  # cosine
        prog = jnp.clip(s / jnp.maximum(oc.total_steps, 1), 0.0, 1.0)
        frac = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * frac


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32)
        if not isinstance(x, jax.ShapeDtypeStruct)
        else jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": (jnp.zeros((), jnp.int32)
                     if not _is_abstract(params)
                     else jax.ShapeDtypeStruct((), jnp.int32))}


def _is_abstract(tree) -> bool:
    leaves = jax.tree_util.tree_leaves(tree)
    return bool(leaves) and isinstance(leaves[0], jax.ShapeDtypeStruct)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def adamw_update(params, grads, state, oc: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_lr(oc, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9)) if oc.grad_clip else 1.0

    b1, b2 = oc.beta1, oc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)

    new_params = jax.tree_util.tree_unflatten(tdef, new_p)
    new_state = {"mu": jax.tree_util.tree_unflatten(tdef, new_mu),
                 "nu": jax.tree_util.tree_unflatten(tdef, new_nu),
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
