"""Logical-axis -> mesh sharding resolution with divisibility fallback.

Rules follow MaxText conventions: batch over (pod, data); heads / mlp /
vocab / experts over model (tensor / expert parallelism).  Every mapping is
validated for divisibility — when an axis doesn't divide (e.g. 8 KV heads on
a 16-way model axis, or minicpm's 36 heads), the rule falls back to the next
candidate or to replication, which guarantees that *every* (arch × shape ×
mesh) cell lowers and compiles; the roofline pass then shows where fallback
cost lands.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered candidates per logical axis name.
LOGICAL_RULES: Dict[str, List[Tuple[str, ...]]] = {
    "batch": [("pod", "data"), ("data",)],
    "layers": [],
    "embed": [],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [],
    "mlp": [("model",)],
    "expert_mlp": [],
    "experts": [("model",)],
    "vocab": [("model",)],
    "state": [],
    "seq": [],
    "kv_seq": [("model",)],  # decode fallback: sequence-sharded KV
}


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_axes(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, List[Tuple[str, ...]]]] = None,
) -> P:
    """Map logical axes to a PartitionSpec, enforcing divisibility and
    never using a mesh axis twice."""
    rules = rules or LOGICAL_RULES
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        assigned = None
        for cand in rules.get(name or "", []):
            cand = tuple(a for a in cand if a in sizes)
            if not cand or any(a in used for a in cand):
                continue
            prod = int(np.prod([sizes[a] for a in cand]))
            if prod > 1 and dim % prod == 0:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(assigned)
    return P(*out)


def tree_pspecs(axes_tree, shapes_tree, mesh: Mesh, rules=None):
    """PartitionSpec tree from (axes_tree, value/ShapeDtypeStruct tree)."""
    def _one(axes, val):
        return resolve_axes(axes, val.shape, mesh, rules)

    return jax.tree_util.tree_map(
        _one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh, rules=None):
    specs = tree_pspecs(axes_tree, shapes_tree, mesh, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input/cache specs (activations are left to GSPMD propagation beyond these
# boundary annotations).
# ---------------------------------------------------------------------------
def batch_axes_for(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    sizes = mesh_axis_sizes(mesh)
    for cand in LOGICAL_RULES["batch"]:
        cand = tuple(a for a in cand if a in sizes)
        if not cand:
            continue
        prod = int(np.prod([sizes[a] for a in cand]))
        if prod > 1 and batch % prod == 0:
            return cand
    return None


def kv_cache_pspec(mesh: Mesh, shape: Tuple[int, int, int, int]) -> P:
    """Cache (B, S, Hkv, D): prefer head sharding, fall back to sequence
    sharding (flash-decoding style partial attention)."""
    b, s, h, _ = shape
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    bspec = batch_axes_for(mesh, b)
    bspec = bspec if bspec is None or len(bspec) > 1 else bspec[0]
    if model > 1 and h % model == 0:
        return P(bspec, None, "model", None)
    if model > 1 and s % model == 0:
        return P(bspec, "model", None, None)
    return P(bspec, None, None, None)


def mamba_state_pspec(mesh: Mesh, shape: Tuple[int, ...]) -> P:
    """SSM state (B, d_inner, n) / conv state (B, d_inner, k-1)."""
    b, di = shape[0], shape[1]
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    bspec = batch_axes_for(mesh, b)
    bspec = bspec if bspec is None or len(bspec) > 1 else bspec[0]
    rest = ["model" if (model > 1 and di % model == 0) else None]
    rest += [None] * (len(shape) - 2)
    return P(bspec, *rest)


def cache_pspecs(cache_tree, mesh: Mesh):
    """PartitionSpec tree for a cache pytree (leaves are 4D k/v buffers,
    stacked 5D block buffers, or 3D mamba states)."""
    def _one(path, x):
        shape = tuple(x.shape)
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        stacked = "blocks" in names
        core = shape[1:] if stacked else shape
        if len(core) == 4:  # attention cache
            spec = kv_cache_pspec(mesh, core)
        elif len(core) in (2, 3):  # mamba ssm/conv state
            spec = mamba_state_pspec(mesh, core)
        else:
            spec = P(*([None] * len(core)))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(_one, cache_tree)


def inputs_pspecs(inputs_tree, mesh: Mesh, cfg=None):
    """Specs for a step-input pytree (tokens/mask/frames/patches/pos/caches)."""
    def _one(path, x):
        if not hasattr(x, "shape"):
            return None  # static python value (e.g. max_len)
        shape = tuple(x.shape)
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if "caches" in names:
            return None  # handled by cache_pspecs
        if shape == ():
            return P()
        leaf = names[-1] if names else ""
        if leaf == "positions":  # (3, B, S)
            bspec = batch_axes_for(mesh, shape[1])
            bspec = bspec if bspec is None or len(bspec) > 1 else bspec[0]
            return P(None, bspec, *([None] * (len(shape) - 2)))
        bspec = batch_axes_for(mesh, shape[0])
        bspec = bspec if bspec is None or len(bspec) > 1 else bspec[0]
        return P(bspec, *([None] * (len(shape) - 1)))

    def _full(path, x):
        spec = _one(path, x)
        return spec

    specs = jax.tree_util.tree_map_with_path(_full, inputs_tree)

    # Patch cache subtree (if present) with cache-aware specs.
    if isinstance(inputs_tree, dict) and "caches" in inputs_tree:
        specs["caches"] = cache_pspecs(inputs_tree["caches"], mesh)
    return specs


def to_named(specs_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, P))
