"""Real-execution disaggregated serving runtime (CPU, tiny reference model).

A faithful miniature of the paper's vLLM integration, in two granularities:

* :class:`DisaggregatedEngine` — the original one-shot PD path: ``serve``
  runs a single synchronous batch end-to-end (prefill -> compress -> wire
  -> decompress -> decode) and reports a :class:`ServedBatch` breakdown.

* :class:`ServingRuntime` — the continuous-batching, multi-tenant runtime
  (DESIGN.md §9): ``submit`` enqueues :class:`~repro.serving.request.Request`
  objects through the shared :class:`~repro.serving.scheduler.ContinuousScheduler`
  (admission control + SLO-class priorities), and each ``step()`` is one
  iteration — admit up to ``max_prefills_per_step`` prefill/fetch slots,
  then advance every in-flight decode slot by one token with a SINGLE
  jitted batched decode over the fixed-capacity slot arena.  Prompts whose
  prefix is already in the :class:`~repro.serving.kvstore.PrefixKVStore`
  are served from the pool (fetch real compressed bytes -> decompress ->
  inject into the request's arena slot), reproducing the paper's
  KV-disaggregated TTFT path; misses run a real prefill into the slot and
  write the compressed prefix back to the pool with the profile the
  Service-Aware Controller picked for the request.

The slot arena is ONE cache pytree with a leading slot axis of size
``max_slots``.  Each slot owns a cache row, a per-slot position, and a
live flag; the batched decode step masks free/fresh rows (parked at a
scratch position) instead of branching per slot, so decode wall-clock is
one model call per iteration regardless of occupancy — the continuous-
batching amortization the per-slot loop of PR 1 lacked.

Every byte on the "wire" is real pipeline output.  Compute time is either
measured wall-clock or (for deterministic benchmarks) modelled from
``prefill_tok_s`` / ``decode_tok_s``; communication time always comes from
the :class:`~repro.serving.network.BandwidthTrace`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.controller import Decision, ServiceAwareController, ServiceContext
from repro.core.pipeline import CompressedKV, CompressionPipeline
from repro.core.profiles import Profile
from repro.core.quality import (
    _greedy_decode,
    _jitted_steps,
    _prompts_for,
    copy_cache_slot,
    extract_kv,
    get_reference_model,
    inject_kv,
)
from repro.core.strategy import StrategyConfig, is_identity
from repro.data.tokenizer import ByteTokenizer
from repro.serving.kvstore import PrefixKVStore
from repro.serving.network import BandwidthTrace, GoodputEstimator
from repro.serving.request import Request, kv_bytes_for
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig


def _select_profile(controller: Optional[ServiceAwareController],
                    static_profile: Optional[Profile],
                    ctx: ServiceContext
                    ) -> Tuple[Profile, Optional[Decision]]:
    """Shared controller / static / identity three-way profile choice."""
    if controller is not None:
        d = controller.select(ctx)
        return d.profile, d
    if static_profile is not None:
        return static_profile, None
    from repro.core.profiles import IDENTITY_PROFILE
    return IDENTITY_PROFILE, None


@dataclass
class ServedBatch:
    workload: str
    text: List[str]
    tokens: np.ndarray
    profile: str
    kv_bytes: int
    wire_bytes: int
    t_prefill: float
    t_compress: float
    t_comm: float
    t_decompress: float
    t_decode: float
    agreement: float  # vs uncompressed decode

    @property
    def jct(self) -> float:
        return (self.t_prefill + self.t_compress + self.t_comm
                + self.t_decompress + self.t_decode)


class DisaggregatedEngine:
    """PD-separated serving of the tiny reference model with real
    compression on the KV path."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 seq: int = 192, decode_tokens: int = 20, batch: int = 4):
        self.cfg, self.params = get_reference_model()
        self.controller = controller
        self.static_profile = static_profile
        self.seq = seq
        self.decode_tokens = decode_tokens
        self.batch = batch
        self.estimator = GoodputEstimator()
        self._pre, self._dec, _ = _jitted_steps(
            self.cfg.name, seq, batch, seq + decode_tokens + 2)
        self.tok = ByteTokenizer()

    # ------------------------------------------------------------------
    def serve(self, workload: str, trace: BandwidthTrace, now: float = 0.0,
              t_slo: float = 0.0, q_min: float = 0.97, seed: int = 0
              ) -> ServedBatch:
        tokens, _ = _prompts_for(workload, self.batch, self.seq, seed)

        # ---- prefill worker ----
        t0 = time.perf_counter()
        logits, caches = self._pre(self.params, {"tokens": tokens})
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        first = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

        # reference decode for agreement scoring
        ref_toks = _greedy_decode(self._dec, self.params, caches, first,
                                  self.seq, self.decode_tokens)

        # ---- controller decision ----
        kvs = [extract_kv(self.cfg, caches, b, upto=self.seq)
               for b in range(self.batch)]
        v_bytes = sum(kv.nbytes_wire() for kv in kvs)
        ctx = ServiceContext(workload=workload,
                             bandwidth=self.estimator.estimate,
                             t_slo=t_slo, q_min=q_min, t_model=t_prefill,
                             kv_bytes=v_bytes)
        profile, decision = _select_profile(self.controller,
                                            self.static_profile, ctx)

        # ---- compress -> wire -> decompress (real bytes) ----
        pipe = CompressionPipeline(profile.strategy)
        t0 = time.perf_counter()
        comps = [pipe.compress(kv) for kv in kvs]
        t_compress = time.perf_counter() - t0
        wire_bytes = sum(c.total_bytes() for c in comps)
        t_comm = trace.transfer_time(now + t_prefill + t_compress, wire_bytes)
        self.estimator.observe(wire_bytes, t_comm)
        t0 = time.perf_counter()
        restored = [pipe.decompress(c) for c in comps]
        t_decompress = time.perf_counter() - t0

        # ---- decode worker ----
        comp_caches = caches
        if not is_identity(profile.strategy):
            for b in range(self.batch):
                comp_caches = inject_kv(self.cfg, comp_caches, b, restored[b])
        t0 = time.perf_counter()
        test_toks = _greedy_decode(self._dec, self.params, comp_caches, first,
                                   self.seq, self.decode_tokens)
        t_decode = time.perf_counter() - t0

        agreement = float((ref_toks == test_toks).mean())
        # One-shot PD: compress/comm/decompress ARE the critical path.
        observed = t_compress + t_comm + t_decompress + ctx.t_model
        if self.controller is not None and decision is not None:
            self.controller.observe(ctx, decision, observed)

        texts = [self.tok.decode(row[1:]) for row in test_toks]
        return ServedBatch(
            workload=workload, text=texts, tokens=test_toks,
            profile=profile.strategy.short_name(), kv_bytes=int(v_bytes),
            wire_bytes=int(wire_bytes), t_prefill=t_prefill,
            t_compress=t_compress, t_comm=t_comm,
            t_decompress=t_decompress, t_decode=t_decode,
            agreement=agreement)


# ===========================================================================
# Continuous-batching runtime
# ===========================================================================
@dataclass
class RuntimeConfig:
    seq: int = 96                 # prompt tokens (padded/truncated)
    decode_tokens: int = 12       # generation budget per request
    # Virtual-clock cost model.  None = measure wall-clock (real execution
    # time of the tiny model); a float models a loaded cluster, which is the
    # paper's pool regime where prefill is the expensive path.
    prefill_tok_s: Optional[float] = None
    decode_tok_s: Optional[float] = None
    pool_fetch_overhead: float = 0.002   # pool RPC setup cost (s)
    store_capacity: int = 64 << 20       # wire bytes
    store_block: int = 16


@dataclass
class ServedRequest:
    """Per-request outcome of the continuous runtime (the per-request
    analogue of :class:`ServedBatch`)."""

    rid: int
    workload: str
    slo_class: str
    text: str
    tokens: np.ndarray
    profile: str
    pool_hit: bool
    kv_bytes: int
    wire_bytes: int               # bytes this request moved over the wire
    arrival: float
    done: float
    ttft: float
    slot: int = -1                # arena slot that served the request
    # Critical-path decomposition; sums exactly to jct.  Keys: queue,
    # prefill | comm+decompress, decode, stall (time spent waiting on the
    # iteration's other stream, e.g. head-of-line prefill blocking decode).
    breakdown: Dict[str, float] = field(default_factory=dict)
    # Off-critical-path cost of writing the compressed prefix to the pool
    # (compress + wire), charged to the background writer, not the request.
    t_pool_write: float = 0.0

    @property
    def jct(self) -> float:
        return self.done - self.arrival


@dataclass
class _Slot:
    """Host-side bookkeeping for one occupied arena slot (the device-side
    state — cache row, position, live flag — lives in the arena arrays)."""

    req: Request
    idx: int                      # arena slot index (row in the cache pytree)
    toks: List[int]               # generated tokens (incl. first)
    pool_hit: bool
    profile: str
    wire_bytes: int
    breakdown: Dict[str, float]
    ttft: float
    pool_write: float = 0.0       # off-path compress+write cost (misses)
    # Controller feedback deferred to _finish so the bandit observes the
    # request's realized critical-path latency (= breakdown sum = jct),
    # not the off-critical-path pool write.
    ctx: Optional[ServiceContext] = None
    decision: Optional[Decision] = None


class ServingRuntime:
    """Iteration-level (continuous-batching) serving of the tiny reference
    model against a compressed prefix-KV pool, on a batched slot arena."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 config: Optional[RuntimeConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 store: Optional[PrefixKVStore] = None,
                 trace: Optional[BandwidthTrace] = None):
        self.cfg = config or RuntimeConfig()
        self.controller = controller
        self.static_profile = static_profile
        self.scheduler = ContinuousScheduler(scheduler or SchedulerConfig())
        # NOTE: `store or ...` would discard a passed-in *empty* store
        # (PrefixKVStore defines __len__).
        self.store = store if store is not None else PrefixKVStore(
            self.cfg.store_capacity, block=self.cfg.store_block)
        self.trace = trace or BandwidthTrace.constant(1e9)
        self.estimator = GoodputEstimator(initial=self.trace.at(0.0))
        self.model_cfg, self.params = get_reference_model()
        self.max_len = self.cfg.seq + self.cfg.decode_tokens + 2
        self._pre1, _, _ = _jitted_steps(
            self.model_cfg.name, self.cfg.seq, 1, self.max_len)
        self.n_slots = self.scheduler.cfg.max_slots
        _, _, self._dec_arena = _jitted_steps(
            self.model_cfg.name, self.cfg.seq, self.n_slots, self.max_len)
        self.tok = ByteTokenizer()
        self.clock = 0.0
        self.steps = 0
        self.completed: List[ServedRequest] = []
        self.step_log: List[Dict[str, float]] = []
        self._slots: Dict[int, _Slot] = {}
        self._prompts: Dict[int, np.ndarray] = {}
        self._next_rid = 0
        # ---- device-side slot arena (lazily materialised) ----
        self._arena: Any = None          # cache pytree, leading axis n_slots
        self._positions = np.zeros(self.n_slots, np.int32)  # next write pos
        self._last_tok = np.zeros(self.n_slots, np.int32)   # last emitted tok

    # ------------------------------------------------------------------
    def _ensure_arena(self):
        if self._arena is None:
            from repro.models.transformer import init_cache, plan_stack
            plan = plan_stack(self.model_cfg)
            if any(s.kind != "attn"
                   for s in plan.prefix_specs + plan.period_specs):
                raise NotImplementedError(
                    "slot arena masking assumes attention-only caches "
                    "(SSM states advance unmasked)")
            self._arena = init_cache(self.model_cfg, self.n_slots,
                                     self.max_len)
        return self._arena

    # ------------------------------------------------------------------
    def submit(self, workload: str, t_slo: float = 0.0, q_min: float = 0.97,
               slo_class: str = "standard", out_tokens: Optional[int] = None,
               prompt_seed: int = 0) -> Optional[int]:
        """Admit one request at the current virtual time.  Two submissions
        with the same (workload, prompt_seed) share a prompt, so the second
        can be served from the prefix pool.  Returns the request id, or
        None if admission control shed it."""
        rid = self._next_rid
        self._next_rid += 1
        tokens, _ = _prompts_for(workload, 1, self.cfg.seq, prompt_seed)
        tokens = np.asarray(tokens)[0]
        m = self.model_cfg
        req = Request(
            rid=rid, workload=workload, arrival=self.clock,
            ctx_tokens=self.cfg.seq,
            out_tokens=(self.cfg.decode_tokens if out_tokens is None
                        else min(out_tokens, self.cfg.decode_tokens)),
            kv_bytes=kv_bytes_for(self.cfg.seq, m.num_layers, m.kv_heads,
                                  m.resolved_head_dim),
            t_slo=t_slo, q_min=q_min, slo_class=slo_class,
            prefix_key=tuple(int(t) for t in tokens))
        if not self.scheduler.submit(req, self.clock):
            return None
        self._prompts[rid] = tokens
        return rid

    # ------------------------------------------------------------------
    def _start_request(self, req: Request, now: float) -> float:
        """Prefill-or-fetch one admitted request into its arena slot
        (``req.slot``, assigned by the scheduler).  Returns the virtual
        cost this slot added to the iteration."""
        tokens = self._prompts[req.rid]
        key = req.prefix_key
        idx = req.slot
        arena = self._ensure_arena()
        # full=True: a partial (block-aligned) prefix hit would leave the
        # uncovered prompt suffix without KV — the runtime has no top-up
        # prefill, so only a full-coverage entry counts as a pool hit.
        entry = self.store.lookup(key, now=now, full=True)
        bd: Dict[str, float] = {"queue": now - req.arrival}

        if entry is not None:
            # ---- pool hit: fetch real compressed bytes, decompress, and
            # inject straight into the request's arena slot
            comp, first = entry.payload
            t_comm = self.trace.transfer_time(now, entry.wire_bytes)
            self.estimator.observe(entry.wire_bytes, t_comm)
            t0 = time.perf_counter()
            pipe = CompressionPipeline(comp.strategy)
            kv = pipe.decompress(comp)
            t_decompress = time.perf_counter() - t0
            # Cache injection is host-side bookkeeping of the miniature
            # (the cold path's equivalent writes happen inside prefill),
            # so it is not billed to the virtual clock.
            self._arena = inject_kv(self.model_cfg, arena, idx, kv)
            cost = self.cfg.pool_fetch_overhead + t_comm + t_decompress
            bd.update(comm=self.cfg.pool_fetch_overhead + t_comm,
                      decompress=t_decompress)
            slot = _Slot(req=req, idx=idx, toks=[int(first)],
                         pool_hit=True,
                         profile=comp.strategy.short_name(),
                         wire_bytes=int(entry.wire_bytes), breakdown=bd,
                         ttft=(now + cost) - req.arrival)
            self._occupy(slot, int(first))
            return cost

        # ---- miss: real prefill into the slot, then write the compressed
        # prefix back to the pool
        t0 = time.perf_counter()
        logits, caches = self._pre1(self.params, {"tokens": tokens[None, :]})
        jax.block_until_ready(logits)
        t_wall = time.perf_counter() - t0
        t_prefill = (req.ctx_tokens / self.cfg.prefill_tok_s
                     if self.cfg.prefill_tok_s else t_wall)
        first = int(np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))[0])
        bd.update(prefill=t_prefill)
        self._arena = copy_cache_slot(self.model_cfg, arena, caches, idx)

        kv = extract_kv(self.model_cfg, caches, 0, upto=self.cfg.seq)
        ctx = ServiceContext(workload=req.workload,
                             bandwidth=self.estimator.estimate,
                             t_slo=req.t_slo, q_min=req.q_min,
                             t_model=t_prefill, kv_bytes=kv.nbytes_wire())
        profile, decision = _select_profile(self.controller,
                                            self.static_profile, ctx)
        pipe = CompressionPipeline(profile.strategy)
        t0 = time.perf_counter()
        comp = pipe.compress(kv)
        t_compress = time.perf_counter() - t0
        wire = comp.total_bytes()
        # The pool write crosses the wire off the request's critical path;
        # its cost is booked to pool_write, and the controller observes the
        # request's critical-path latency at _finish instead.
        t_comm = self.trace.transfer_time(now + t_prefill + t_compress, wire)
        self.estimator.observe(wire, t_comm)
        self.store.put(key, (comp, first), wire, kv_bytes=kv.nbytes_wire(),
                       workload=req.workload, slo_class=req.slo_class,
                       now=now + t_prefill + t_compress + t_comm)
        slot = _Slot(req=req, idx=idx, toks=[first], pool_hit=False,
                     profile=profile.strategy.short_name(),
                     wire_bytes=int(wire), breakdown=bd,
                     ttft=(now + t_prefill) - req.arrival,
                     pool_write=t_compress + t_comm,
                     ctx=ctx, decision=decision)
        self._occupy(slot, first)
        return t_prefill

    # ------------------------------------------------------------------
    def _occupy(self, slot: _Slot, first: int) -> None:
        self._slots[slot.req.rid] = slot
        self._positions[slot.idx] = self.cfg.seq
        self._last_tok[slot.idx] = first

    # ------------------------------------------------------------------
    def _finish(self, slot: _Slot, now: float) -> None:
        req = slot.req
        toks = np.asarray(slot.toks, dtype=np.int32)
        req.ttft = slot.ttft
        req.done = now
        req.chosen = slot.profile
        req.breakdown = slot.breakdown
        req.slo_violated = req.t_slo > 0 and slot.ttft > req.t_slo
        if self.controller is not None and slot.decision is not None:
            # Residual-bandit feedback: the realized critical-path latency,
            # exactly the ServedRequest breakdown sum (== jct).
            self.controller.observe(slot.ctx, slot.decision,
                                    sum(slot.breakdown.values()))
        self.completed.append(ServedRequest(
            rid=req.rid, workload=req.workload, slo_class=req.slo_class,
            text=self.tok.decode(toks), tokens=toks, profile=slot.profile,
            pool_hit=slot.pool_hit, kv_bytes=int(req.kv_bytes),
            wire_bytes=slot.wire_bytes, arrival=req.arrival, done=now,
            ttft=slot.ttft, slot=slot.idx, breakdown=slot.breakdown,
            t_pool_write=slot.pool_write))
        self.scheduler.finish(req.rid)   # releases the arena slot id
        del self._slots[req.rid]
        self._prompts.pop(req.rid, None)

    # ------------------------------------------------------------------
    def step(self) -> Dict[str, float]:
        """One scheduler iteration: admit prefill/fetch slots, then advance
        every *previously running* decode slot by one token (a request's
        first decode token comes the iteration after its prefill) — all
        slots in ONE masked batched decode call."""
        now = self.clock
        started: List[Tuple[_Slot, float]] = []   # (slot, start-work end offset)
        offset = 0.0
        new_rids = set()
        for req in self.scheduler.next_prefills(now):
            offset += self._start_request(req, now + offset)
            started.append((self._slots[req.rid], offset))
            new_rids.add(req.rid)

        # Iteration-level decode: every in-flight slot emits one token via
        # a single jitted arena step (per-slot positions, on-device argmax,
        # one (B,) token pull per iteration — no per-slot host round-trips).
        decode_wall = 0.0
        active = [s for rid, s in self._slots.items() if rid not in new_rids]
        if active:
            mask = np.zeros(self.n_slots, bool)
            for slot in active:
                mask[slot.idx] = True
            t0 = time.perf_counter()
            nxt, self._arena = self._dec_arena(
                self.params, self._ensure_arena(),
                jnp.asarray(self._last_tok[:, None]),
                jnp.asarray(self._positions), jnp.asarray(mask))
            nxt = np.asarray(nxt)        # the step's single host sync
            decode_wall = time.perf_counter() - t0
            for slot in active:
                t = int(nxt[slot.idx])
                slot.toks.append(t)
                self._last_tok[slot.idx] = t
                self._positions[slot.idx] += 1
        decode_cost = 0.0
        if active:
            decode_cost = (1.0 / self.cfg.decode_tok_s
                           if self.cfg.decode_tok_s else decode_wall)

        # An iteration costs the slower of the prefill and decode streams
        # (PD-separated workers run them concurrently); the difference is
        # charged to each slot as "stall" so breakdowns sum exactly to jct.
        iter_cost = max(offset, decode_cost)
        for slot in active:
            slot.breakdown["decode"] = \
                slot.breakdown.get("decode", 0.0) + decode_cost
            slot.breakdown["stall"] = \
                slot.breakdown.get("stall", 0.0) + iter_cost - decode_cost
        for slot, end_offset in started:
            slot.breakdown["stall"] = \
                slot.breakdown.get("stall", 0.0) + iter_cost - end_offset
        self.clock = now + iter_cost
        self.steps += 1
        for slot in list(self._slots.values()):
            if len(slot.toks) > slot.req.out_tokens:
                self._finish(slot, self.clock)

        stats = {"step": float(self.steps), "clock": self.clock,
                 "in_flight": float(len(active) + len(started)),
                 "queue_depth": float(self.scheduler.queue_depth),
                 "completed": float(len(self.completed)),
                 "store_used": float(self.store.used_bytes)}
        self.step_log.append(stats)
        return stats

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> List[ServedRequest]:
        """Step until every admitted request completed, or until
        ``max_steps`` iterations *from this call* — the budget is relative,
        so a second ``run()`` on a long-lived runtime keeps making
        progress instead of returning against the cumulative counter."""
        start = self.steps
        while not self.scheduler.idle and self.steps - start < max_steps:
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    def max_in_flight(self) -> int:
        return int(max((s["in_flight"] for s in self.step_log), default=0))

    def summary(self) -> Dict[str, float]:
        hits = [r for r in self.completed if r.pool_hit]
        cold = [r for r in self.completed if not r.pool_hit]
        out = {
            "completed": len(self.completed),
            "rejected": self.scheduler.admission.rejected,
            "max_in_flight": self.max_in_flight(),
            "pool_hits": len(hits),
            "pool_hit_rate": len(hits) / max(len(self.completed), 1),
        }
        if hits:
            out["mean_ttft_hit"] = float(np.mean([r.ttft for r in hits]))
        if cold:
            out["mean_ttft_cold"] = float(np.mean([r.ttft for r in cold]))
        out.update({f"store_{k}": v for k, v in self.store.summary().items()})
        return out
