"""Paper Fig. 8: (left) sampled-subset accuracy stabilises quickly;
(right) CR relative rankings are invariant across requests."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import CompressionPipeline, KVCache
from repro.core.quality import evaluate_quality, get_reference_model
from repro.core.strategy import BASELINES


def run(smoke: bool = False) -> None:
    ref = get_reference_model()

    # Obs 1: accuracy on growing sample sizes converges to the full value.
    t0 = time.perf_counter()
    cfg = BASELINES["kivi"]
    n_full = 5 if smoke else 10
    subsets = (2, 3, 4) if smoke else (2, 4, 6)
    full = np.mean(list(evaluate_quality(
        cfg, ref=ref, n_prompts=n_full, decode_tokens=12, seed=3).values()))
    errs = []
    for n in subsets:
        sub = np.mean(list(evaluate_quality(
            cfg, ref=ref, n_prompts=n, decode_tokens=12, seed=3).values()))
        errs.append(abs(sub - full))
    emit("fig8_sampled_acc", (time.perf_counter() - t0) * 1e6,
         f"full={full:.3f} " + " ".join(
             f"err_n{n}={e:.3f}" for n, e in zip(subsets, errs)))

    # Obs 2: CR rankings invariant across different request contents.
    t0 = time.perf_counter()
    cfgs = [BASELINES["kivi"], BASELINES["cachegen"], BASELINES["mixhq"]]
    rankings = []
    for seed in range(3 if smoke else 5):
        kv = KVCache.random(4, 2, 160, 32, seed=seed)
        crs = [CompressionPipeline(c).compress(kv).compression_ratio()
               for c in cfgs]
        rankings.append(tuple(np.argsort(crs).tolist()))
    stable = len(set(rankings)) == 1
    emit("fig8_cr_rank_stability", (time.perf_counter() - t0) * 1e6,
         f"stable={stable} rankings={rankings[0]}")


if __name__ == "__main__":
    run()
