"""Property tests for the production trace harness (ISSUE 6).

The determinism contract (DESIGN.md §11) is checked at the strongest
surface available: byte-identity of the canonical serialization (equal
SHA-1 digests).  Structural invariants (arrivals monotone, rids dense,
SLO classes/metrics registered, per-tenant conservation under merge) are
property-tested over randomized build inputs via the hypothesis shim in
``tests/_hypothesis_compat.py``.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.serving.kvstore import SLO_CLASSES
from repro.workloads import (
    ARCHETYPES,
    DEFAULT_GEOM,
    TenantSpec,
    Trace,
    build_trace,
    default_tenants,
    make_arrivals,
    scaled_trace,
    trace_requests,
    validate,
)
from repro.workloads.trace import SLO_METRICS

ARRIVAL_KINDS = ("poisson", "diurnal", "mmpp")


def _tenants(rate_scale=0.5):
    return default_tenants(rate_scale=rate_scale)


# ---------------------------------------------------------------------------
# Determinism: same seed => byte-identical trace
# ---------------------------------------------------------------------------
@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       duration=st.floats(min_value=2.0, max_value=30.0))
def test_same_seed_is_byte_identical(seed, duration):
    a = build_trace(_tenants(), duration=duration, seed=seed)
    b = build_trace(_tenants(), duration=duration, seed=seed)
    assert a.digest() == b.digest()
    assert a.to_jsonl() == b.to_jsonl()


def test_different_seeds_differ():
    a = build_trace(_tenants(), duration=20.0, seed=1)
    b = build_trace(_tenants(), duration=20.0, seed=2)
    assert a.digest() != b.digest()


def test_jsonl_round_trip_preserves_digest():
    tr = build_trace(_tenants(), duration=15.0, seed=7)
    back = Trace.from_jsonl(tr.to_jsonl())
    assert back.digest() == tr.digest()
    assert len(back) == len(tr)


# ---------------------------------------------------------------------------
# Structural invariants over randomized single-tenant streams
# ---------------------------------------------------------------------------
@settings(max_examples=20)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       scenario=st.sampled_from(sorted(ARCHETYPES)),
       arrival=st.sampled_from(ARRIVAL_KINDS),
       rate=st.floats(min_value=0.2, max_value=6.0),
       duration=st.floats(min_value=1.0, max_value=25.0))
def test_stream_invariants(seed, scenario, arrival, rate, duration):
    """Arrivals non-decreasing, rids dense, every SLO class and metric
    registered, lengths positive — the full ``validate`` contract — for
    every archetype under every arrival process."""
    tr = build_trace([TenantSpec("t0", scenario, rate, arrival)],
                     duration=duration, seed=seed)
    validate(tr)                      # raises on any violated invariant
    ts = [e.t for e in tr.events]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    for e in tr.events:
        assert e.slo_class in SLO_CLASSES
        assert e.slo_metric in SLO_METRICS


@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       kind=st.sampled_from(ARRIVAL_KINDS),
       rate=st.floats(min_value=0.5, max_value=20.0),
       duration=st.floats(min_value=1.0, max_value=40.0))
def test_arrival_processes_stay_in_window(seed, kind, rate, duration):
    rng = np.random.default_rng(seed)
    proc = make_arrivals(kind, rate)
    times = proc.times(duration, rng)
    assert proc.mean_rate() > 0
    assert np.all(np.diff(times) >= 0)
    if len(times):
        assert times[0] >= 0.0 and times[-1] < duration


# ---------------------------------------------------------------------------
# Superposition: merge conserves every tenant's events
# ---------------------------------------------------------------------------
@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       duration=st.floats(min_value=5.0, max_value=30.0))
def test_merge_conserves_per_tenant_counts(seed, duration):
    tenants = _tenants()
    merged = build_trace(tenants, duration=duration, seed=seed)
    validate(merged)
    counts = merged.counts_by_tenant()
    # Rebuild each tenant's stream standalone (same child rng indexing as
    # build_trace) and check the merge dropped/duplicated nothing.
    from repro.workloads.scenarios import build_tenant_trace
    total = 0
    for i, ten in enumerate(tenants):
        part, _ = build_tenant_trace(ten, duration, seed, stream=i)
        assert counts.get(ten.name, 0) == len(part), ten.name
        total += len(part)
    assert len(merged) == total


def test_merge_is_arrival_sorted_with_dense_rids():
    merged = build_trace(_tenants(), duration=20.0, seed=3)
    for i, e in enumerate(merged.events):
        assert e.rid == i
    ts = [e.t for e in merged.events]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# Simulator materialization
# ---------------------------------------------------------------------------
def test_to_requests_prefix_hits_and_payload_sizing():
    tr = build_trace(_tenants(2.0), duration=20.0, seed=11)
    reqs = trace_requests(tr)
    assert len(reqs) == len(tr)
    seen = set()
    n_hits = 0
    for e, r in zip(tr.events, reqs):
        assert r.rid == e.rid and r.arrival == e.t
        assert r.kv_bytes == pytest.approx(
            DEFAULT_GEOM.kv_bytes(e.ctx_tokens))
        # prefix_hit is set exactly on repeats of an already-seen group
        assert r.prefix_hit == (e.prefix_group in seen)
        seen.add(e.prefix_group)
        n_hits += r.prefix_hit
    assert n_hits > 0          # chat/classify sharing must show up


def test_scaled_trace_hits_target_size():
    for target in (500, 2000):
        tr = scaled_trace(target, seed=5)
        assert 0.5 * target <= len(tr) <= 2.0 * target, \
            (target, len(tr))
        validate(tr)
