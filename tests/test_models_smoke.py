"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + no NaNs; prefill/decode consistency vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import reduce_config, supported_shapes
from repro.distribution.optimizer import OptConfig, init_opt_state
from repro.distribution.steps import make_train_step
from repro.models import decode_step, forward, init_params, make_inputs, prefill


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduce_config(get_config(arch))
    params, axes = init_params(cfg, seed=0)
    inp = make_inputs(cfg, "train", seq=32, batch=2, abstract=False, seed=1)

    # forward (shifted inputs)
    b = dict(inp["batch"])
    b["tokens"] = b["tokens"][:, :-1]
    logits, aux = forward(cfg, params, b)
    exp_len = b["tokens"].shape[1] if cfg.family != "vlm" else 32
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"

    # one real optimizer step
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, oc, remat=False))
    params2, opt_state2, metrics = step(params, opt_state, inp["batch"])
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["loss"]) > 0
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[1]
    l1 = jax.tree_util.tree_leaves(params2)[1]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_available(arch):
    cfg = reduce_config(get_config(arch))
    params, _ = init_params(cfg, seed=0)
    pin = make_inputs(cfg, "prefill", seq=24, batch=2, abstract=False, seed=2)
    logits, caches = prefill(cfg, params, pin["batch"],
                             max_len=pin["max_len"] + 4)
    assert logits.shape == (2, 1, cfg.vocab_size)
    pos = jnp.asarray(pin["batch"]["tokens"].shape[1], jnp.int32)
    dlog, caches2 = decode_step(cfg, params, caches,
                                jnp.zeros((2, 1), jnp.int32), pos)
    assert dlog.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(dlog).all())


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-9b", "falcon-mamba-7b",
                                  "deepseek-moe-16b", "jamba-v0.1-52b"])
def test_decode_consistency_with_forward(arch):
    """Teacher-forced decode must reproduce the full-forward logits.

    MoE capacity dropping is sequence-length dependent (tokens compete for
    expert slots), so the consistency check runs with a no-drop capacity
    factor — the dropped-token divergence is expected MoE semantics, not a
    cache bug."""
    from dataclasses import replace
    cfg = reduce_config(get_config(arch))
    if cfg.moe:
        cfg = replace(cfg, capacity_factor=16.0)
    params, _ = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    T = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T + 1)),
                         jnp.int32)

    full_logits, _ = forward(cfg, params, {"tokens": tokens})
    _, caches = prefill(cfg, params, {"tokens": tokens[:, :T]}, max_len=T + 1)
    dlog, _ = decode_step(cfg, params, caches, tokens[:, T:T + 1],
                          jnp.asarray(T, jnp.int32))
    a = np.asarray(full_logits[:, T, :], np.float32)
    b = np.asarray(dlog[:, 0, :], np.float32)
    # identical math, bf16 accumulation differences only
    assert np.argmax(a, -1).tolist() == np.argmax(b, -1).tolist()
    np.testing.assert_allclose(a, b, atol=0.15, rtol=0.05)


def test_param_counts_sane():
    """Full-config param counts in the right ballpark (catches config typos)."""
    expect = {
        "qwen3-4b": (3e9, 7e9),
        "gemma2-9b": (8e9, 13e9),
        # note: assigned config prescribes llama-arch (gated GLU) at
        # d_ff=24576, which lands above the namesake's 20B
        "granite-20b": (15e9, 30e9),
        "minicpm-2b": (2e9, 3.5e9),
        "jamba-v0.1-52b": (40e9, 65e9),
        "whisper-small": (0.15e9, 0.45e9),
        "qwen2-vl-72b": (60e9, 85e9),
        "llama4-scout-17b-a16e": (85e9, 120e9),  # total (17B active)
        "deepseek-moe-16b": (14e9, 20e9),
        "falcon-mamba-7b": (6e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_less_than_total_for_moe():
    for arch in ("llama4-scout-17b-a16e", "deepseek-moe-16b",
                 "jamba-v0.1-52b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()


def test_supported_shapes():
    assert "long_500k" in supported_shapes(get_config("falcon-mamba-7b"))
    assert "long_500k" in supported_shapes(get_config("jamba-v0.1-52b"))
    assert "long_500k" not in supported_shapes(get_config("qwen3-4b"))
    for arch in ASSIGNED_ARCHS:
        assert "train_4k" in supported_shapes(get_config(arch))


def test_scan_period_detection():
    assert get_config("qwen3-4b").scan_period() == 1
    assert get_config("gemma2-9b").scan_period() == 2
    assert get_config("jamba-v0.1-52b").scan_period() == 8
    from repro.models.transformer import plan_stack
    plan = plan_stack(get_config("deepseek-moe-16b"))
    assert len(plan.prefix_specs) == 1 and not plan.prefix_specs[0].moe
    assert plan.n_blocks == 27
