"""Real-execution disaggregated serving (CPU, tiny reference model).

Two granularities remain in this module; the heavy lifting moved into the
worker/cluster layers (ISSUE 5):

* :class:`DisaggregatedEngine` — the one-shot PD path: ``serve`` runs a
  single synchronous batch end-to-end (prefill -> compress -> wire ->
  decompress -> decode) and reports a :class:`ServedBatch` breakdown.  It
  is a thin wrapper over the same stage helpers
  (:func:`~repro.serving.workers.compress_kvs`,
  :func:`~repro.serving.workers.decompress_kvs`,
  :class:`~repro.serving.network.KVWire`) the continuous runtime
  pipelines per request.

* :class:`ServingRuntime` — the continuous-batching, multi-tenant runtime
  (DESIGN.md §9): since ISSUE 5 this is the **1x1 facade** over
  :class:`~repro.serving.cluster.ClusterRuntime` — one
  :class:`~repro.serving.workers.PrefillWorker`, one
  :class:`~repro.serving.workers.DecodeWorker`, one
  (p0 -> d0) link — preserving the original single-engine API
  (``submit`` / ``step`` / ``run`` / ``summary``, ``.wire``, ``.store``,
  ``.estimator``) byte-for-byte: the pinned PR-1 token fixture holds in
  both ``pool`` and ``pd`` modes.  Scale-out (N x M workers, per-link
  topology, load-aware routing) lives in ``repro.serving.cluster``
  (DESIGN.md §10).

Both serving scenarios (``RuntimeConfig.mode``):

  - ``"pool"`` (KV-disaggregated prefix caching, the paper's TTFT path):
    the prefix pool is a :class:`~repro.serving.kvstore.TieredKVStore`
    memory hierarchy (HBM -> DRAM -> remote by default); hits fetch real
    compressed bytes over the holding tier's serialized link and promote
    on access, misses prefill locally and write the compressed prefix
    back off the critical path.
  - ``"pd"`` (PD separation, the paper's JCT path): every cold request's
    prefix KV crosses the network — prefill -> controller-selected
    compress -> serialized :class:`~repro.serving.network.KVWire`
    transfer -> decompress -> decode arena — ON the critical path, and
    the transferred bytes seed the decode-side prefix pool.

Every byte on the "wire" is real pipeline output.  Compute time is either
measured wall-clock or (for deterministic benchmarks) modelled from
``prefill_tok_s`` / ``decode_tok_s`` (codec stages then follow the
profile's measured throughputs, ``V/s_enc`` + ``V/s_dec``, per Eq. 1);
communication time always comes from the
:class:`~repro.serving.network.BandwidthTrace`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.controller import ServiceAwareController, ServiceContext
from repro.core.profiles import Profile
from repro.core.quality import (
    _greedy_decode,
    _jitted_steps,
    _prompts_for,
    extract_kv,
    get_reference_model,
    inject_kv,
)
from repro.core.strategy import is_identity
from repro.data.tokenizer import ByteTokenizer
from repro.serving.cluster import ClusterRuntime
from repro.serving.network import BandwidthTrace, GoodputEstimator, KVWire
from repro.serving.scheduler import SchedulerConfig

# Re-exported for backward compatibility: these lived here before the
# worker split (ISSUE 5); their home is now repro.serving.workers.
from repro.serving.workers import (  # noqa: F401
    RuntimeConfig,
    ServedRequest,
    Slot,
    _select_profile,
    compress_kvs,
    decompress_kvs,
    recompress_entry,
)


@dataclass
class ServedBatch:
    workload: str
    text: List[str]
    tokens: np.ndarray
    profile: str
    kv_bytes: int
    wire_bytes: int
    t_prefill: float
    t_compress: float
    t_comm: float
    t_decompress: float
    t_decode: float
    agreement: float  # vs uncompressed decode

    @property
    def jct(self) -> float:
        return (self.t_prefill + self.t_compress + self.t_comm
                + self.t_decompress + self.t_decode)


class DisaggregatedEngine:
    """One-shot PD-separated serving of the tiny reference model: a thin
    synchronous wrapper over the shared stage helpers (the continuous
    :class:`ServingRuntime` pipelines the same stages per request)."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 seq: int = 192, decode_tokens: int = 20, batch: int = 4):
        self.cfg, self.params = get_reference_model()
        self.controller = controller
        self.static_profile = static_profile
        self.seq = seq
        self.decode_tokens = decode_tokens
        self.batch = batch
        self.estimator = GoodputEstimator()
        self._pre, self._dec, _ = _jitted_steps(
            self.cfg.name, seq, batch, seq + decode_tokens + 2)
        self.tok = ByteTokenizer()

    # ------------------------------------------------------------------
    def serve(self, workload: str, trace: BandwidthTrace, now: float = 0.0,
              t_slo: float = 0.0, q_min: float = 0.97, seed: int = 0
              ) -> ServedBatch:
        tokens, _ = _prompts_for(workload, self.batch, self.seq, seed)
        # Build the wire up front: attaching the (unseeded) estimator
        # seeds its initial from the link's configured trace, so the
        # controller decision below starts from THIS wire's bandwidth,
        # not a universal 10 Gb/s guess.
        wire = KVWire(trace, self.estimator)

        # ---- prefill worker ----
        t0 = time.perf_counter()
        logits, caches = self._pre(self.params, {"tokens": tokens})
        # lint: sync-ok(one-shot engine times real prefill wall-clock here)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        first = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

        # reference decode for agreement scoring
        ref_toks = _greedy_decode(self._dec, self.params, caches, first,
                                  self.seq, self.decode_tokens)

        # ---- controller decision ----
        kvs = [extract_kv(self.cfg, caches, b, upto=self.seq)
               for b in range(self.batch)]
        v_bytes = sum(kv.nbytes_wire() for kv in kvs)
        ctx = ServiceContext(workload=workload,
                             bandwidth=self.estimator.estimate,
                             t_slo=t_slo, q_min=q_min, t_model=t_prefill,
                             kv_bytes=v_bytes, slo_metric="jct")
        profile, decision = _select_profile(self.controller,
                                            self.static_profile, ctx)

        # ---- compress -> wire -> decompress (shared PD stages) ----
        comps, wire_bytes, t_compress = compress_kvs(profile.strategy, kvs)
        t_comm = wire.send(now + t_prefill + t_compress, wire_bytes).t_comm
        restored, t_decompress = decompress_kvs(comps)

        # ---- decode worker ----
        comp_caches = caches
        if not is_identity(profile.strategy):
            for b in range(self.batch):
                comp_caches = inject_kv(self.cfg, comp_caches, b, restored[b])
        t0 = time.perf_counter()
        test_toks = _greedy_decode(self._dec, self.params, comp_caches, first,
                                   self.seq, self.decode_tokens)
        t_decode = time.perf_counter() - t0

        agreement = float((ref_toks == test_toks).mean())
        # One-shot PD: compress/comm/decompress ARE the critical path.
        observed = t_compress + t_comm + t_decompress + ctx.t_model
        if self.controller is not None and decision is not None:
            self.controller.observe(ctx, decision, observed)

        texts = [self.tok.decode(row[1:]) for row in test_toks]
        return ServedBatch(
            workload=workload, text=texts, tokens=test_toks,
            profile=profile.strategy.short_name(), kv_bytes=int(v_bytes),
            wire_bytes=int(wire_bytes), t_prefill=t_prefill,
            t_compress=t_compress, t_comm=t_comm,
            t_decompress=t_decompress, t_decode=t_decode,
            agreement=agreement)


# ===========================================================================
# Continuous-batching runtime: the 1x1 cluster facade
# ===========================================================================
class ServingRuntime(ClusterRuntime):
    """Iteration-level (continuous-batching) serving of the tiny reference
    model — the single-engine deployment: a :class:`ClusterRuntime` of
    exactly one prefill worker, one decode arena, and one (p0 -> d0)
    link, with the original single-engine attribute surface
    (``.wire``, ``.store``, ``.estimator``, ``.n_slots``)."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 config: Optional[RuntimeConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 store: Optional[Any] = None,
                 trace: Optional[BandwidthTrace] = None):
        super().__init__(controller=controller,
                         static_profile=static_profile,
                         config=config, scheduler=scheduler, store=store,
                         trace=trace, n_prefill=1, n_decode=1)
