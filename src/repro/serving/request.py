"""Request / session model for the disaggregated serving runtime."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.data.synthetic import WORKLOADS

# Explicit request lifecycle (PD-disaggregated continuous runtime): the
# scheduler moves a request waiting -> prefilling; the engine advances it
# prefilling -> transferring (its compressed KV is on the wire) ->
# decoding -> done.  Pool hits skip prefilling (the pool fetch IS their
# transfer).  "rejected" is terminal for load-shed requests.
LIFECYCLE = ("waiting", "prefilling", "transferring", "decoding", "done",
             "rejected")


@dataclass(slots=True)
class Request:
    rid: int
    workload: str            # router-provided label w (Sec. 2.2)
    arrival: float           # seconds
    ctx_tokens: int          # prompt length
    out_tokens: int          # decode length
    kv_bytes: float          # uncompressed KV payload V
    t_slo: float = 0.0       # 0 = no SLO
    # Which latency the SLO (and the controller's guardrail feedback)
    # targets: "ttft" | "jct".  None = the serving scenario's default
    # (pool/prefix-caching -> ttft, PD separation -> jct), resolved by
    # whichever backend executes the request.
    slo_metric: Optional[str] = None
    q_min: float = 0.97
    prefix_hit: bool = False  # pool scenario: reusable KV exists remotely
    # Scheduler priority class: interactive | standard | batch
    # (see repro.serving.kvstore.SLO_CLASSES).
    slo_class: str = "standard"
    # Token prefix identifying reusable KV in a PrefixKVStore; when set, the
    # store (not the prefix_hit flag) decides pool hits.
    prefix_key: Optional[Tuple[int, ...]] = None

    # Arena slot id while in flight (assigned by ContinuousScheduler on
    # admission to a running slot, released on finish; None while waiting
    # and in the event-driven simulator, which has no physical slots).
    slot: Optional[int] = None
    # Lifecycle state (see LIFECYCLE); maintained by the scheduler and the
    # continuous runtime, observational for the event-driven simulator.
    state: str = "waiting"

    def resolved_slo_metric(self, scenario_default: str = "jct") -> str:
        return self.slo_metric if self.slo_metric is not None \
            else scenario_default

    # ---- outcome fields (filled by the simulator) ----
    done: float = 0.0
    ttft: float = 0.0
    breakdown: Dict[str, float] = field(default_factory=dict)
    chosen: str = ""
    # Placement route ("p0->d1") when a NetworkTopology routed the request
    # (multi-worker cluster / topology-driven simulator); "" otherwise.
    route: str = ""
    slo_violated: bool = False
    retries: int = 0

    @property
    def jct(self) -> float:
        return self.done - self.arrival


def kv_bytes_for(ctx_tokens: int, num_layers: int, kv_heads: int,
                 head_dim: int, bytes_per_el: int = 2) -> float:
    return 2.0 * num_layers * kv_heads * head_dim * ctx_tokens * bytes_per_el


@dataclass
class WorkloadMix:
    """Poisson arrivals over a workload mix."""

    rate: float = 4.0                      # requests/s
    mix: Optional[Dict[str, float]] = None
    ctx_scale: float = 1.0
    seed: int = 0
    model_layers: int = 32
    model_kv_heads: int = 8
    model_head_dim: int = 128
    slo: float = 0.0
    q_min: float = 0.97
    prefix_hit_rate: float = 0.0
    # Share of each SLO class, e.g. {"interactive": 0.3, "batch": 0.7}.
    slo_class_mix: Optional[Dict[str, float]] = None

    def generate(self, n: int):
        rng = np.random.default_rng(self.seed)
        # The new draws (prefix-pool reuse, SLO class) come from a second
        # generator so the primary stream — and therefore every previously
        # seeded workload (arrivals, ctx/out lengths, prefix_hit flags) —
        # is byte-identical to what it produced before these fields existed.
        rng_aux = np.random.default_rng((self.seed, 0x9E3779B9))
        mix = self.mix or {w: 1.0 for w in WORKLOADS}
        names = list(mix)
        probs = np.asarray([mix[w] for w in names], dtype=float)
        probs /= probs.sum()
        classes, class_probs = ["standard"], np.asarray([1.0])
        if self.slo_class_mix:
            classes = list(self.slo_class_mix)
            class_probs = np.asarray([self.slo_class_mix[c] for c in classes],
                                     dtype=float)
            class_probs /= class_probs.sum()
        # Per-workload pool of previously issued prefixes: with probability
        # prefix_hit_rate a request re-uses one (so a PrefixKVStore sees a
        # genuine share-able prefix population; the first user of a prefix
        # still pays the cold miss).
        prefix_pools: Dict[str, list] = {w: [] for w in names}
        t = 0.0
        out = []
        for i in range(n):
            t += rng.exponential(1.0 / self.rate)
            w = names[int(rng.choice(len(names), p=probs))]
            spec = WORKLOADS[w]
            ctx = int(max(64, rng.lognormal(
                np.log(spec.ctx_scale * self.ctx_scale * 16), 0.4)))
            gen = int(max(4, rng.poisson(spec.out_scale * 4)))
            pool = prefix_pools[w]
            if pool and rng_aux.random() < self.prefix_hit_rate:
                key = pool[int(rng_aux.integers(len(pool)))]
            else:
                key = (i,)
                pool.append(key)
            out.append(Request(
                rid=i, workload=w, arrival=t, ctx_tokens=ctx, out_tokens=gen,
                kv_bytes=kv_bytes_for(ctx, self.model_layers,
                                      self.model_kv_heads, self.model_head_dim),
                t_slo=self.slo, q_min=self.q_min,
                prefix_hit=bool(rng.random() < self.prefix_hit_rate),
                slo_class=classes[int(rng_aux.choice(len(classes),
                                                     p=class_probs))],
                prefix_key=key,
            ))
        return out
