"""Synthetic workload families with genuinely different sequence statistics.

The paper's Motivation 1 rests on workload-dependent KV statistics: math
(GSM8K), code (HumanEval), summarization (Multi-News), QA (Qasper) have
different request distributions, so the same compression strategy yields
different accuracy/CR per workload.  We reproduce that with four byte-level
generators whose entropy, repetition structure, and long-range dependency
patterns differ:

  - ``mathlike``:  arithmetic chains ("37+25=62;62-18=44;...") — short-range
    exact dependencies, digit-heavy alphabet (high local precision demand).
  - ``codelike``:  keyword/indentation templates — low entropy, heavy
    repetition (compresses well; tolerant to aggressive quantization).
  - ``qalike``:    needle retrieval — "k07=v83. ... Q:k07? A:v83" — long-range
    exact retrieval (sensitive to KV noise in retrieval heads).
  - ``summlike``:  noisy repeated sentences; answer = lead sentence — long
    context, redundant (high compressibility, moderate sensitivity).

Each generator returns (prompt, answer): quality for a compression strategy is
measured as decode agreement / answer accuracy with compressed vs raw KV.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import numpy as np

Rng = np.random.Generator


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    gen: Callable[[Rng, int], Tuple[str, str]]
    # Typical context length scale (bytes) for the serving simulator.
    ctx_scale: int
    # Typical output length scale (tokens).
    out_scale: int


def _gen_mathlike(rng: Rng, approx_len: int) -> Tuple[str, str]:
    parts = []
    val = int(rng.integers(10, 99))
    total = 0
    while total < approx_len - 12:
        delta = int(rng.integers(1, 49))
        op = "+" if rng.random() < 0.5 else "-"
        nxt = val + delta if op == "+" else max(val - delta, 1)
        seg = f"{val}{op}{delta}={nxt};"
        parts.append(seg)
        total += len(seg)
        val = nxt
    delta = int(rng.integers(1, 49))
    ans = val + delta
    prompt = "".join(parts) + f"{val}+{delta}="
    return prompt, f"{ans};"


_KEYWORDS = ["def ", "for ", "if ", "ret ", "let ", "fn "]
_NAMES = ["foo", "bar", "baz", "qux", "acc", "tmp", "idx", "val"]


def _gen_codelike(rng: Rng, approx_len: int) -> Tuple[str, str]:
    lines = []
    total = 0
    while total < approx_len - 24:
        kw = _KEYWORDS[int(rng.integers(0, len(_KEYWORDS)))]
        a = _NAMES[int(rng.integers(0, len(_NAMES)))]
        b = _NAMES[int(rng.integers(0, len(_NAMES)))]
        indent = "  " * int(rng.integers(0, 3))
        line = f"{indent}{kw}{a}({b}):\n"
        lines.append(line)
        total += len(line)
    # The answer continues the dominant pattern: a close-paren + return line.
    prompt = "".join(lines) + "  ret "
    ans = _NAMES[int(rng.integers(0, len(_NAMES)))]
    return prompt, f"{ans}\n"


def _gen_qalike(rng: Rng, approx_len: int) -> Tuple[str, str]:
    n_facts = max(2, (approx_len - 16) // 10)
    keys = rng.permutation(100)[: min(n_facts, 100)]
    facts = []
    values = {}
    for k in keys:
        v = int(rng.integers(10, 99))
        values[int(k)] = v
        facts.append(f"k{int(k):02d}=v{v}.")
    needle = int(keys[int(rng.integers(0, len(keys)))])
    prompt = "".join(facts) + f"Q:k{needle:02d}?A:"
    return prompt, f"v{values[needle]}."


_SENTS = [
    "the quick brown fox jumps over the lazy dog",
    "rain falls softly on the quiet harbor town",
    "markets rallied as rates held steady today",
    "the committee approved the final budget plan",
]


def _gen_summlike(rng: Rng, approx_len: int) -> Tuple[str, str]:
    lead = _SENTS[int(rng.integers(0, len(_SENTS)))]
    body = [lead + ". "]
    total = len(body[0])
    while total < approx_len - len(lead) - 16:
        s = _SENTS[int(rng.integers(0, len(_SENTS)))]
        # Noisy repetition: occasionally perturb a word.
        if rng.random() < 0.2:
            s = s.replace(" the ", " a ", 1)
        body.append(s + ". ")
        total += len(s) + 2
    prompt = "".join(body) + "TLDR: "
    return prompt, lead[:24]


WORKLOADS: Dict[str, WorkloadSpec] = {
    "mathlike": WorkloadSpec("mathlike", _gen_mathlike, ctx_scale=512, out_scale=8),
    "codelike": WorkloadSpec("codelike", _gen_codelike, ctx_scale=768, out_scale=8),
    "qalike": WorkloadSpec("qalike", _gen_qalike, ctx_scale=1024, out_scale=6),
    "summlike": WorkloadSpec("summlike", _gen_summlike, ctx_scale=1280, out_scale=16),
}


def make_prompt(workload: str, rng: Rng, approx_len: int = 0) -> Tuple[str, str]:
    spec = WORKLOADS[workload]
    return spec.gen(rng, approx_len or spec.ctx_scale)


def make_batch(
    workload: str,
    batch: int,
    seq_len: int,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens, loss_mask) for LM training on a workload mix.

    ``workload`` may be a name or "mixed".
    """
    from repro.data.tokenizer import ByteTokenizer

    tok = ByteTokenizer()
    rng = np.random.default_rng(seed)
    names = list(WORKLOADS) if workload == "mixed" else [workload]
    rows, masks = [], []
    for i in range(batch):
        name = names[int(rng.integers(0, len(names)))]
        prompt, ans = make_prompt(name, rng, approx_len=seq_len)
        ids = tok.encode(prompt + ans)
        row = tok.pad_to(ids, seq_len + 1)
        mask = (row != tok.pad_id).astype(np.float32)
        rows.append(row)
        masks.append(mask)
    return np.stack(rows), np.stack(masks)
