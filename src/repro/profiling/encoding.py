"""Heterogeneous-parameter encoding (Alg. 1 line 1).

Categorical strategy fields -> one-hot; numeric fields -> min-max scaled.
The resulting unified embedding lets the GP kernel measure structural
similarity across mixed parameter types.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.strategy import (
    BITS_CHOICES,
    CODECS,
    GRANULARITIES,
    GROUP_CHOICES,
    QUANTIZERS,
    TRANSFORMS,
    StrategyConfig,
)

_CATEGORICAL: List[Tuple[str, Sequence[str]]] = [
    ("transform", TRANSFORMS),
    ("quantizer", QUANTIZERS),
    ("granularity", GRANULARITIES),
    ("codec", CODECS),
]

_NUMERIC: List[Tuple[str, float, float]] = [
    ("key_bits", 1, 16),
    ("value_bits", 1, 16),
    ("group_size", min(GROUP_CHOICES), max(GROUP_CHOICES)),
    ("mixhq_high_bits", 1, 8),
    ("mixhq_low_bits", 1, 8),
    ("retrieval_frac", 0.0, 1.0),
    ("token_heavy_hitter_frac", 0.0, 1.0),
    ("delta_group", 8, 128),
    ("duo_recent", 16, 512),
]

_BOOL = ["layer_pyramid", "symmetric"]


def embedding_dim() -> int:
    return sum(len(v) for _, v in _CATEGORICAL) + len(_NUMERIC) + len(_BOOL) + 3


def encode(cfg: StrategyConfig) -> np.ndarray:
    parts: List[float] = []
    for field, vocab in _CATEGORICAL:
        val = getattr(cfg, field)
        onehot = [1.0 if val == v else 0.0 for v in vocab]
        parts.extend(onehot)
    for field, lo, hi in _NUMERIC:
        val = float(getattr(cfg, field))
        parts.append((val - lo) / (hi - lo))
    for field in _BOOL:
        parts.append(1.0 if getattr(cfg, field) else 0.0)
    # tier bits (cachegen) as scaled numerics
    for i in range(3):
        parts.append(cfg.tier_bits[i] / 8.0)
    return np.asarray(parts, dtype=np.float64)


def encode_batch(cfgs: Sequence[StrategyConfig]) -> np.ndarray:
    return np.stack([encode(c) for c in cfgs])
