"""Prefix-KV pool store: capacity invariant, eviction policy, prefix hits."""
import numpy as np
import pytest

from repro.serving.kvstore import SLO_CLASSES, PrefixKVStore, slo_rank


def _toks(i, n=32):
    return tuple(range(i * 1000, i * 1000 + n))


def test_capacity_invariant_under_random_churn():
    rng = np.random.default_rng(0)
    store = PrefixKVStore(capacity_bytes=10_000, block=8)
    classes = list(SLO_CLASSES)
    for i in range(300):
        size = int(rng.integers(100, 3000))
        store.put(_toks(int(rng.integers(50))), payload=i, wire_bytes=size,
                  slo_class=classes[int(rng.integers(3))], now=float(i))
        assert store.used_bytes <= store.capacity_bytes
        assert store.used_bytes == sum(e.wire_bytes for e in store.entries())
    assert store.stats.evictions > 0


def test_oversized_payload_rejected_without_eviction():
    store = PrefixKVStore(capacity_bytes=1000)
    store.put(_toks(0), "a", 800, now=0.0)
    evicted = store.put(_toks(1), "big", 5000, now=1.0)
    assert evicted == [] and store.stats.rejected_puts == 1
    assert store.used_bytes == 800 and len(store) == 1  # untouched


def test_slo_aware_lru_eviction_order():
    """batch evicted before standard before interactive; LRU within class."""
    store = PrefixKVStore(capacity_bytes=1000)
    store.put(_toks(0), "i", 250, slo_class="interactive", now=0.0)
    store.put(_toks(1), "b_old", 250, slo_class="batch", now=1.0)
    store.put(_toks(2), "b_new", 250, slo_class="batch", now=2.0)
    store.put(_toks(3), "s", 250, slo_class="standard", now=3.0)
    # needs 500 bytes -> evicts the two batch entries, LRU first
    evicted = store.put(_toks(4), "x", 500, slo_class="standard", now=4.0)
    assert [e.payload for e in evicted] == ["b_old", "b_new"]
    assert store.contains(_toks(0), now=4.0) and store.contains(_toks(3),
                                                               now=4.0)


def test_lru_recency_updated_by_lookup():
    store = PrefixKVStore(capacity_bytes=500)
    store.put(_toks(0), "a", 200, now=0.0)
    store.put(_toks(1), "b", 200, now=1.0)
    store.lookup(_toks(0), now=5.0)  # refresh "a"
    evicted = store.put(_toks(2), "c", 300, now=6.0)
    assert [e.payload for e in evicted] == ["b"]


def test_prefix_matching_block_aligned():
    store = PrefixKVStore(capacity_bytes=10_000, block=16)
    base = tuple(range(32))
    store.put(base, "kv32", 100, now=0.0)
    # a longer prompt sharing the stored 32-token prefix hits it
    hit = store.lookup(base + tuple(range(100, 148)), now=1.0)
    assert hit is not None and hit.payload == "kv32"
    # an unrelated prompt misses
    assert store.lookup(tuple(range(500, 548)), now=2.0) is None
    # longest stored prefix wins
    store.put(base + tuple(range(100, 116)), "kv48", 100, now=3.0)
    hit = store.lookup(base + tuple(range(100, 148)), now=4.0)
    assert hit.payload == "kv48"
    assert store.stats.hits == 2 and store.stats.misses == 1


def test_compressed_kv_roundtrips_bit_exact_through_store():
    """A pool hit must hand back byte-identical KV: compress -> store ->
    lookup -> decompress reproduces the (fp16-representable) cache exactly."""
    from repro.core.kvcache import KVCache
    from repro.core.pipeline import CompressionPipeline
    from repro.core.strategy import IDENTITY_STRATEGY

    kv = KVCache.random(num_layers=2, kv_heads=2, seq=64, head_dim=32, seed=3)
    kv = KVCache(kv.k.astype(np.float16).astype(np.float32),
                 kv.v.astype(np.float16).astype(np.float32))
    pipe = CompressionPipeline(IDENTITY_STRATEGY)
    comp = pipe.compress(kv)

    store = PrefixKVStore(capacity_bytes=comp.total_bytes() + 1000, block=16)
    store.put(tuple(range(64)), comp, comp.total_bytes(), now=0.0)
    entry = store.lookup(tuple(range(64)) + (99,), now=1.0)
    assert entry is not None
    restored = CompressionPipeline(entry.payload.strategy).decompress(
        entry.payload)
    np.testing.assert_array_equal(restored.k, kv.k)
    np.testing.assert_array_equal(restored.v, kv.v)


def test_full_lookup_requires_exact_coverage():
    """full=True consumers (the runtime) can't top-up a partial prefix, so
    an entry covering only part of the prompt must not count as a hit —
    but a usable block-aligned partial prefix is a *partial* miss, not a
    cold one."""
    store = PrefixKVStore(capacity_bytes=10_000, block=16)
    base = tuple(range(32))
    store.put(base, "kv32", 100, now=0.0)
    assert store.lookup(base + tuple(range(100, 116)), now=1.0,
                        full=True) is None
    assert store.lookup(base, now=2.0, full=True).payload == "kv32"
    assert store.stats.partial_misses == 1 and store.stats.misses == 0
    assert store.stats.hits == 1
    # unrelated prompt: a true cold miss, not a partial one
    assert store.lookup(tuple(range(500, 532)), now=3.0, full=True) is None
    assert store.stats.misses == 1 and store.stats.partial_misses == 1
    assert store.stats.hit_rate == pytest.approx(1 / 3)


def test_partial_miss_requires_visible_partial_entry():
    """A partial prefix still in flight (created > now) must not turn a
    cold miss into a partial one."""
    store = PrefixKVStore(capacity_bytes=10_000, block=16)
    base = tuple(range(32))
    store.put(base, "kv32", 100, now=5.0)   # write completes at t=5
    assert store.lookup(base + tuple(range(100, 116)), now=1.0,
                        full=True) is None
    assert store.stats.misses == 1 and store.stats.partial_misses == 0


def test_contains_respects_write_visibility():
    """Regression: contains() used to ignore the created <= now rule that
    lookup enforces, so callers could see time-traveling entries."""
    store = PrefixKVStore(capacity_bytes=10_000, block=16)
    store.put(_toks(0), "a", 100, now=2.5)  # pool write completes at t=2.5
    assert not store.contains(_toks(0))            # default now=0.0
    assert not store.contains(_toks(0), now=2.0)   # still in flight
    assert store.contains(_toks(0), now=2.5)
    assert store.contains(_toks(0), now=9.0)
    assert not store.contains(_toks(1), now=9.0)
    # presence probes leave recency and hit/miss counters untouched
    assert store.stats.hits == 0 and store.stats.misses == 0


def test_slo_rank_mapping():
    assert slo_rank("interactive") < slo_rank("standard") < slo_rank("batch")
    assert slo_rank("unknown-class") == slo_rank("standard")


def test_put_never_evicts_more_critical_slo_class():
    """Bugfix (ISSUE 4): a batch-class put used to evict interactive
    entries.  An insert must never evict an entry of strictly more
    critical SLO rank — it is rejected (counted) instead, with nothing
    partially evicted."""
    store = PrefixKVStore(capacity_bytes=1000)
    store.put(_toks(0), "i", 600, slo_class="interactive", now=0.0)
    store.put(_toks(1), "b", 300, slo_class="batch", now=1.0)
    # batch put needing room: may evict the batch entry, NEVER interactive
    evicted = store.put(_toks(2), "b2", 500, slo_class="batch", now=2.0)
    assert evicted == [] and store.stats.rejected_puts == 1
    assert store.contains(_toks(0), now=2.0)   # interactive survived
    assert store.contains(_toks(1), now=2.0)   # nothing partially evicted
    assert store.used_bytes == 900
    # standard put CAN evict batch (equal-or-lower priority only)
    evicted = store.put(_toks(3), "s", 400, slo_class="standard", now=3.0)
    assert [e.payload for e in evicted] == ["b"]
    assert store.contains(_toks(0), now=3.0)
    # interactive put can evict anything less critical
    evicted = store.put(_toks(4), "i2", 400, slo_class="interactive", now=4.0)
    assert [e.payload for e in evicted] == ["s"]


def test_refresh_rolls_back_when_protected():
    """A same-key refresh that cannot make room without an SLO inversion
    must leave the original entry in place."""
    store = PrefixKVStore(capacity_bytes=1000)
    store.put(_toks(0), "i", 700, slo_class="interactive", now=0.0)
    store.put(_toks(1), "b_v1", 300, slo_class="batch", now=1.0)
    # refreshing the batch entry with a bigger payload would need to evict
    # the interactive entry -> rejected, v1 still stored and accounted
    evicted = store.put(_toks(1), "b_v2", 600, slo_class="batch", now=2.0)
    assert evicted == [] and store.stats.rejected_puts == 1
    entry = store.lookup(_toks(1), now=3.0)
    assert entry is not None and entry.payload == "b_v1"
    assert store.used_bytes == 1000
