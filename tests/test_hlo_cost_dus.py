"""Cost-model regressions for in-place update accounting (the §Perf
hillclimb-1 fix): scan ys accumulation must NOT be charged full-buffer
traffic per iteration."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo_text


def test_scan_ys_not_charged_full_buffer():
    """A scan emitting (D,)-slices into an (N, D) output should cost O(N*D)
    bytes total, not O(N^2 * D)."""
    n, d = 256, 512

    def f(x):
        def body(c, _):
            c = jnp.tanh(c)
            return c, c  # ys slice (d,)
        _, ys = jax.lax.scan(body, x, None, length=n)
        return ys

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((d,), jnp.float32)).compile()
    c = analyze_hlo_text(comp.as_text())
    linear = n * d * 4
    assert c.bytes < 20 * linear, (c.bytes, linear)  # O(N*D), not O(N^2*D)


def test_standalone_dus_charged_update_size():
    big, upd = 1 << 20, 128

    def f(buf, u, i):
        return jax.lax.dynamic_update_slice(buf, u, (i,))

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((big,), jnp.float32),
        jax.ShapeDtypeStruct((upd,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32)).compile()
    c = analyze_hlo_text(comp.as_text())
    # XLA inserts ONE real full-buffer copy (entry param not donated);
    # the DUS itself must only add update-region traffic on top — so the
    # total sits near 2x buffer (copy r+w), nowhere near 4x (copy + full
    # DUS charge).
    assert c.bytes < big * 4 * 2.5, c.bytes
    assert c.bytes > big * 4 * 1.5  # the genuine copy IS counted


def test_dynamic_slice_charged_slice_size():
    big, sl = 1 << 20, 256

    def f(buf, i):
        return jax.lax.dynamic_slice(buf, (i,), (sl,)) * 2.0

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((big,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32)).compile()
    c = analyze_hlo_text(comp.as_text())
    assert c.bytes < big, c.bytes
