"""The Service-Aware Online Controller (Sec. 6) — ties together:

  1. quality bucketing (restrict to profiles meeting the request's q_min),
  2. Theorem 6.1 benefit filter (drop non-beneficial profiles at current B),
  3. Theorem 6.2 lower-envelope O(1) lookup + neighbour candidate set,
  4. the residual-corrected ε-greedy bandit with SLO guardrails.

``select`` is the <1 ms control-plane decision made at each KV-movement
boundary; ``observe`` feeds runtime JCT back for residual correction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.controller.bandit import BanditConfig, ResidualBandit
from repro.controller.envelope import LowerEnvelope, build_envelope
from repro.controller.latency_model import (
    ServiceContext,
    TierFetch,
    bandwidth_threshold,
    baseline_latency,
    is_beneficial,
    predicted_latency,
    speculative_decode_latency,
    tier_fetch_latency,
)

# Quality buckets by *relative accuracy loss* (Sec. 6.1: "bucket profiles by
# accuracy loss and restrict selection to the matching bucket").  A request
# with budget q_min maps to the coarsest bucket whose floor still covers it.
DEFAULT_BUCKETS: Tuple[float, ...] = (0.99, 0.97, 0.95, 0.90, 0.80, 0.70,
                                      0.50, 0.0)


@dataclass
class Decision:
    profile: Profile
    interval: int
    bucket: int
    predicted: float
    candidates: List[Profile] = field(default_factory=list)
    # Speculation length for the request's decode (DESIGN.md §15): the
    # draft budget k minimizing the modelled decode-stream time at the
    # (workload, route) accept-rate estimate.  0 = plain decode; the
    # runtime caps it at its own cfg.spec_k.
    spec_k: int = 0


@dataclass
class FetchDecision:
    """Outcome of :meth:`ServiceAwareController.select_fetch`."""

    option: TierFetch
    predicted: float
    candidates: List[TierFetch] = field(default_factory=list)


class ServiceAwareController:
    def __init__(
        self,
        profiles_by_workload: Dict[str, Sequence[Profile]],
        buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
        bandit_config: BanditConfig = BanditConfig(),
        use_bandit: bool = True,
        use_envelope: bool = True,
        spec_candidates: Sequence[int] = (0,),
        spec_accept_prior: float = 0.5,
        spec_accept_alpha: float = 0.2,
    ):
        self.buckets = buckets
        self.use_bandit = use_bandit
        self.use_envelope = use_envelope
        self._bandit_config = bandit_config
        # Adaptive speculation length (DESIGN.md §15).  The candidate set
        # defaults to (0,) — zero behavioural change for existing
        # deployments; a runtime enabling spec_adaptive passes e.g.
        # (0, 2, 4).  Accept rates are tracked per (workload, route) as an
        # EWMA residual around the optimistic prior: routes drift
        # independently (different hardware mixes repeat differently),
        # exactly like the latency bandits above.
        self.spec_candidates = tuple(spec_candidates)
        self._spec_prior = spec_accept_prior
        self._spec_alpha = spec_accept_alpha
        self._accept_rates: Dict[Tuple[str, str], float] = {}
        # Per (workload, bucket): lower envelope built offline.  Envelopes
        # are route-independent (profiles are an offline property); bandit
        # state is NOT — see _bandit_for.
        self._envelopes: Dict[Tuple[str, int], LowerEnvelope] = {}
        self._bandits: Dict[Tuple[str, int, str], ResidualBandit] = {}
        self._profiles = profiles_by_workload
        for w, profs in profiles_by_workload.items():
            for bi, q_floor in enumerate(buckets):
                eligible = [p for p in profs if p.q(w) >= q_floor]
                self._envelopes[(w, bi)] = build_envelope(eligible)
                self._bandits[(w, bi, "")] = ResidualBandit(bandit_config)

    # ------------------------------------------------------------------
    def _bandit_for(self, workload: str, bucket: int,
                    route: str) -> ResidualBandit:
        """Per-(workload, bucket, route) residual bandit, created lazily
        for routes first seen online: each link of a multi-worker cluster
        drifts independently (congestion, outages), so its residual
        corrections must not be polluted by other links' observations.
        Route "" (single-link deployments) keeps the offline-built state.
        """
        key = (workload, bucket, route)
        bandit = self._bandits.get(key)
        if bandit is None:
            bandit = ResidualBandit(self._bandit_config)
            self._bandits[key] = bandit
        return bandit

    # ------------------------------------------------------------------
    def _bucket_of(self, q_min: float) -> int:
        """Strictest bucket whose floor covers ``q_min`` (bucket 0 when
        ``q_min`` exceeds every floor — the strictest available; ``select``
        then filters candidates by ``q_min`` itself, so a budget above the
        top floor never silently admits profiles below it)."""
        best = 0
        for bi, floor in enumerate(self.buckets):
            if floor >= q_min:
                best = bi       # floors descend: keep the coarsest cover
            else:
                break
        return best

    # ------------------------------------------------------------------
    @staticmethod
    def _eligible_candidates(env: LowerEnvelope, x: float,
                             ctx: ServiceContext) -> List[Profile]:
        """The envelope's neighbour candidate set, filtered by Theorem 6.1
        (drop non-beneficial profiles at the current bandwidth) and by the
        request's OWN q_min (not just the bucket floor: a q_min above the
        top floor must not admit profiles below it).  Shared by ``select``
        and ``predict`` so routing scores the same candidate set selection
        draws from."""
        candidates = [p for p in env.candidates(x, n_neighbors=1)
                      if (p.cr <= 1.0 or is_beneficial(p, ctx.bandwidth))
                      and (p.cr <= 1.0 or p.q(ctx.workload) >= ctx.q_min)]
        return candidates or [IDENTITY_PROFILE]

    def select(self, ctx: ServiceContext) -> Decision:
        bucket = self._bucket_of(ctx.q_min)
        spec_k = self._choose_spec_k(ctx)
        env = self._envelopes.get((ctx.workload, bucket))
        if env is None or not env.lines:
            # Identity fallback: predicted must be comparable with the
            # other branches' predicted_latency (t_model included), or the
            # bandit's residuals for this arm absorb the whole model time.
            return Decision(IDENTITY_PROFILE, 0, bucket,
                            baseline_latency(ctx), spec_k=spec_k)

        x = 1.0 / max(ctx.bandwidth, 1e-9)
        if not self.use_envelope:
            # ablation: pick max-CR profile regardless of service state
            profs = [l.profile for l in env.lines]
            p = max(profs, key=lambda q: q.cr)
            return Decision(p, 0, bucket, predicted_latency(p, ctx), [p],
                            spec_k=spec_k)

        interval = env.optimal_index(x)
        candidates = self._eligible_candidates(env, x, ctx)

        if self.use_bandit:
            bandit = self._bandit_for(ctx.workload, bucket, ctx.route)
            p = bandit.select(interval, candidates, ctx)
        else:
            p = min(candidates, key=lambda q: predicted_latency(q, ctx))

        return Decision(p, interval, bucket, predicted_latency(p, ctx),
                        candidates, spec_k=spec_k)

    # ------------------------------------------------------------------
    # Adaptive speculation length (DESIGN.md §15)
    # ------------------------------------------------------------------
    def accept_rate(self, workload: str, route: str) -> float:
        """The controller's per-draft acceptance estimate for
        (workload, route): the optimistic prior until the first
        observation, then an EWMA of realized per-request accept rates."""
        return self._accept_rates.get((workload, route), self._spec_prior)

    def observe_accept(self, workload: str, route: str,
                       rate: float) -> None:
        """Feed one finished request's realized per-draft accept rate
        (drafts_accepted / drafts_offered) back into the (workload,
        route) EWMA — the accept-rate analogue of the latency bandit's
        residual update.  The latency residuals themselves also see
        speculative requests' realized JCTs per route, so systematic
        accept mis-estimates are additionally absorbed there."""
        rate = min(max(rate, 0.0), 1.0)
        key = (workload, route)
        prev = self._accept_rates.get(key)
        self._accept_rates[key] = (rate if prev is None else
                                   (1 - self._spec_alpha) * prev
                                   + self._spec_alpha * rate)

    def _choose_spec_k(self, ctx: ServiceContext) -> int:
        """Pick the draft budget minimizing the modelled decode-stream
        time over ``spec_candidates`` at the (workload, route) accept
        estimate.  Ties break toward smaller k — at accept rate 0 the
        model collapses every candidate to the baseline and k = 0 wins,
        the required fall-back-to-plain-decode behaviour.  ``decode_time``
        only scales the objective, so an unknown (0) decode time still
        ranks candidates correctly — substitute 1s."""
        cands = self.spec_candidates
        if len(cands) <= 1:
            return cands[0] if cands else 0
        r = self.accept_rate(ctx.workload, ctx.route)
        d = ctx.decode_time if ctx.decode_time > 0 else 1.0
        return min(cands,
                   key=lambda k: (speculative_decode_latency(d, k, r), k))

    # ------------------------------------------------------------------
    def select_fetch(self, ctx: ServiceContext,
                     options: Sequence[TierFetch]
                     ) -> Optional[FetchDecision]:
        """Tier-aware fetch routing (ISSUE 4): pick the materialization
        route with the smallest tier-aware fetch term — e.g. trade
        "fetch the stored encoding from DRAM" against "refetch a smaller
        re-encoding" that pays encode time to cross a slow link with
        fewer bytes.  (Min-latency choice also maximizes SLO feasibility:
        if the argmin misses the deadline, every route does.)"""
        opts = list(options)
        if not opts:
            return None
        scored = [(tier_fetch_latency(o), o) for o in opts]
        t, o = min(scored, key=lambda pair: pair[0])
        return FetchDecision(o, t, opts)

    # ------------------------------------------------------------------
    def observe(self, ctx: ServiceContext, decision: Decision,
                observed_latency: float) -> None:
        if not self.use_bandit:
            return
        # Residuals correct the prediction that was ACTED ON: the
        # select-time Decision.predicted, not a recomputation from the
        # observe-time context (whose bandwidth estimate may have
        # drifted since the decision).  The feedback lands on the SAME
        # per-route bandit select() consulted (ctx carries the route).
        bandit = self._bandit_for(ctx.workload, decision.bucket, ctx.route)
        bandit.update(decision.interval, decision.profile, ctx,
                      observed_latency, predicted=decision.predicted)

    # ------------------------------------------------------------------
    def predict(self, ctx: ServiceContext) -> float:
        """Side-effect-free predicted latency of the profile the envelope
        would choose for ``ctx`` — the routing layer's view of a route's
        KV-movement cost.  Touches neither the bandit state nor its RNG
        (``select`` advances both), so probing every candidate route per
        request is safe."""
        bucket = self._bucket_of(ctx.q_min)
        env = self._envelopes.get((ctx.workload, bucket))
        if env is None or not env.lines:
            return baseline_latency(ctx)
        x = 1.0 / max(ctx.bandwidth, 1e-9)
        if not self.use_envelope:
            # mirror select()'s ablation: the router must score the
            # profile the controller will actually pick (max CR)
            p = max((l.profile for l in env.lines), key=lambda q: q.cr)
            return predicted_latency(p, ctx)
        candidates = self._eligible_candidates(env, x, ctx)
        return min(predicted_latency(p, ctx) for p in candidates)
