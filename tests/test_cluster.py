"""Multi-worker disaggregated ClusterRuntime: 1x1 token parity, N x M
scale-out, per-link routing, worker-local vs shared pools, and scheduler
aging under sustained contention (ISSUE 5)."""
import json

import numpy as np
import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import (
    BandwidthTrace,
    GBPS,
    NetworkTopology,
    SchedulerConfig,
)

WORKLOAD_CYCLE = ("qalike", "codelike", "mathlike", "summlike")


def _profile(cr=2.0, bits=8, codec=None):
    kw = {"codec": codec} if codec else {}
    return Profile(StrategyConfig(quantizer="uniform", key_bits=bits,
                                  value_bits=bits, granularity="per_channel",
                                  **kw),
                   cr=cr, s_enc=5e8, s_dec=5e8)


def _cluster(reference_model, *, mode="pool", seq=48, decode_tokens=4,
             prefill_tok_s=2000.0, decode_tok_s=500.0, bandwidth=1 * GBPS,
             max_prefills=1, max_slots=4, n_prefill=1, n_decode=1, **kw):
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.engine import RuntimeConfig
    defaults = dict(
        static_profile=_profile(),
        config=RuntimeConfig(seq=seq, decode_tokens=decode_tokens,
                             prefill_tok_s=prefill_tok_s,
                             decode_tok_s=decode_tok_s, mode=mode),
        trace=BandwidthTrace.constant(bandwidth),
        scheduler=SchedulerConfig(max_slots=max_slots,
                                  max_prefills_per_step=max_prefills,
                                  max_queue=256),
        n_prefill=n_prefill, n_decode=n_decode)
    defaults.update(kw)
    rt = ClusterRuntime(**defaults)
    rt.model_cfg, rt.params = reference_model
    return rt


# ---------------------------------------------------------------------------
# 1x1 cluster == the single-engine runtime (pinned PR-1 fixture)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["pool", "pd"])
def test_cluster_1x1_token_parity_with_pr1_fixture(reference_model, mode):
    """A 1x1 ClusterRuntime (constructed directly, not through the
    ServingRuntime facade) must reproduce the pinned PR-1 tokens
    bit-for-bit in BOTH serving scenarios: the multi-worker refactor may
    not perturb the single-engine path by one float."""
    from _runtime_scenario import FIXTURE, params_digest, run_scenario
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.engine import RuntimeConfig

    fix = json.loads(FIXTURE.read_text())
    rt = ClusterRuntime(
        static_profile=_profile(),
        config=RuntimeConfig(seq=64, decode_tokens=6, prefill_tok_s=2000.0,
                             decode_tok_s=500.0, mode=mode),
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=32),
        n_prefill=1, n_decode=1)
    rt.model_cfg, rt.params = reference_model
    if params_digest(rt.params) != fix["params_digest"]:
        pytest.skip("reference model differs from the fixture's "
                    "(e.g. CI trains a smaller REPRO_REF_STEPS model)")
    out = run_scenario(rt)
    assert set(out) == set(fix["outputs"])
    for rid, rec in fix["outputs"].items():
        assert out[rid]["pool_hit"] == rec["pool_hit"], (mode, rid)
        assert out[rid]["tokens"] == rec["tokens"], (mode, rid)
    # every request was served by the single (p0 -> d0) route
    assert all(r.route == "p0->d0" for r in rt.completed)


# ---------------------------------------------------------------------------
# Scale-out throughput
# ---------------------------------------------------------------------------
def _throughput(reference_model, n_prefill, n_decode, n_requests):
    rt = _cluster(reference_model, mode="pd", decode_tokens=3,
                  prefill_tok_s=200.0, n_prefill=n_prefill,
                  n_decode=n_decode)
    for i in range(n_requests):
        # distinct prompts: a genuinely cold, saturating stream
        rt.submit(WORKLOAD_CYCLE[i % 4], prompt_seed=500 + 11 * i,
                  out_tokens=1)
    done = rt.run()
    assert len(done) == n_requests
    return n_requests / rt.clock, rt


@pytest.mark.slow
def test_2x2_cluster_throughput_scales(reference_model):
    """Under saturating offered load a 2x2 cluster must sustain close to
    2x the completed-request throughput of 1x1: iterations run the
    prefill streams of distinct workers concurrently (virtual clock =>
    deterministic)."""
    t11, _ = _throughput(reference_model, 1, 1, 16)
    t22, rt22 = _throughput(reference_model, 2, 2, 16)
    assert t22 >= 1.8 * t11, (t11, t22)
    # both prefill workers actually shared the load
    by_pw = {}
    for r in rt22.completed:
        pw = r.route.split("->")[0]
        by_pw[pw] = by_pw.get(pw, 0) + 1
    assert set(by_pw) == {"p0", "p1"}
    assert min(by_pw.values()) >= 4
    s = rt22.summary()
    assert s["n_prefill_workers"] == 2.0 and s["n_decode_workers"] == 2.0
    assert "jct_p95" in s and "ttft_p99" in s


# ---------------------------------------------------------------------------
# Load-aware routing on a heterogeneous topology
# ---------------------------------------------------------------------------
def _hetero_mean_jct(reference_model, router, n=6):
    slow = BandwidthTrace.constant(0.002 * GBPS)    # ~0.6 s per transfer
    topo = NetworkTopology.full_mesh(
        1, 2, BandwidthTrace.constant(1 * GBPS), links={(0, 1): slow})
    rt = _cluster(reference_model, mode="pd", decode_tokens=3,
                  prefill_tok_s=400.0, n_prefill=1, n_decode=2,
                  topology=topo, router=router, max_slots=6)
    for i in range(n):
        rt.submit(WORKLOAD_CYCLE[i % 4], prompt_seed=900 + 7 * i,
                  out_tokens=1)
        rt.step()
    done = rt.run()
    assert len(done) == n and all(not r.pool_hit for r in done)
    slow_share = sum(1 for r in done if r.route == "p0->d1")
    return float(np.mean([r.jct for r in done])), slow_share


@pytest.mark.slow
def test_load_aware_routing_beats_round_robin_on_heterogeneous_links(
        reference_model):
    """One 1 Gbps link, one ~2 Mbps link: round-robin alternates and pays
    the slow wire on half the requests; the load-aware argmin (per-link
    goodput estimates seeded from each link's OWN trace) avoids it and
    strictly lowers mean JCT."""
    jct_rr, slow_rr = _hetero_mean_jct(reference_model, "round_robin")
    jct_la, slow_la = _hetero_mean_jct(reference_model, "load_aware")
    assert jct_la < jct_rr, (jct_la, jct_rr)
    assert slow_la < slow_rr
    assert slow_rr == 3        # RR really alternated


# ---------------------------------------------------------------------------
# Worker-local vs cluster-shared pools
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_pd_decode_side_pools_are_worker_local(reference_model):
    """In PD mode each decode worker's seeded prefix pool is LOCAL: a
    repeat prompt routed to a different worker pays the cold path again;
    routed back to the seeding worker, it hits."""
    rt = _cluster(reference_model, mode="pd", n_prefill=1, n_decode=2,
                  router="round_robin", max_prefills=1)
    for _ in range(3):                       # same prompt three times
        rt.submit("qalike", prompt_seed=5)
        rt.run()
    a, b, c = rt.completed
    assert a.route == "p0->d0" and not a.pool_hit       # cold, seeds d0
    assert b.route == "p0->d1" and not b.pool_hit       # d1's pool is cold
    assert c.route == "p0->d0" and c.pool_hit           # back on d0: hit
    assert c.wire_bytes == a.wire_bytes


@pytest.mark.slow
def test_pool_mode_remote_tier_is_cluster_shared(reference_model):
    """In pool mode the remote tier is ONE disaggregated store: with the
    worker-local hot tiers disabled, a prefix written through worker d0
    is a pool hit for worker d1 (the hierarchy ends in the shared tier)."""
    from repro.serving.engine import RuntimeConfig
    rt = _cluster(
        reference_model, mode="pool", n_prefill=1, n_decode=2,
        router="round_robin", prefill_tok_s=150.0, decode_tok_s=20.0,
        bandwidth=0.05 * GBPS,
        config=RuntimeConfig(seq=48, decode_tokens=4, prefill_tok_s=150.0,
                             decode_tok_s=20.0, hot_tier_bytes=0,
                             dram_tier_bytes=0))
    rt.submit("qalike", prompt_seed=7)
    rt.run()
    rt.submit("qalike", prompt_seed=7)
    rt.run()
    cold, hit = rt.completed
    assert cold.route == "p0->d0" and not cold.pool_hit
    assert hit.route == "p0->d1" and hit.pool_hit
    assert hit.wire_bytes == cold.wire_bytes
    # one shared remote KVTier object across both workers' hierarchies
    d0, d1 = rt.decode_workers
    assert d0.store.tiers[-1] is d1.store.tiers[-1]
    assert d0.store.tiers[0] is not d1.store.tiers[0]


@pytest.mark.slow
def test_affinity_does_not_pin_repeats_behind_a_slow_wire(reference_model):
    """The affinity term prices the hit's REAL fetch (stored bytes over
    the holding tier's link), not a flat overhead: a prefix seeded on a
    worker behind a near-dead wire must not capture its repeats when the
    cold path over the fast link is cheaper."""
    from repro.serving.cluster import LoadAwareRouter
    dead_slow = BandwidthTrace.constant(0.0002 * GBPS)   # 25 KB/s
    topo = NetworkTopology.full_mesh(
        1, 2, BandwidthTrace.constant(1 * GBPS), links={(0, 1): dead_slow})
    rt = _cluster(reference_model, mode="pd", n_prefill=1, n_decode=2,
                  router="round_robin", prefill_tok_s=400.0, topology=topo)
    rt.submit("codelike", prompt_seed=1)     # rr -> d0 (fast, irrelevant)
    rt.run()
    rt.submit("qalike", prompt_seed=5)       # rr -> d1: seeds the SLOW pool
    rt.run()
    assert rt.completed[1].route == "p0->d1"
    rt.router = LoadAwareRouter()
    rt.submit("qalike", prompt_seed=5)       # repeat of the slow prefix
    rt.run()
    r = rt.completed[2]
    # fetching ~tens of KB at 25 KB/s costs seconds; the cold path over
    # the 1 Gbps link costs ~0.2 s — load-aware must re-prefill on d0
    assert r.route == "p0->d0" and not r.pool_hit


@pytest.mark.slow
def test_cluster_rejects_conflicting_topology_dimensions(reference_model):
    topo = NetworkTopology.full_mesh(1, 2, BandwidthTrace.constant(1e9))
    with pytest.raises(ValueError):
        _cluster(reference_model, n_prefill=2, n_decode=3, topology=topo)


@pytest.mark.slow
def test_load_aware_router_exploits_prefix_affinity(reference_model):
    """The load-aware router places a repeat prompt on the worker that
    already holds its prefix (decode-side affinity), instead of blindly
    spreading load."""
    rt = _cluster(reference_model, mode="pd", n_prefill=1, n_decode=2,
                  router="load_aware")
    rt.submit("qalike", prompt_seed=5)
    rt.run()
    seeded = rt.completed[0].route
    # occupy nothing; the repeat must follow the prefix
    rt.submit("qalike", prompt_seed=5)
    rt.run()
    assert rt.completed[1].route == seeded
    assert rt.completed[1].pool_hit


# ---------------------------------------------------------------------------
# Scheduler aging under sustained contention (starvation-freedom)
# ---------------------------------------------------------------------------
def _flooded_batch_outcome(reference_model, aging_s, steps=14):
    """One batch request behind a continuous interactive flood: returns
    (batch_completed, interactive_flood_still_waiting)."""
    rt = _cluster(reference_model, mode="pool", prefill_tok_s=150.0,
                  decode_tok_s=20.0, max_prefills=1, max_slots=3,
                  scheduler=SchedulerConfig(max_slots=3,
                                            max_prefills_per_step=1,
                                            max_queue=256,
                                            aging_s=aging_s))
    rt.submit("qalike", slo_class="batch", prompt_seed=0, out_tokens=1)
    for k in range(steps):
        rt.submit("codelike", slo_class="interactive",
                  prompt_seed=100 + k, out_tokens=1)
        rt.step()
    batch_done = any(r.slo_class == "batch" for r in rt.completed)
    flood_waiting = any(q.slo_class == "interactive"
                        for q in rt.scheduler.waiting)
    return batch_done, flood_waiting


@pytest.mark.slow
def test_runtime_aging_admits_batch_under_interactive_flood(
        reference_model):
    """Starvation-freedom of priority_key aging in the real runtime: a
    batch request submitted behind a continuous interactive flood is
    eventually admitted and completes while the flood continues.  With
    aging disabled the same horizon starves it — the aging term is what
    provides the guarantee."""
    done, flooded = _flooded_batch_outcome(reference_model, aging_s=0.5)
    assert done and flooded
    starved, _ = _flooded_batch_outcome(reference_model, aging_s=0.0)
    assert not starved
