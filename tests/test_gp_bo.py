"""Bayesian Profiling Engine: GP sanity, BO efficiency, ablations."""
import numpy as np
import pytest

from repro.core.strategy import enumerate_space, estimate_cr
from repro.profiling import BOConfig, GaussianProcess, run_bo, run_random_search


def test_gp_fits_smooth_function():
    rng = np.random.default_rng(0)
    x = rng.uniform(-2, 2, size=(40, 2))
    y = np.sin(x[:, 0]) + 0.5 * x[:, 1]
    gp = GaussianProcess(length_scale=0.8).fit(x, y)
    xq = rng.uniform(-1.5, 1.5, size=(30, 2))
    yq = np.sin(xq[:, 0]) + 0.5 * xq[:, 1]
    mean, std = gp.predict(xq)
    assert np.abs(mean - yq).mean() < 0.1
    # interpolation points have low predictive std
    m2, s2 = gp.predict(x[:5])
    assert (s2 < 0.1).all()


def test_gp_prob_greater_monotone():
    gp = GaussianProcess().fit(np.array([[0.0], [1.0]]), np.array([0.0, 1.0]))
    # query away from the observations so posterior std is non-trivial
    p_low = gp.prob_greater(np.array([[2.5]]), 0.2)
    p_high = gp.prob_greater(np.array([[2.5]]), 0.9)
    assert p_low > p_high


def _synthetic_eval(cfg):
    """Monotone CR-Acc trade-off with structure in the config space."""
    cr = estimate_cr(cfg)
    penalty = 0.004 * cr**1.5
    if cfg.transform == "hadamard":
        penalty *= 0.8  # rotation genuinely helps
    acc = max(0.0, 1.0 - penalty)
    return acc, cr


@pytest.fixture(scope="module")
def space():
    return enumerate_space("module")


def test_bo_finds_global_optimum(space):
    res = run_bo(space, _synthetic_eval,
                 BOConfig(acc_threshold=0.95, max_iters=150, seed=1))
    feasible = [(c, _synthetic_eval(c)) for c in space
                if _synthetic_eval(c)[0] >= 0.95]
    true_best = max(v[1] for _, v in feasible)
    assert res.best is not None
    assert res.best_cr() >= true_best - 1e-9
    # sample efficiency: far fewer evals than the space size
    assert res.evaluations < len(space) * 0.6


def test_bo_beats_random_in_sample_efficiency(space):
    budget = 25
    bo = run_bo(space, _synthetic_eval,
                BOConfig(acc_threshold=0.95, max_iters=budget, seed=3))
    rnd = run_random_search(space, _synthetic_eval,
                            BOConfig(acc_threshold=0.95, max_iters=budget,
                                     seed=3))
    assert bo.best_cr() >= rnd.best_cr()


def test_pruning_reduces_evaluations(space):
    full = run_bo(space, _synthetic_eval,
                  BOConfig(acc_threshold=0.95, max_iters=400, seed=5))
    no_prune = run_bo(space, _synthetic_eval,
                      BOConfig(acc_threshold=0.95, max_iters=400, seed=5,
                               use_pruning=False, use_early_stop=False))
    assert full.evaluations <= no_prune.evaluations
    # both still find the optimum
    assert abs(full.best_cr() - no_prune.best_cr()) < 1e-6


def test_feasible_set_respects_constraint(space):
    res = run_bo(space, _synthetic_eval,
                 BOConfig(acc_threshold=0.97, max_iters=60, seed=7))
    assert all(o.acc >= 0.97 for o in res.feasible)


def test_early_stop_on_exhaustion():
    tiny = enumerate_space("pipeline")
    res = run_bo(tiny, _synthetic_eval,
                 BOConfig(acc_threshold=0.5, max_iters=10_000, seed=0))
    assert res.evaluations <= len(tiny)
