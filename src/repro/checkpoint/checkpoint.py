"""Fault-tolerant checkpointing with elastic restore.

Design (1000+-node deployment):
  - every leaf is written as its own .npy under <dir>/step_<k>/ with a JSON
    manifest (step, leaf count, shapes/dtypes, user metadata) written LAST —
    a checkpoint without a manifest is incomplete and ignored on restore,
    so a writer crash can never corrupt the restore path;
  - ``save(..., background=True)`` snapshots to host memory synchronously
    and writes asynchronously (training continues during I/O);
  - ``restore`` maps leaves onto a *template* pytree and accepts target
    ``shardings`` — restoring onto a different mesh than the one that wrote
    the checkpoint (elastic scaling) is just a different placement;
  - ``keep`` bounds disk usage by pruning old steps after a successful
    write.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                steps.append(int(p.name.split("_")[1]))
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None,
             background: bool = False) -> None:
        """Write a checkpoint; ``background=True`` returns after host
        snapshot and flushes on a writer thread."""
        self.wait()
        leaves = jax.tree_util.tree_leaves(tree)
        # synchronous device->host snapshot (cheap; the slow part is disk)
        host = [np.asarray(x) for x in leaves]

        def _write():
            d = self._step_dir(step)
            tmp = d.with_suffix(".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host):
                np.save(tmp / f"leaf_{i:05d}.npy", arr)
            manifest = {
                "step": step,
                "n_leaves": len(host),
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host],
                "metadata": metadata or {},
                "written_at": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)
            self._prune()

        if background:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _prune(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*") if (p / "manifest.json").exists())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore onto ``template``'s structure.  ``shardings`` (optional
        matching pytree of NamedSharding) places leaves for the *current*
        mesh — elastic restore across mesh changes."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree_util.tree_flatten(template)
        assert manifest["n_leaves"] == len(leaves), \
            f"leaf count mismatch: ckpt {manifest['n_leaves']} vs template {len(leaves)}"
        host = [np.load(d / f"leaf_{i:05d}.npy") for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
            out = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                   for a, s in zip(host, sh_leaves)]
        else:
            out = [jax.device_put(a) for a in host]
        return jax.tree_util.tree_unflatten(treedef, out)

    def metadata(self, step: Optional[int] = None) -> Dict:
        if step is None:
            step = self.latest_step()
        d = self._step_dir(step)
        return json.loads((d / "manifest.json").read_text())["metadata"]
