"""Pallas TPU kernels for the KV-compression hot paths.

  quant_pack        — fused group-quantize + int4/int8 pack (prefill side)
  dequant_unpack    — unpack + dequantize (decode side)
  hadamard          — blockwise Hadamard transform on the MXU
  decode_attention  — quantized flash-decode attention (int KV read)
  paged_attention   — block-table page gather + fused dequant decode
                      attention over the paged arena (DESIGN.md §12)
  paged_verify_attention — multi-token speculative verify over paged KV
                      (q-tile axis + staircase causal mask, DESIGN.md §15)

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling, a jit'd
wrapper in ops.py, and a pure-jnp oracle in ref.py.
"""
from repro.kernels.ops import (
    decode_attention_op,
    dequant_unpack_op,
    hadamard_op,
    paged_attention_op,
    paged_verify_attention_op,
    quant_pack_op,
)

__all__ = ["decode_attention_op", "dequant_unpack_op", "hadamard_op",
           "paged_attention_op", "paged_verify_attention_op",
           "quant_pack_op"]
