"""Mesh-aware sharding constraints usable from model code.

``shard_hint(x, spec_names)`` applies ``with_sharding_constraint`` when (a)
tracing under an ambient mesh, (b) the named axes exist, and (c) each dim is
divisible by its axes — otherwise it is the identity, so model code stays
runnable on a single CPU device and under any mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

AxisName = Union[None, str, Tuple[str, ...]]


def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:  # `with mesh:` context manager path
        import jax._src.mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return pm.abstract_mesh
    except Exception:
        pass
    return None


def shard_hint(x, *axes: AxisName):
    """Constrain ``x`` to PartitionSpec(*axes) if valid under the ambient
    mesh; no-op otherwise.  len(axes) must equal x.ndim."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    out = []
    used = set()
    for dim, name in zip(x.shape, axes):
        cand = (name,) if isinstance(name, str) else (name or ())
        cand = tuple(a for a in cand if a in sizes and a not in used)
        if not cand:
            out.append(None)
            continue
        prod = int(np.prod([sizes[a] for a in cand]))
        if prod > 1 and dim % prod == 0:
            out.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            out.append(None)
    if all(o is None for o in out):
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))
