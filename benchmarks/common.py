"""Shared helpers for the benchmark harness.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (one per paper
table/figure cell) so ``python -m benchmarks.run`` output is machine-
readable.
"""
from __future__ import annotations

import sys
import time
from typing import Callable, Dict, List, Optional

ROWS: List[str] = []
_ROWS_STRUCTURED: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    _ROWS_STRUCTURED.append({"name": name, "us_per_call": us_per_call,
                             "derived": derived})
    print(row)
    sys.stdout.flush()


def write_json(path: str) -> None:
    """Archive the emitted rows as machine-readable JSON (CI artifact)."""
    import json
    with open(path, "w") as f:
        json.dump(_ROWS_STRUCTURED, f, indent=1)
    print(f"# wrote {len(_ROWS_STRUCTURED)} rows to {path}")


def time_call(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def default_profiles(with_quality: bool = True, fast: bool = False):
    """The standard benchmark profile set: paper baselines + a bit-sweep."""
    from repro.core.strategy import BASELINES, StrategyConfig
    from repro.launch.profile_offline import build_profiles

    strategies = [
        BASELINES["cachegen"], BASELINES["kivi"], BASELINES["duoattention"],
        BASELINES["mixhq"],
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_channel"),
        StrategyConfig(quantizer="uniform", key_bits=4, value_bits=4,
                       granularity="per_channel", codec="zstd3"),
        StrategyConfig(transform="hadamard", quantizer="uniform", key_bits=4,
                       value_bits=4, granularity="per_token"),
    ]
    qk = {"n_prompts": 3, "decode_tokens": 10} if fast else {}
    return build_profiles(strategies, with_quality=with_quality,
                          quality_kwargs=qk)


_CACHED_PROFILES = None


def cached_profiles():
    global _CACHED_PROFILES
    if _CACHED_PROFILES is None:
        _CACHED_PROFILES = default_profiles(fast=True)
    return _CACHED_PROFILES
