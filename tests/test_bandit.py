"""Residual-corrected bandit: drift correction, guardrails, cooldown."""
import numpy as np
import pytest

from repro.controller import BanditConfig, ResidualBandit, ServiceContext
from repro.controller.latency_model import predicted_latency
from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.core.strategy import StrategyConfig


def _profile(cr, s, bits=4):
    return Profile(StrategyConfig(key_bits=bits, value_bits=bits), cr=cr,
                   s_enc=2 * s, s_dec=2 * s)


def _ctx(bandwidth=1e9, slo=0.0, v=1e8):
    return ServiceContext("qalike", bandwidth, slo, 0.9, t_model=0.0,
                          kv_bytes=v)


def test_residual_ewma_converges():
    bandit = ResidualBandit(BanditConfig(alpha=0.3, epsilon=0.0))
    p = _profile(4.0, 1e9)
    ctx = _ctx()
    true_extra = 0.05  # constant unmodelled overhead
    for _ in range(60):
        t_obs = predicted_latency(p, ctx) + true_extra
        bandit.update(0, p, ctx, t_obs)
    assert abs(bandit.residual_of(0, p) - true_extra) < 0.005


def test_bandit_corrects_model_mispredictions():
    """Model prefers p_fast, but runtime drift makes p_slow better; the
    bandit must flip after observing residuals."""
    cfg = BanditConfig(alpha=0.4, epsilon=0.0, seed=0)
    bandit = ResidualBandit(cfg)
    p_model_best = _profile(8.0, 1e10, bits=2)   # looks fastest on paper
    p_actual_best = _profile(4.0, 1e10, bits=4)
    ctx = _ctx(bandwidth=5e8)
    cands = [p_model_best, p_actual_best]
    assert bandit.select(0, cands, ctx) is p_model_best  # prior decision
    for _ in range(30):
        chosen = bandit.select(0, cands, ctx)
        extra = 0.5 if chosen is p_model_best else 0.0  # hidden contention
        bandit.update(0, chosen, ctx, predicted_latency(chosen, ctx) + extra)
        # force one exploration of the alternative early on
        bandit.update(0, p_actual_best, ctx,
                      predicted_latency(p_actual_best, ctx))
    assert bandit.select(0, cands, ctx) is p_actual_best


def test_slo_feasibility_filter_prefers_feasible():
    bandit = ResidualBandit(BanditConfig(epsilon=0.0))
    slow = _profile(8.0, 1e6, bits=2)   # high CR but way too slow for SLO
    ok = _profile(2.0, 1e11, bits=8)    # meets the SLO
    ctx = _ctx(bandwidth=1e10, slo=0.05, v=1e9)
    assert bandit.select(0, [slow, ok], ctx) is ok


def test_empty_feasible_set_best_effort_fallback():
    """Paper Sec 6.2: empty feasible set -> conservative *compression*
    default (least-bad candidate), never raw KV."""
    bandit = ResidualBandit(BanditConfig(epsilon=0.0))
    slow = _profile(8.0, 1e6, bits=2)
    slower = _profile(8.0, 1e5, bits=3)
    ctx = _ctx(bandwidth=1e7, slo=0.001, v=1e9)
    assert bandit.select(0, [slower, slow], ctx) is slow
    # with no candidates at all, identity remains the final fallback
    assert bandit.select(0, [], ctx) is IDENTITY_PROFILE


def test_violation_cooldown_quarantines():
    cfg = BanditConfig(epsilon=0.0, violation_k=3, violation_m=5,
                       cooldown_steps=100)
    bandit = ResidualBandit(cfg)
    bad = _profile(6.0, 1e10, bits=2)
    good = _profile(2.0, 1e10, bits=8)
    ctx = _ctx(bandwidth=1e9, slo=0.3, v=1e8)
    for _ in range(4):  # bad profile repeatedly blows the SLO
        bandit.update(0, bad, ctx, observed_latency=1.0)
    chosen = bandit.select(0, [bad, good], ctx)
    assert chosen is good


def test_exploration_excludes_greedy_arm():
    """ε-exploration must draw from the non-greedy arms (corrected-latency
    argmin excluded) — not from candidate order, which excludes an
    arbitrary arm."""
    bandit = ResidualBandit(BanditConfig(epsilon=1.0, seed=3))
    fast = _profile(8.0, 1e11, bits=2)
    mid = _profile(4.0, 1e10, bits=4)
    slow = _profile(2.0, 1e9, bits=8)
    ctx = _ctx(bandwidth=1e9)
    # Put the greedy (lowest corrected latency) arm in every candidate
    # position: with epsilon=1 it must never be selected.
    by_latency = sorted([fast, mid, slow],
                        key=lambda p: predicted_latency(p, ctx))
    greedy = by_latency[0]
    for order in ([fast, mid, slow], [mid, slow, fast], [slow, fast, mid]):
        for _ in range(25):
            assert bandit.select(0, list(order), ctx) is not greedy


def test_exploration_with_single_arm_stays_greedy():
    bandit = ResidualBandit(BanditConfig(epsilon=1.0, seed=0))
    only = _profile(4.0, 1e10)
    ctx = _ctx()
    assert bandit.select(0, [only], ctx) is only
