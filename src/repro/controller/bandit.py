"""Residual-Corrected Bandit (Sec. 6.2).

Per (quality-bucket b, envelope-interval i) environment:
  - EWMA residual  δ̄ ← (1-α)δ̄ + α(T_obs - T̂_p)        (Eq. 7)
  - corrected latency  T_eff = T̂_p + δ̄                 (Eq. 8)
  - ε-greedy over the 2-3 profile candidate set
  - safety guardrails: conservative feasibility filter T̂_p ≤ T_SLO with a
    conservative fallback, and a violation cooldown (K violations in the
    last M uses -> quarantined for a cooldown window).
"""
from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.controller.latency_model import ServiceContext, predicted_latency


@dataclass
class BanditConfig:
    alpha: float = 0.2          # EWMA tracking speed
    epsilon: float = 0.08       # exploration probability
    violation_k: int = 3        # K violations ...
    violation_m: int = 10       # ... in the last M uses
    cooldown_steps: int = 25    # quarantine window
    seed: int = 0


@dataclass
class _ArmState:
    residual: float = 0.0
    count: int = 0
    recent_violations: Deque[bool] = field(default_factory=lambda: deque(maxlen=10))
    cooldown_until: int = -1


class ResidualBandit:
    """One instance per (workload, quality bucket); environments keyed by
    envelope interval."""

    def __init__(self, config: BanditConfig = BanditConfig()):
        self.config = config
        self._arms: Dict[Tuple[int, str], _ArmState] = {}
        self._step = 0
        self._rng = random.Random(config.seed)

    def _arm(self, interval: int, p: Profile) -> _ArmState:
        key = (interval, p.strategy.key())
        if key not in self._arms:
            self._arms[key] = _ArmState(
                recent_violations=deque(maxlen=self.config.violation_m))
        return self._arms[key]

    # ------------------------------------------------------------------
    def select(self, interval: int, candidates: List[Profile],
               ctx: ServiceContext) -> Profile:
        """ε-greedy over corrected latencies with safety guardrails."""
        self._step += 1
        usable = []
        best_effort = []
        for p in candidates:
            arm = self._arm(interval, p)
            if arm.cooldown_until >= self._step:
                continue  # quarantined after repeated SLO violations
            t_hat = predicted_latency(p, ctx)
            best_effort.append((p, t_hat + arm.residual))
            if ctx.t_slo > 0 and t_hat > ctx.t_slo:
                continue  # conservative feasibility filter
            usable.append((p, t_hat + arm.residual))

        if not usable:
            # Paper Sec 6.2: empty feasible set -> fall back to a default
            # conservative *compression* configuration (best-effort minimum
            # predicted latency), never to shipping raw KV.
            if best_effort:
                return min(best_effort, key=lambda pt: pt[1])[0]
            return IDENTITY_PROFILE

        greedy = min(usable, key=lambda pt: pt[1])
        if self._rng.random() < self.config.epsilon and len(usable) > 1:
            # Explore a non-greedy arm: exclude the corrected-latency argmin
            # (usable is in candidate order, so usable[1:] would exclude an
            # arbitrary arm instead).
            return self._rng.choice(
                [pt for pt in usable if pt is not greedy])[0]
        return greedy[0]

    # ------------------------------------------------------------------
    def update(self, interval: int, p: Profile, ctx: ServiceContext,
               observed_latency: float,
               predicted: Optional[float] = None) -> None:
        """EWMA-track the residual of the prediction that was *acted on*:
        pass ``predicted`` (the select-time ``Decision.predicted``) so a
        bandwidth estimate that drifted between select and observe cannot
        make the residual correct a prediction nobody acted on; without it
        the prediction is recomputed from ``ctx`` (legacy behaviour)."""
        arm = self._arm(interval, p)
        t_hat = predicted if predicted is not None \
            else predicted_latency(p, ctx)
        delta = observed_latency - t_hat
        a = self.config.alpha
        arm.residual = (1 - a) * arm.residual + a * delta
        arm.count += 1

        violated = ctx.t_slo > 0 and observed_latency > ctx.t_slo
        arm.recent_violations.append(violated)
        if (sum(arm.recent_violations) >= self.config.violation_k
                and len(arm.recent_violations) >= self.config.violation_k):
            arm.cooldown_until = self._step + self.config.cooldown_steps
            arm.recent_violations.clear()

    # ------------------------------------------------------------------
    def residual_of(self, interval: int, p: Profile) -> float:
        return self._arm(interval, p).residual
