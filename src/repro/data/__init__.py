from repro.data.synthetic import WORKLOADS, WorkloadSpec, make_batch, make_prompt
from repro.data.tokenizer import ByteTokenizer

__all__ = ["WORKLOADS", "WorkloadSpec", "make_batch", "make_prompt", "ByteTokenizer"]
