"""Paged KV arena (ISSUE 7): page-table invariants, fp16-page token
parity vs the pinned PR-1 fixture, and the quantized-resident fast path.
"""
import json

import numpy as np
import pytest

from repro.core.kvcache import ArenaOutOfPages, PageTable
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig, paged_eligible
from repro.serving import BandwidthTrace, GBPS, SchedulerConfig


# ---------------------------------------------------------------------------
# PageTable: pure host-side bookkeeping
# ---------------------------------------------------------------------------
def test_page_table_conservation_under_churn():
    """Random admit/grow/release churn: every step preserves page
    conservation, single ownership, and the scratch-page reservation."""
    rng = np.random.default_rng(0)
    pt = PageTable(num_pages=33, page_size=8)
    live = set()
    for _ in range(500):
        slot = int(rng.integers(0, 10))
        if slot in live and rng.random() < 0.4:
            assert pt.release(slot) > 0
            live.discard(slot)
        else:
            try:
                pt.ensure(slot, int(rng.integers(1, 65)))
                live.add(slot)
            except ArenaOutOfPages:
                pass    # pool full: the ask must leave state untouched
        pt.check()
    for slot in list(live):
        pt.release(slot)
    pt.check()
    assert pt.free_pages == 32      # everything back, page 0 still reserved


def test_page_table_no_partial_grant():
    pt = PageTable(num_pages=5, page_size=4)    # 4 allocatable pages
    pt.ensure(0, 8)                             # slot 0 takes 2
    owned_before, free_before = list(pt.pages[0]), pt.free_pages
    with pytest.raises(ArenaOutOfPages):
        pt.ensure(1, 100)
    assert pt.pages.get(1, []) == []            # nothing granted
    assert pt.free_pages == free_before
    assert pt.pages[0] == owned_before
    pt.check()


def test_page_table_block_row_scratch_padding():
    pt = PageTable(num_pages=9, page_size=8)
    pt.ensure(2, 20)                            # ceil(20/8) = 3 pages
    row = pt.block_row(2, 5)
    assert row.dtype == np.int32 and row.shape == (5,)
    assert (row[:3] > 0).all()                  # real pages
    assert (row[3:] == 0).all()                 # scratch sentinel padding
    # growth is monotone: ensure() at a smaller ask allocates nothing
    assert pt.ensure(2, 8) == []


def test_page_table_byte_accounting():
    fp16 = PageTable.page_bytes_fp16(16, 2, 32, 4)
    q4 = PageTable.page_bytes_quant(16, 2, 32, 4, bits=4, group=32)
    q8 = PageTable.page_bytes_quant(16, 2, 32, 4, bits=8, group=32)
    assert fp16 > q8 > q4 > 0
    # int4 with coarse groups approaches 4x over fp16
    assert fp16 / q4 > 3.0 and fp16 / q8 > 1.5


# ---------------------------------------------------------------------------
# Runtime parity: the paged arena must be a pure re-layout
# ---------------------------------------------------------------------------
def _paged_cfg(mode, **kw):
    from repro.serving.engine import RuntimeConfig
    # page_size=8 divides max_len = seq + decode_tokens + 2 = 72, so the
    # paged gathered view is shape- and value-identical to the dense
    # arena and parity is bit-exact.
    return RuntimeConfig(seq=64, decode_tokens=6, prefill_tok_s=2000.0,
                         decode_tok_s=500.0, mode=mode, paged=True,
                         page_size=8, **kw)


def _runtime(reference_model, config, profile=None):
    from repro.serving.engine import ServingRuntime
    if profile is None:
        profile = Profile(
            StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                           granularity="per_channel"),
            cr=2.0, s_enc=5e8, s_dec=5e8)
    rt = ServingRuntime(
        static_profile=profile, config=config,
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=32))
    rt.model_cfg, rt.params = reference_model
    return rt


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["pool", "pd"])
def test_paged_fp16_token_parity_with_pr1_fixture(reference_model, mode):
    """The fixture profile (per-channel, asymmetric) is NOT
    paged-eligible, so its pool hits take the materialized fp16-page
    injection path — which must reproduce the pinned PR-1 tokens
    bit-for-bit in both pool and pd modes."""
    from _runtime_scenario import FIXTURE, params_digest, run_scenario
    fix = json.loads(FIXTURE.read_text())
    rt = _runtime(reference_model, _paged_cfg(mode))
    if params_digest(rt.params) != fix["params_digest"]:
        pytest.skip("reference model differs from the fixture's "
                    "(e.g. CI trains a smaller REPRO_REF_STEPS model)")
    out = run_scenario(rt)
    assert set(out) == set(fix["outputs"])
    for rid, rec in fix["outputs"].items():
        assert out[rid]["pool_hit"] == rec["pool_hit"], rid
        assert out[rid]["tokens"] == rec["tokens"], rid
    # all pages returned to the pool after the run, invariants intact
    for dw in rt.decode_workers:
        dw.page_table.check()
        assert dw.page_table.free_pages == dw.page_table.num_pages - 1


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["pool", "pd"])
def test_paged_vs_dense_token_parity(reference_model, mode):
    """Fixture-independent twin of the parity test above: whatever the
    trained reference model is, the paged runtime must emit exactly the
    dense runtime's tokens across the hit/miss scenario."""
    from _runtime_scenario import run_scenario
    from repro.serving.engine import RuntimeConfig

    dense_cfg = RuntimeConfig(seq=64, decode_tokens=6, prefill_tok_s=2000.0,
                              decode_tok_s=500.0, mode=mode)
    out_dense = run_scenario(_runtime(reference_model, dense_cfg))
    out_paged = run_scenario(_runtime(reference_model, _paged_cfg(mode)))
    assert set(out_dense) == set(out_paged)
    for rid in out_dense:
        assert out_paged[rid]["pool_hit"] == out_dense[rid]["pool_hit"], rid
        assert out_paged[rid]["tokens"] == out_dense[rid]["tokens"], rid


@pytest.mark.slow
def test_paged_quant_resident_token_parity(reference_model):
    """A paged-eligible profile (per-token symmetric int8) keeps pool
    hits resident as quantized pages: tokens must match the dense
    (materialized-decompress) runtime exactly, and the hit's decompress
    term leaves the TTFT breakdown — with breakdowns still summing to
    JCT."""
    from _runtime_scenario import run_scenario
    from repro.serving.engine import RuntimeConfig

    profile = Profile(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_token", symmetric=True,
                       group_size=32),
        cr=2.0, s_enc=5e8, s_dec=5e8)
    assert paged_eligible(profile.strategy)

    dense_cfg = RuntimeConfig(seq=64, decode_tokens=6, prefill_tok_s=2000.0,
                              decode_tok_s=500.0)
    rt_dense = _runtime(reference_model, dense_cfg, profile=profile)
    rt_paged = _runtime(reference_model, _paged_cfg("pool"), profile=profile)
    out_dense, out_paged = run_scenario(rt_dense), run_scenario(rt_paged)

    assert set(out_dense) == set(out_paged)
    for rid in out_dense:
        assert out_paged[rid]["pool_hit"] == out_dense[rid]["pool_hit"], rid
        assert out_paged[rid]["tokens"] == out_dense[rid]["tokens"], rid

    hits_d = [r for r in rt_dense.completed if r.pool_hit]
    hits_p = [r for r in rt_paged.completed if r.pool_hit]
    assert len(hits_p) == len(hits_d) > 0
    for r in hits_d:    # dense hits pay the materialized decompress
        assert r.breakdown["decompress"] > 0
    for r in hits_p:    # paged hits decode the pages in the fused path
        assert r.breakdown["decompress"] == 0.0
    for r in rt_paged.completed:
        assert sum(r.breakdown.values()) == pytest.approx(r.jct, abs=1e-9)


@pytest.mark.slow
def test_paged_arena_pages_override_raises_when_exhausted(reference_model):
    """An explicit undersized ``arena_pages`` surfaces as
    ArenaOutOfPages instead of silently corrupting a stolen page."""
    rt = _runtime(reference_model, _paged_cfg("pool", arena_pages=4))
    rt.submit("qalike", prompt_seed=0)
    with pytest.raises(ArenaOutOfPages):
        rt.run()
