"""Scenario archetypes: what each request *is*, beyond when it arrives.

Four production archetypes, each mapped onto the ``data.synthetic``
workload families (their prompt generators and context scales are reused,
so a trace event can always be materialized into a real prompt for the
real-execution runtime):

* ``chat`` — interactive conversations behind a small set of HOT shared
  system prompts: high prefix-sharing probability over few groups
  (Zipf-skewed), short-to-medium contexts, tight TTFT SLO.
* ``rag`` — long-context retrieval-augmented generation: heavy-tailed
  contexts (lognormal), little sharing (every retrieval set differs),
  looser TTFT SLO, standard class.
* ``agentic`` — multi-turn tool-using sessions: each arrival spawns a
  session of several turns sharing ONE prefix group whose context GROWS
  turn over turn (the KV written by turn *i* covers a prefix of turn
  *i+1* — growing KV reuse), JCT SLO.
* ``classify`` — prefill-only one-token classification (FUTURE.md #5
  shape): out_tokens == 1, short contexts, high sharing on the classifier
  prompt, batch class.

Each archetype is declarative (:class:`ScenarioSpec`); generation is a
pure function of ``(spec, arrival times, rng)`` so traces are
seed-deterministic end to end.  :func:`build_trace` composes per-tenant
archetype + arrival-process pairs into one superposed trace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.synthetic import WORKLOADS
from repro.workloads.arrivals import make_arrivals
from repro.workloads.trace import Trace, TraceEvent

Rng = np.random.Generator


def _mix_scale(mix: Dict[str, float]) -> float:
    """Weighted mean ctx scale of a workload mix (tokens ~ bytes for the
    byte tokenizer) — ties archetype context medians to the synthetic
    workload families they draw prompts from."""
    tot = sum(mix.values())
    return sum(WORKLOADS[w].ctx_scale * p for w, p in mix.items()) / tot


@dataclass(frozen=True)
class ScenarioSpec:
    """Declarative archetype: lengths, sharing, turns, SLO contract."""

    name: str
    workload_mix: Dict[str, float]
    ctx_median: int               # lognormal median prompt tokens
    ctx_sigma: float = 0.4        # lognormal sigma
    ctx_min: int = 64
    ctx_max: int = 65536
    out_median: int = 32          # lognormal median decode tokens
    out_sigma: float = 0.5
    out_min: int = 1
    slo_class: str = "standard"
    slo_metric: str = "ttft"
    t_slo: float = 0.0
    q_min: float = 0.97
    # Prefix sharing: with probability share_p a request reuses one of
    # hot_groups Zipf-skewed shared groups; otherwise it opens its own.
    hot_groups: int = 0
    share_p: float = 0.0
    zipf_a: float = 1.3
    # Multi-turn sessions (agentic): mean turns per session (geometric),
    # think-time between turns, and context carried forward per turn
    # (prev ctx + prev output + fresh user tokens).
    turns_mean: float = 1.0
    turn_gap_s: float = 4.0
    turn_user_tokens: int = 96


def _lognormal_ints(rng: Rng, n: int, median: float, sigma: float,
                    lo: int, hi: int) -> np.ndarray:
    vals = rng.lognormal(math.log(median), sigma, size=n)
    return np.clip(vals, lo, hi).astype(np.int64)


def _zipf_groups(rng: Rng, n: int, k: int, a: float) -> np.ndarray:
    """n draws over k hot groups with Zipf(a) popularity."""
    w = 1.0 / np.arange(1, k + 1, dtype=np.float64) ** a
    w /= w.sum()
    return rng.choice(k, size=n, p=w)


def generate_events(spec: ScenarioSpec, tenant: str, times: np.ndarray,
                    rng: Rng, group_start: int = 0
                    ) -> Tuple[List[TraceEvent], int]:
    """Expand arrival times into trace events for one (tenant, archetype)
    stream.  Returns ``(events, next_free_group)``; rids are dense in
    event-time order, local to this stream (``Trace.merge`` renumbers).
    All draws are vectorized up front so million-event streams build in
    seconds; sessions (``turns_mean > 1``) expand each arrival into its
    turns."""
    n = len(times)
    if n == 0:
        return [], group_start
    names = sorted(spec.workload_mix)
    probs = np.asarray([spec.workload_mix[w] for w in names], dtype=float)
    probs /= probs.sum()
    widx = rng.choice(len(names), size=n, p=probs)
    ctx = _lognormal_ints(rng, n, spec.ctx_median, spec.ctx_sigma,
                          spec.ctx_min, spec.ctx_max)
    out = _lognormal_ints(rng, n, spec.out_median, spec.out_sigma,
                          spec.out_min, 1 << 20)
    share = (rng.random(n) < spec.share_p) if spec.hot_groups > 0 \
        else np.zeros(n, dtype=bool)
    hot = _zipf_groups(rng, n, max(spec.hot_groups, 1), spec.zipf_a) \
        if spec.hot_groups > 0 else np.zeros(n, dtype=np.int64)
    multi_turn = spec.turns_mean > 1.0
    turns = (1 + rng.geometric(1.0 / spec.turns_mean, size=n)
             if multi_turn else np.ones(n, dtype=np.int64))

    rows: List[Tuple] = []       # (t, workload, ctx, out, group)
    next_group = group_start + spec.hot_groups
    for i in range(n):
        w = names[widx[i]]
        if share[i]:
            g = group_start + int(hot[i])
        else:
            g = next_group
            next_group += 1
        t = float(times[i])
        c, o = int(ctx[i]), int(out[i])
        rows.append((t, w, c, o, g))
        if multi_turn:
            # Session turns share the group; context grows by the prior
            # turn's output plus fresh user tokens, so each turn's pool
            # entry covers a strict prefix of the next turn's prompt.
            for _ in range(int(turns[i]) - 1):
                t = t + float(rng.exponential(spec.turn_gap_s))
                c = min(c + o + spec.turn_user_tokens, spec.ctx_max)
                o = int(_lognormal_ints(rng, 1, spec.out_median,
                                        spec.out_sigma, spec.out_min,
                                        1 << 20)[0])
                rows.append((t, w, c, o, g))
    rows.sort(key=lambda r: r[0])
    events = [TraceEvent(rid=i, t=r[0], tenant=tenant, scenario=spec.name,
                         workload=r[1], ctx_tokens=r[2], out_tokens=r[3],
                         prefix_group=r[4], slo_class=spec.slo_class,
                         slo_metric=spec.slo_metric, t_slo=spec.t_slo,
                         q_min=spec.q_min)
              for i, r in enumerate(rows)]
    return events, next_group


# ---------------------------------------------------------------------------
# The four archetypes (context medians anchored to the synthetic
# families' ctx scales via _mix_scale).
# ---------------------------------------------------------------------------
_CHAT_MIX = {"qalike": 0.5, "summlike": 0.3, "codelike": 0.2}
_RAG_MIX = {"qalike": 0.6, "summlike": 0.4}
_AGENTIC_MIX = {"codelike": 0.5, "mathlike": 0.5}
_CLASSIFY_MIX = {"mathlike": 0.5, "qalike": 0.5}

ARCHETYPES: Dict[str, ScenarioSpec] = {
    "chat": ScenarioSpec(
        name="chat", workload_mix=_CHAT_MIX,
        ctx_median=int(2 * _mix_scale(_CHAT_MIX)), ctx_sigma=0.5,
        out_median=48, slo_class="interactive", slo_metric="ttft",
        t_slo=1.5, hot_groups=12, share_p=0.65, zipf_a=1.3),
    "rag": ScenarioSpec(
        name="rag", workload_mix=_RAG_MIX,
        ctx_median=int(14 * _mix_scale(_RAG_MIX)), ctx_sigma=0.7,
        out_median=64, slo_class="standard", slo_metric="ttft",
        t_slo=8.0, hot_groups=0, share_p=0.0),
    "agentic": ScenarioSpec(
        name="agentic", workload_mix=_AGENTIC_MIX,
        ctx_median=int(2 * _mix_scale(_AGENTIC_MIX)), ctx_sigma=0.4,
        out_median=96, slo_class="standard", slo_metric="jct",
        t_slo=12.0, turns_mean=3.5, turn_gap_s=3.0),
    "classify": ScenarioSpec(
        name="classify", workload_mix=_CLASSIFY_MIX,
        ctx_median=int(1 * _mix_scale(_CLASSIFY_MIX)), ctx_sigma=0.3,
        out_median=1, out_sigma=0.0, slo_class="batch",
        slo_metric="ttft", t_slo=4.0, hot_groups=6, share_p=0.8),
}


# ---------------------------------------------------------------------------
# Tenant composition
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TenantSpec:
    """One tenant = one archetype stream under one arrival process."""

    name: str
    scenario: str                 # ARCHETYPES key
    rate: float                   # primary rate of the arrival process
    arrival: str = "poisson"      # poisson | diurnal | mmpp
    arrival_kw: Dict[str, object] = field(default_factory=dict)
    overrides: Dict[str, object] = field(default_factory=dict)
    # ScenarioSpec field overrides (e.g. tighter t_slo for a paid tier)


def default_tenants(rate_scale: float = 1.0) -> List[TenantSpec]:
    """The standard mixed-production tenant set used by the trace-grid
    benchmark: diurnal chat, steady RAG, bursty agentic, and an offline
    classification batch source."""
    return [
        TenantSpec("chat-web", "chat", 3.0 * rate_scale, "diurnal",
                   {"amplitude": 0.6, "gamma_shape": 4.0}),
        TenantSpec("rag-search", "rag", 1.0 * rate_scale, "poisson"),
        TenantSpec("agents", "agentic", 0.6 * rate_scale, "mmpp",
                   {"mean_on": 6.0, "mean_off": 14.0}),
        TenantSpec("classify-batch", "classify", 2.0 * rate_scale, "mmpp",
                   {"mean_on": 4.0, "mean_off": 10.0}),
    ]


def build_tenant_trace(tenant: TenantSpec, duration: float, seed: int,
                       stream: int = 0, group_start: int = 0
                       ) -> Tuple[Trace, int]:
    """One tenant's trace; deterministic in ``(tenant, duration, seed,
    stream)``.  Returns ``(trace, next_free_group)``."""
    spec = ARCHETYPES[tenant.scenario]
    if tenant.overrides:
        spec = replace(spec, **tenant.overrides)
    rng = np.random.default_rng((seed, 0x7E1A_17, stream))
    proc = make_arrivals(tenant.arrival, tenant.rate, **tenant.arrival_kw)
    times = proc.times(duration, rng)
    events, next_group = generate_events(spec, tenant.name, times, rng,
                                         group_start)
    meta = {"tenant": tenant.name, "scenario": tenant.scenario,
            "arrival": tenant.arrival, "rate": tenant.rate,
            "duration": duration}
    return Trace(events, seed=seed, meta=meta), next_group


def build_trace(tenants: Sequence[TenantSpec], duration: float,
                seed: int = 0) -> Trace:
    """Superpose per-tenant streams into one arrival-ordered trace.

    Each tenant gets an independent child rng stream (indexed by its
    position) and a disjoint prefix-group range, so the composite is
    deterministic in ``(tenants, duration, seed)`` and per-tenant event
    counts are conserved by the merge."""
    parts: List[Trace] = []
    group_start = 0
    for i, ten in enumerate(tenants):
        tr, group_start = build_tenant_trace(ten, duration, seed,
                                             stream=i,
                                             group_start=group_start)
        parts.append(tr)
    merged = Trace.merge(parts, seed=seed)
    merged.meta["duration"] = duration
    merged.meta["tenants"] = [t.name for t in tenants]
    return merged


def scaled_trace(n_events: int, seed: int = 0,
                 tenants: Optional[Sequence[TenantSpec]] = None) -> Trace:
    """A trace with ~``n_events`` events from the default tenant mix —
    the sizing knob the stress benchmarks use.  Rates stay fixed (the
    traffic SHAPE is the point); duration scales with the target."""
    tenants = list(tenants) if tenants is not None else default_tenants()
    mean_rate = sum(
        make_arrivals(t.arrival, t.rate, **t.arrival_kw).mean_rate()
        * max(ARCHETYPES[t.scenario].turns_mean, 1.0)
        for t in tenants)
    duration = max(n_events / max(mean_rate, 1e-9), 1.0)
    return build_trace(tenants, duration, seed=seed)
