"""Stage 2 of the pipeline: the Quantizer ``Q``.

Representative methods are decomposed into one bucketed group-quantization
primitive plus per-method *bit-allocation plans*:

  - ``uniform``  : same bits everywhere; granularity in {per_head,
                   per_channel (KIVI-K style, groups along tokens),
                   per_token (KIVI-V style, groups along channels)}.
  - ``kivi``     : K per-channel + V per-token, asymmetric, group metadata
                   (reproduces KIVI's ~5.33x metadata-bounded CR ceiling).
  - ``cachegen`` : layer-tiered bits (earlier layers get more bits).
  - ``mixhq``    : the paper's new component — Mixed-Precision Head-Wise
                   quantization.  Retrieval heads keep high precision,
                   streaming heads get ultra-low bits (instead of being
                   pruned).  Generalises to the layer dimension
                   (``layer_pyramid``) and token dimension
                   (``token_heavy_hitter_frac`` — heavy hitters stay high).
  - ``duo``      : DuoAttention-style pruning baseline (streaming heads keep
                   sink+recent tokens only, at source precision).

All quantizers are *exact-byte accounted*: payload bits + fp16 scale/zp
metadata + masks, so measured CR matches what would cross the wire.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.strategy import SCALE_BYTES, SOURCE_BYTES, ZP_BYTES, StrategyConfig

Array = np.ndarray
_EPS = 1e-8


# ---------------------------------------------------------------------------
# Grouped min/max quantization primitive.
# ---------------------------------------------------------------------------
def _pad_to_multiple(x: Array, axis: int, m: int) -> Tuple[Array, int]:
    s = x.shape[axis]
    rem = (-s) % m
    if rem == 0:
        return x, s
    pad_width = [(0, 0)] * x.ndim
    pad_width[axis] = (0, rem)
    return np.pad(x, pad_width, mode="edge"), s


def group_quantize(
    x: Array, bits: int, grouping: str, group_size: int, symmetric: bool
) -> Tuple[Array, Array, Optional[Array]]:
    """Quantize ``x: (N, S, D)`` -> (codes uint8 (N,S,D), scale, zp).

    grouping:
      per_head    — one group per (N) slice
      per_channel — stats per channel over token groups of ``group_size``
      per_token   — stats per token over channel groups of ``group_size``
    """
    assert 1 <= bits <= 8
    n, s, d = x.shape
    qmax = (1 << bits) - 1

    if grouping == "per_head":
        xg = x.reshape(n, 1, s * d)
        axis = 2
    elif grouping == "per_channel":
        xp, s0 = _pad_to_multiple(x, 1, group_size)
        g = xp.shape[1] // group_size
        xg = xp.reshape(n, g, group_size, d)
        axis = 2
    elif grouping == "per_token":
        xp, d0 = _pad_to_multiple(x, 2, group_size)
        g = xp.shape[2] // group_size
        xg = xp.reshape(n, s, g, group_size)
        axis = 3
    else:
        raise ValueError(grouping)

    if symmetric:
        amax = np.abs(xg).max(axis=axis, keepdims=True)
        scale = np.maximum(amax / max((1 << (bits - 1)) - 1, 1), _EPS)
        q = np.clip(np.rint(xg / scale) + (1 << (bits - 1)), 0, qmax)
        zp = None
    else:
        mn = xg.min(axis=axis, keepdims=True)
        mx = xg.max(axis=axis, keepdims=True)
        scale = np.maximum((mx - mn) / qmax, _EPS)
        q = np.clip(np.rint((xg - mn) / scale), 0, qmax)
        zp = mn.astype(np.float16)

    codes = q.astype(np.uint8)
    # Un-reshape codes back to (N, S, D), trimming any padding.
    if grouping == "per_head":
        codes = codes.reshape(n, s, d)
    elif grouping == "per_channel":
        codes = codes.reshape(n, -1, d)[:, :s, :]
    else:
        codes = codes.reshape(n, s, -1)[:, :, :d]
    return codes, scale.astype(np.float16), zp


def group_dequantize(
    codes: Array, scale: Array, zp: Optional[Array], bits: int, grouping: str,
    group_size: int, symmetric: bool,
) -> Array:
    n, s, d = codes.shape
    q = codes.astype(np.float32)
    if grouping == "per_head":
        qg = q.reshape(n, 1, s * d)
    elif grouping == "per_channel":
        qp, _ = _pad_to_multiple(q, 1, group_size)
        qg = qp.reshape(n, -1, group_size, d)
    else:
        qp, _ = _pad_to_multiple(q, 2, group_size)
        qg = qp.reshape(n, s, -1, group_size)

    sc = scale.astype(np.float32)
    if symmetric:
        x = (qg - (1 << (bits - 1))) * sc
    else:
        x = qg * sc + zp.astype(np.float32)

    if grouping == "per_head":
        return x.reshape(n, s, d).astype(np.float32)
    if grouping == "per_channel":
        return x.reshape(n, -1, d)[:, :s, :].astype(np.float32)
    return x.reshape(n, s, -1)[:, :, :d].astype(np.float32)


# ---------------------------------------------------------------------------
# Bucketed representation.
# ---------------------------------------------------------------------------
@dataclass
class QuantBucket:
    """A set of (layer, head) slices quantized with one (bits, grouping)."""

    lh_index: Array  # (N, 2) int32 — (layer, head) of each slice
    bits: int
    grouping: str
    group_size: int
    symmetric: bool
    codes: Array  # (N, S, D) uint8, or float16 for passthrough (bits==16)
    scale: Optional[Array]
    zp: Optional[Array]
    token_index: Optional[Array] = None  # token subset (heavy-hitter / duo)

    def payload_bits(self) -> int:
        if self.bits >= 16:
            return int(self.codes.size) * SOURCE_BYTES * 8
        return int(self.codes.size) * self.bits

    def meta_bytes(self) -> int:
        b = 0
        if self.scale is not None:
            b += self.scale.size * SCALE_BYTES
        if self.zp is not None:
            b += self.zp.size * ZP_BYTES
        b += self.lh_index.size * 2  # uint16 slice ids
        if self.token_index is not None:
            b += self.token_index.size * 4
        return int(b)

    def dequantize(self) -> Array:
        if self.bits >= 16:
            return self.codes.astype(np.float32)
        return group_dequantize(
            self.codes, self.scale, self.zp, self.bits, self.grouping,
            self.group_size, self.symmetric,
        )


@dataclass
class QuantizedTensor:
    """Quantized (L, H, S, D) tensor as buckets; positions absent from every
    bucket are pruned (decode to zero)."""

    shape: Tuple[int, int, int, int]
    buckets: List[QuantBucket] = field(default_factory=list)

    def payload_bits(self) -> int:
        return sum(b.payload_bits() for b in self.buckets)

    def meta_bytes(self) -> int:
        return sum(b.meta_bytes() for b in self.buckets)

    def dequantize(self) -> Array:
        out = np.zeros(self.shape, dtype=np.float32)
        for b in self.buckets:
            x = b.dequantize()  # (N, S', D)
            ls, hs = b.lh_index[:, 0], b.lh_index[:, 1]
            if b.token_index is None:
                out[ls, hs] = x
            else:
                out[ls[:, None], hs[:, None], b.token_index[None, :]] = x
        return out


# ---------------------------------------------------------------------------
# Bit-allocation plans per quantizer.
# ---------------------------------------------------------------------------
def head_importance_scores(k: Array) -> Array:
    """Default retrieval-head proxy score: token-axis dispersion of K.

    Retrieval heads carry token-distinguishing keys (high variance across
    tokens); streaming heads have near-constant keys.  Real deployments can
    inject DuoAttention-style calibrated scores instead (see
    ``repro.core.quality.calibrate_head_scores``).
    """
    # k: (L, H, S, D) -> score (L, H)
    centered = k - k.mean(axis=2, keepdims=True)
    return np.sqrt((centered**2).mean(axis=(2, 3)))


def _tier_bits_per_layer(num_layers: int, tier_bits, tier_fracs) -> Array:
    f1, f2 = tier_fracs
    n1 = max(int(round(num_layers * f1)), 1)
    n2 = max(int(round(num_layers * f2)), 1)
    out = np.full((num_layers,), tier_bits[2], dtype=np.int32)
    out[:n1] = tier_bits[0]
    out[n1 : n1 + n2] = tier_bits[1]
    return out


def _quantize_bucketed(
    x: Array, bits_lh: Array, grouping: str, group_size: int, symmetric: bool
) -> QuantizedTensor:
    """Bucket (l, h) slices by bit-width and quantize each bucket."""
    L, H, S, D = x.shape
    qt = QuantizedTensor(shape=(L, H, S, D))
    for bits in np.unique(bits_lh):
        ls, hs = np.nonzero(bits_lh == bits)
        sl = x[ls, hs]  # (N, S, D)
        if bits >= 16:
            qt.buckets.append(
                QuantBucket(
                    lh_index=np.stack([ls, hs], 1).astype(np.int32),
                    bits=16, grouping="passthrough", group_size=0,
                    symmetric=False, codes=sl.astype(np.float16),
                    scale=None, zp=None,
                )
            )
            continue
        codes, scale, zp = group_quantize(sl, int(bits), grouping, group_size, symmetric)
        qt.buckets.append(
            QuantBucket(
                lh_index=np.stack([ls, hs], 1).astype(np.int32),
                bits=int(bits), grouping=grouping, group_size=group_size,
                symmetric=symmetric, codes=codes, scale=scale, zp=zp,
            )
        )
    return qt


def quantize_tensor(
    x: Array,
    cfg: StrategyConfig,
    is_key: bool,
    head_scores: Optional[Array] = None,
) -> QuantizedTensor:
    """Quantize one transformed (L, H, S, D) tensor according to ``cfg``."""
    L, H, S, D = x.shape

    if cfg.quantizer == "uniform":
        bits = cfg.key_bits if is_key else cfg.value_bits
        bits_lh = np.full((L, H), bits, dtype=np.int32)
        return _quantize_bucketed(x, bits_lh, cfg.granularity, cfg.group_size,
                                  cfg.symmetric)

    if cfg.quantizer == "kivi":
        bits = cfg.key_bits if is_key else cfg.value_bits
        grouping = "per_channel" if is_key else "per_token"
        bits_lh = np.full((L, H), bits, dtype=np.int32)
        return _quantize_bucketed(x, bits_lh, grouping, cfg.group_size, False)

    if cfg.quantizer == "cachegen":
        per_layer = _tier_bits_per_layer(L, cfg.tier_bits, cfg.tier_fracs)
        bits_lh = np.broadcast_to(per_layer[:, None], (L, H)).copy()
        return _quantize_bucketed(x, bits_lh, "per_channel", cfg.group_size,
                                  cfg.symmetric)

    if cfg.quantizer == "mixhq":
        return _quantize_mixhq(x, cfg, head_scores)

    if cfg.quantizer == "duo":
        return _quantize_duo(x, cfg, head_scores)

    raise ValueError(cfg.quantizer)


def _resolve_head_scores(x: Array, head_scores: Optional[Array]) -> Array:
    if head_scores is not None:
        assert head_scores.shape == x.shape[:2], (head_scores.shape, x.shape)
        return head_scores
    return head_importance_scores(x)


def _retrieval_mask(scores: Array, frac: float) -> Array:
    """Boolean (L, H): top ``frac`` heads per layer are retrieval heads."""
    L, H = scores.shape
    k = max(int(round(H * frac)), 0)
    mask = np.zeros((L, H), dtype=bool)
    if k > 0:
        idx = np.argsort(-scores, axis=1)[:, :k]
        mask[np.arange(L)[:, None], idx] = True
    return mask


def _quantize_mixhq(x: Array, cfg: StrategyConfig,
                    head_scores: Optional[Array]) -> QuantizedTensor:
    """MixHQ: variable precision allocation instead of binary pruning."""
    L, H, S, D = x.shape
    scores = _resolve_head_scores(x, head_scores)
    retrieval = _retrieval_mask(scores, cfg.retrieval_frac)

    bits_lh = np.where(retrieval, cfg.mixhq_high_bits, cfg.mixhq_low_bits).astype(np.int32)
    if cfg.layer_pyramid:
        # Deeper third of layers: shave one more bit off streaming heads.
        deep = np.arange(L) >= (2 * L) // 3
        shave = deep[:, None] & ~retrieval
        bits_lh = np.where(shave, np.maximum(bits_lh - 1, 1), bits_lh)

    hh_frac = cfg.token_heavy_hitter_frac
    if hh_frac <= 0.0:
        return _quantize_bucketed(x, bits_lh, "per_channel", cfg.group_size,
                                  cfg.symmetric)

    # Token-dimension generalisation (SnapKV-style heavy hitters): globally
    # shared heavy token set stays at high bits inside streaming heads.
    tok_norm = np.sqrt((x**2).mean(axis=(0, 1, 3)))  # (S,)
    k = max(int(round(S * hh_frac)), 1)
    heavy_idx = np.sort(np.argsort(-tok_norm)[:k])
    light_idx = np.setdiff1d(np.arange(S), heavy_idx)

    qt = QuantizedTensor(shape=(L, H, S, D))
    qt_buckets: List[QuantBucket] = []
    # Retrieval heads: all tokens at high bits.
    ls, hs = np.nonzero(retrieval)
    if len(ls):
        sl = x[ls, hs]
        codes, scale, zp = group_quantize(sl, cfg.mixhq_high_bits, "per_channel",
                                          cfg.group_size, cfg.symmetric)
        qt_buckets.append(QuantBucket(np.stack([ls, hs], 1).astype(np.int32),
                                      cfg.mixhq_high_bits, "per_channel",
                                      cfg.group_size, cfg.symmetric, codes, scale, zp))
    ls, hs = np.nonzero(~retrieval)
    if len(ls):
        stream_bits = bits_lh[ls, hs]
        for bits in np.unique(stream_bits):
            sel = stream_bits == bits
            lss, hss = ls[sel], hs[sel]
            heavy = x[lss, hss][:, heavy_idx, :]
            light = x[lss, hss][:, light_idx, :]
            ch, sch, zph = group_quantize(heavy, cfg.mixhq_high_bits, "per_channel",
                                          cfg.group_size, cfg.symmetric)
            cl, scl, zpl = group_quantize(light, int(bits), "per_channel",
                                          cfg.group_size, cfg.symmetric)
            idx = np.stack([lss, hss], 1).astype(np.int32)
            qt_buckets.append(QuantBucket(idx, cfg.mixhq_high_bits, "per_channel",
                                          cfg.group_size, cfg.symmetric, ch, sch,
                                          zph, token_index=heavy_idx))
            qt_buckets.append(QuantBucket(idx, int(bits), "per_channel",
                                          cfg.group_size, cfg.symmetric, cl, scl,
                                          zpl, token_index=light_idx))
    qt.buckets = qt_buckets
    return qt


def _quantize_duo(x: Array, cfg: StrategyConfig,
                  head_scores: Optional[Array]) -> QuantizedTensor:
    """DuoAttention baseline: streaming heads keep sink+recent only (fp16)."""
    L, H, S, D = x.shape
    scores = _resolve_head_scores(x, head_scores)
    retrieval = _retrieval_mask(scores, cfg.retrieval_frac)
    keep_idx = np.unique(
        np.concatenate([
            np.arange(min(cfg.duo_sink, S)),
            np.arange(max(S - cfg.duo_recent, 0), S),
        ])
    )

    qt = QuantizedTensor(shape=(L, H, S, D))
    ls, hs = np.nonzero(retrieval)
    if len(ls):
        qt.buckets.append(QuantBucket(
            np.stack([ls, hs], 1).astype(np.int32), 16, "passthrough", 0, False,
            x[ls, hs].astype(np.float16), None, None,
        ))
    ls, hs = np.nonzero(~retrieval)
    if len(ls):
        qt.buckets.append(QuantBucket(
            np.stack([ls, hs], 1).astype(np.int32), 16, "passthrough", 0, False,
            x[ls, hs][:, keep_idx, :].astype(np.float16), None, None,
            token_index=keep_idx,
        ))
    return qt
