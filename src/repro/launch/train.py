"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real training on the available devices (reduced configs on CPU; the
full configs compile via the dry-run).  Includes checkpoint/restart, WSD or
cosine schedules, optional gradient compression with error feedback, and a
crash-recovery path (restore latest checkpoint and continue).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.synthetic import make_batch
from repro.distribution.grad_compress import init_ef_state, make_grad_transform
from repro.distribution.optimizer import OptConfig, init_opt_state
from repro.distribution.steps import make_train_step
from repro.models import init_params


def train(arch: str, steps: int = 100, batch: int = 8, seq: int = 128,
          lr: float = 3e-3, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, grad_compress_bits: int = 0,
          resume: bool = True, seed: int = 0, log_every: int = 10):
    cfg = get_config(arch)
    if cfg.vocab_size > 4096:
        print(f"[train] full config {arch} is dry-run-only on CPU; "
              f"use '{arch}-reduced'")
    params, _ = init_params(cfg, seed=seed)
    oc = OptConfig(lr=lr, warmup_steps=max(steps // 10, 5), total_steps=steps,
                   schedule=cfg.lr_schedule, weight_decay=0.01)
    opt_state = init_opt_state(params)

    grad_transform = None
    if grad_compress_bits:
        grad_transform = make_grad_transform(bits=grad_compress_bits)
        opt_state["ef"] = init_ef_state(params)

    step_fn = jax.jit(make_train_step(cfg, oc, remat=False,
                                      grad_transform=grad_transform))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr is not None and resume and mgr.latest_step() is not None:
        state_tpl = {"params": params, "opt": opt_state}
        restored = mgr.restore(state_tpl)
        params, opt_state = restored["params"], restored["opt"]
        start = mgr.latest_step()
        print(f"[train] resumed from step {start}")

    losses = []
    t0 = time.time()
    for i in range(start, steps):
        tokens, mask = make_batch("mixed", batch, seq, seed=seed * 99991 + i)
        b = {"tokens": jnp.asarray(tokens), "mask": jnp.asarray(mask[:, 1:])}
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1}/{steps} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(i+1-start,1)*1e3:.0f} ms/step)")
        if mgr is not None and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state},
                     metadata={"loss": losses[-1]}, background=True)
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state},
                 metadata={"loss": losses[-1] if losses else float("nan")})
        mgr.wait()
    return params, opt_state, losses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _, _, losses = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir or None,
        ckpt_every=args.ckpt_every,
        grad_compress_bits=args.grad_compress_bits, seed=args.seed)
    print(f"final loss: {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
