"""The Offline Profiling stage end-to-end: Bayesian search over the module
space on one workload, Pareto distillation, and the per-bucket policy table
(lower envelopes) the online controller uses.

    PYTHONPATH=src python examples/offline_profiling.py
"""
from repro.controller import build_envelope
from repro.launch.profile_offline import search_and_build
from repro.serving.network import GBPS


def main():
    # summlike tolerates compression well; qalike (needle retrieval) is the
    # adversarial case — try workload="qalike", acc_threshold=0.6 to see the
    # constraint bite.
    profiles, frontier = search_and_build(
        level="module", workload="summlike", acc_threshold=0.85,
        max_iters=30, verbose=True)

    print(f"\n{len(profiles)} measured profiles; "
          f"{len(frontier)} on the 3D Pareto frontier:")
    for pt in sorted(frontier, key=lambda p: -p.cr):
        print(f"  acc={pt.acc:.3f} cr={pt.cr:5.2f} lat/B={pt.lat:.3e}  "
              f"{pt.profile.strategy.short_name()}")

    env = build_envelope([pt.profile for pt in frontier])
    print(f"\npiecewise policy (lower envelope, {len(env.lines)} segments):")
    prev = 0.0
    for i, line in enumerate(env.lines):
        hi = env.breaks[i] if i < len(env.breaks) else float("inf")
        lo_b = (1.0 / hi) / GBPS if hi > 0 else float("inf")
        hi_b = (1.0 / prev) / GBPS if prev > 0 else float("inf")
        print(f"  B in ({lo_b:8.3f}, {hi_b:8.3f}] Gbps -> "
              f"{line.profile.strategy.short_name()}")
        prev = hi


if __name__ == "__main__":
    main()
