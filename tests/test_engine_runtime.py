"""Continuous-batching ServingRuntime e2e on the real tiny model."""
import numpy as np
import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import BandwidthTrace, GBPS, PrefixKVStore, SchedulerConfig


def _profile():
    # 8-bit per-channel: real compression on the pool path, near-lossless.
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel"),
                   cr=2.0, s_enc=5e8, s_dec=5e8)


def _runtime(reference_model, **kw):
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    cfg = RuntimeConfig(seq=64, decode_tokens=6,
                        prefill_tok_s=2000.0, decode_tok_s=500.0)
    defaults = dict(
        static_profile=_profile(), config=cfg,
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=32))
    defaults.update(kw)
    rt = ServingRuntime(**defaults)
    # pin the session-cached reference model (avoids retraining paths)
    rt.model_cfg, rt.params = reference_model
    return rt


@pytest.mark.slow
def test_pool_hit_beats_cold_prefill_ttft(reference_model):
    """The paper's TTFT path: a prefix-pool hit (fetch real compressed
    bytes + decompress + inject) must beat recomputing prefill."""
    rt = _runtime(reference_model)
    cold_rid = rt.submit("qalike", prompt_seed=42)
    rt.run()
    assert len(rt.store) == 1  # prefix written back to the pool
    hit_rid = rt.submit("qalike", prompt_seed=42)  # identical prompt
    rt.run()

    by_rid = {r.rid: r for r in rt.completed}
    cold, hit = by_rid[cold_rid], by_rid[hit_rid]
    assert not cold.pool_hit and hit.pool_hit
    assert hit.ttft < cold.ttft
    assert hit.breakdown["comm"] > 0 and hit.breakdown.get("prefill", 0) == 0
    assert cold.breakdown["prefill"] > 0
    assert cold.t_pool_write > 0 and hit.t_pool_write == 0
    # real bytes moved: the hit fetched exactly what the cold request stored
    assert hit.wire_bytes == cold.wire_bytes > 0
    assert hit.wire_bytes < cold.kv_bytes  # compressed on the wire
    # both generated a full completion
    assert len(hit.tokens) == len(cold.tokens) == rt.cfg.decode_tokens + 1
    assert rt.store.stats.hits == 1


@pytest.mark.slow
def test_runtime_sustains_concurrent_in_flight_requests(reference_model):
    rt = _runtime(reference_model)
    rids = [rt.submit(w, prompt_seed=i) for i, w in enumerate(
        ("qalike", "codelike", "mathlike", "summlike", "qalike", "codelike"))]
    assert all(r is not None for r in rids)
    done = rt.run()
    assert len(done) == 6
    assert rt.max_in_flight() >= 4  # continuous batching, not one-by-one
    for r in done:
        assert r.jct >= r.ttft > 0
        total = sum(r.breakdown.values())
        assert total == pytest.approx(r.jct, abs=1e-6), (r.breakdown, r.jct)


@pytest.mark.slow
def test_runtime_admission_and_slo_priority(reference_model):
    rt = _runtime(reference_model,
                  scheduler=SchedulerConfig(max_slots=2,
                                            max_prefills_per_step=1,
                                            max_queue=4, aging_s=0.0))
    assert rt.submit("qalike", slo_class="batch", prompt_seed=0) is not None
    assert rt.submit("qalike", slo_class="batch", prompt_seed=1) is not None
    assert rt.submit("qalike", slo_class="batch", prompt_seed=2) is not None
    assert rt.submit("qalike", slo_class="interactive",
                     prompt_seed=3) is not None
    # queue bound (4) reached -> load shed
    assert rt.submit("qalike", slo_class="batch", prompt_seed=4) is None
    rt.run()
    assert len(rt.completed) == 4
    # the interactive request jumped the batch queue: first token first
    inter = [r for r in rt.completed if r.slo_class == "interactive"][0]
    batch_ttfts = [r.ttft for r in rt.completed if r.slo_class == "batch"]
    assert inter.ttft <= min(batch_ttfts)


@pytest.mark.slow
def test_store_eviction_under_tiny_capacity(reference_model):
    store = PrefixKVStore(capacity_bytes=40_000, block=16)
    rt = _runtime(reference_model, store=store)
    for i in range(4):
        rt.submit("codelike", prompt_seed=100 + i)
        rt.run()
    assert store.used_bytes <= store.capacity_bytes
    assert store.stats.evictions > 0 or store.stats.rejected_puts > 0
