"""Trace replay invariants across both serving backends (ISSUE 6).

* Simulator replay (topology mode, both routing policies): per-request
  breakdowns sum exactly to JCT, ``wire_wait`` is accounted, and every
  request carries its stamped route.
* The inlined fast PD path must be bit-identical to the general event
  loop — same breakdowns, same outcomes, same estimator state.
* Cluster replay (real-execution N x M ClusterRuntime over a bursty
  trace): the breakdown-sum == JCT property extends to the runtime,
  ``wire_wait``/``stall`` included, routes stamped.
"""
import numpy as np
import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import BandwidthTrace, GBPS, NetworkTopology, \
    SchedulerConfig
from repro.serving.simulator import SimConfig, Simulator, StaticPolicy
from repro.workloads import TenantSpec, build_trace, replay_runtime, \
    replay_simulator, trace_requests

BREAKDOWN_ABS = 1e-9


def _profile(cr=3.5):
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel"),
                   cr=cr, s_enc=60.0 * GBPS, s_dec=80.0 * GBPS,
                   quality=0.995)


def _bursty_trace(duration=25.0, seed=42):
    """Mixed diurnal + on-off traffic: bursts guarantee queueing and
    wire contention, so the properties are checked under load."""
    tenants = [
        TenantSpec("chat", "chat", 3.0, "diurnal", {"amplitude": 0.7}),
        TenantSpec("agents", "agentic", 0.8, "mmpp",
                   {"mean_on": 3.0, "mean_off": 6.0}),
    ]
    return build_trace(tenants, duration=duration, seed=seed)


# ---------------------------------------------------------------------------
# Simulator replay over a per-link topology
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("routing", ["round_robin", "load_aware"])
def test_sim_replay_breakdowns_sum_to_jct(routing):
    trace = _bursty_trace()
    topo = NetworkTopology.full_mesh(
        2, 2, BandwidthTrace.constant(2 * GBPS),
        links={(0, 1): BandwidthTrace.constant(0.5 * GBPS)})
    res = replay_simulator(
        trace, StaticPolicy(_profile(), "u8"),
        BandwidthTrace.constant(2 * GBPS),
        SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0),
        topology=topo, routing=routing)
    done = res.completed()
    assert len(done) == len(trace)
    assert any(r.breakdown.get("wire_wait", 0.0) > 0 for r in done), \
        "bursty trace should contend on at least one link"
    for r in done:
        assert sum(r.breakdown.values()) == pytest.approx(
            r.jct, abs=BREAKDOWN_ABS), (r.rid, r.breakdown, r.jct)
        assert 0 < r.ttft <= r.jct + 1e-12
        assert "wire_wait" in r.breakdown
        assert r.route and r.route.startswith("p") and "->d" in r.route
        assert all(v >= -1e-12 for v in r.breakdown.values()), r.breakdown


def test_sim_replay_flat_breakdowns_sum_to_jct():
    """Same property on the flat (no-topology) PD path, which dispatches
    through the inlined fast loop for static policies."""
    trace = _bursty_trace()
    res = replay_simulator(
        trace, StaticPolicy(_profile(), "u8"),
        BandwidthTrace.constant(2 * GBPS),
        SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0))
    done = res.completed()
    assert len(done) == len(trace)
    for r in done:
        assert sum(r.breakdown.values()) == pytest.approx(
            r.jct, abs=BREAKDOWN_ABS), (r.rid, r.breakdown, r.jct)
        assert 0 < r.ttft <= r.jct + 1e-12


# ---------------------------------------------------------------------------
# Fast PD path == general event loop, bit for bit
# ---------------------------------------------------------------------------
def test_fast_pd_path_is_bit_identical_to_general_loop():
    trace = _bursty_trace(duration=40.0, seed=9)
    cfg = SimConfig(scenario="pd", n_prefill=3, n_decode=2,
                    straggler_sigma=0.15, seed=0)
    bw = BandwidthTrace.steps([(0.0, 2 * GBPS), (10.0, 0.6 * GBPS),
                               (20.0, 4 * GBPS)])

    fast_pol = StaticPolicy(_profile(), "u8")
    sim_fast = Simulator(cfg, fast_pol, bw, trace_requests(trace))
    assert sim_fast._fast_pd_eligible()
    res_fast = sim_fast.run()

    slow_pol = StaticPolicy(_profile(), "u8")
    slow_pol.needs_ctx = True          # forces the general event loop
    sim_slow = Simulator(cfg, slow_pol, bw, trace_requests(trace))
    assert not sim_slow._fast_pd_eligible()
    res_slow = sim_slow.run()

    for a, b in zip(res_fast.requests, res_slow.requests):
        assert a.rid == b.rid
        assert a.done == b.done, a.rid
        assert a.ttft == b.ttft, a.rid
        assert a.chosen == b.chosen
        assert a.slo_violated == b.slo_violated
        assert a.breakdown == b.breakdown, a.rid
    assert sim_fast.estimator._est == sim_slow.estimator._est
    assert res_fast.summary() == res_slow.summary()


# ---------------------------------------------------------------------------
# Real-execution cluster replay
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["pd", "pool"])
def test_cluster_replay_breakdowns_sum_to_jct(reference_model, mode):
    """Replaying a bursty trace through a 2x2 ClusterRuntime preserves
    the breakdown accounting identity per completed request, with
    ``wire_wait``/``stall`` terms included and routes stamped."""
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.engine import RuntimeConfig

    rt = ClusterRuntime(
        static_profile=_profile(cr=2.0),
        config=RuntimeConfig(seq=48, decode_tokens=4, prefill_tok_s=2000.0,
                             decode_tok_s=500.0, mode=mode),
        trace=BandwidthTrace.constant(0.5 * GBPS),
        scheduler=SchedulerConfig(max_slots=4, max_prefills_per_step=2,
                                  max_queue=64),
        n_prefill=2, n_decode=2)
    rt.model_cfg, rt.params = reference_model
    trace = _bursty_trace(duration=4.0, seed=21)
    assert 6 <= len(trace) <= 64      # bursty but runtime-sized
    done = replay_runtime(rt, trace)
    assert len(done) == len(trace)
    assert any(r.route for r in done)
    for r in done:
        assert sum(r.breakdown.values()) == pytest.approx(
            r.jct, abs=BREAKDOWN_ABS), (mode, r.rid, r.breakdown, r.jct)
        assert 0 < r.ttft <= r.jct + 1e-12
        assert all(v >= -1e-12 for v in r.breakdown.values()), r.breakdown
        assert r.route and "->" in r.route
