"""Roofline summary rows from the dry-run artifacts (deliverable g).

Reads dryrun_single.jsonl / dryrun_multi.jsonl when present (produced by
``python -m repro.launch.dryrun --arch all --shape all --mesh both``);
otherwise lowers a small representative subset live.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from benchmarks.common import emit

ROOT = Path(__file__).parent.parent


def _rows(path: Path):
    if not path.exists():
        return []
    return [json.loads(l) for l in path.open() if l.strip()]


def run(smoke: bool = False) -> None:
    # reads pre-computed dry-run artifacts (or reports them missing):
    # the smoke path IS the full path
    for mesh_name, fname in (("single", "dryrun_single.jsonl"),
                             ("multi", "dryrun_multi.jsonl")):
        rows = [r for r in _rows(ROOT / fname) if r.get("status") == "ok"]
        if not rows:
            emit(f"roofline_{mesh_name}", 0.0,
                 f"missing {fname} — run repro.launch.dryrun")
            continue
        dominant = {}
        for r in rows:
            emit(f"roofline_{mesh_name}_{r['arch']}_{r['shape']}",
                 r.get("compile_seconds", 0.0) * 1e6,
                 f"tc={r['t_compute']:.3e} tm={r['t_memory']:.3e} "
                 f"tx={r['t_collective']:.3e} dom={r['dominant']} "
                 f"useful={r['useful_ratio']:.2f}")
            dominant[r["dominant"]] = dominant.get(r["dominant"], 0) + 1
            if "transfer" in r:
                t = r["transfer"]
                emit(f"kvxfer_{mesh_name}_{r['arch']}", 0.0,
                     f"coll_bytes={t['coll_bytes']:.3e} "
                     f"tx={t['t_collective']:.4f}s")
        emit(f"roofline_{mesh_name}_summary", 0.0,
             f"cells={len(rows)} dominant={dominant}")


if __name__ == "__main__":
    run()
