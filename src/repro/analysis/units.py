"""units: dimensional consistency from the repo's naming conventions.

The serving stack and the KVServe latency model (Eq. 1) juggle four
dimensions that all live in bare floats: payload sizes in **bytes**
(``*_bytes``, ``nbytes``, ``payload``, the paper's V), wall/virtual
times in **seconds** (``t_*``, ``now``, ``free_at``, ``*_latency``),
link **bandwidths** in bytes/s (``*_bw``, ``bandwidth``, ``goodput``,
the paper's B, codec speeds ``s_enc``/``s_dec``), and **token** counts /
rates (``*_tokens``, ``*_tok_s``).  A bytes-vs-seconds slip type-checks
fine and only shows up as a wrong crossover plot.

The rule infers a dimension *tag* for each name (variable, attribute,
call) from these conventions and flags:

* ``+``/``-``/comparisons mixing two *different* known tags,
* assignments storing a known tag into a name carrying a different one,
* ``max``/``min`` over mixed known tags.

Division and multiplication are the sanctioned conversions
(bytes / bandwidth -> seconds, tokens / tok_s -> seconds, ...).  Names
that match no convention stay untagged and never flag — the rule is
deliberately low-noise.

Scope: ``serving/`` (incl. the simulator), ``controller/``,
``workloads/`` and ``distribution/``.  Suppression token: ``units-ok``.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from repro.analysis.core import Finding, Project, SourceFile, dotted, func_defs

RULE_ID = "units"
TOKEN = "units-ok"

BYTES, SECONDS, BW, TOKENS, TOKRATE = \
    "bytes", "seconds", "bytes/s", "tokens", "tokens/s"

# Ordered: first match wins (the tok/s patterns must pre-empt `_s$`).
NAME_TAGS = [
    (re.compile(r"(_tok_s|_tok_rate)$"), TOKRATE),
    (re.compile(r"^(bw|bandwidth|goodput|rate|estimate|B)$"
                r"|(_bw|_bandwidth|_goodput)$"
                r"|^s_(enc|dec|eff|p)$"), BW),
    (re.compile(r"^n?bytes$|_bytes$|^bytes_|^payload$"), BYTES),
    (re.compile(r"_tokens$"), TOKENS),
    (re.compile(r"^t[0-9]?$|^t_"
                r"|(_time|_latency|_seconds|_wait|_delay|_overhead|_s)$"
                r"|^(now|free_at|ready|arrival|done|deadline|ttft|jct"
                r"|elapsed|wall|dur|slack|start|end|cost|iter_cost)$"
                r"|_cost$"), SECONDS),
]

CALL_TAGS = [
    (re.compile(r"(_time|_latency|_seconds|_s|_cost|_wait)$"
                r"|^(perf_counter|codec_cost)$"), SECONDS),
    (re.compile(r"_bytes$|^n?bytes\w*$|^kv_bytes_for$"), BYTES),
]

DIV_RESULTS = {
    (BYTES, BW): SECONDS,
    (BYTES, SECONDS): BW,
    (TOKENS, TOKRATE): SECONDS,
    (TOKENS, SECONDS): TOKRATE,
}
MUL_RESULTS = {
    (BW, SECONDS): BYTES, (SECONDS, BW): BYTES,
    (TOKRATE, SECONDS): TOKENS, (SECONDS, TOKRATE): TOKENS,
}


def _name_tag(name: str) -> Optional[str]:
    for pat, tag in NAME_TAGS:
        if pat.search(name):
            return tag
    return None


def _call_tag(name: str) -> Optional[str]:
    for pat, tag in CALL_TAGS:
        if pat.search(name):
            return tag
    return None


def _in_scope(f: SourceFile) -> bool:
    return (f.in_dir("serving") or f.in_dir("controller")
            or f.in_dir("workloads") or f.in_dir("distribution")) \
        and not f.in_dir("tests")


class _Tagger:
    def __init__(self, f: SourceFile):
        self.f = f
        self.findings: List[Finding] = []

    def _flag(self, node: ast.AST, what: str) -> None:
        self.findings.append(Finding(
            RULE_ID, self.f.rel, node.lineno, what,
            "insert the conversion (divide by a bandwidth/rate), or "
            "rename the variable to match its dimension; annotate "
            "`# lint: units-ok(reason)` if the mix is intentional"))

    # -- expression tags ----------------------------------------------------
    def tag(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return _name_tag(node.id)
        if isinstance(node, ast.Attribute):
            return _name_tag(node.attr)
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d in ("float", "int", "abs", "round"):
                return self.tag(node.args[0]) if node.args else None
            if d in ("max", "min"):
                tags = {t for t in (self.tag(a) for a in node.args) if t}
                if len(tags) > 1:
                    self._flag(node, f"{d}() over mixed dimensions "
                                     f"({', '.join(sorted(tags))})")
                    return None
                return next(iter(tags), None)
            tail = d.rsplit(".", 1)[-1]
            return _call_tag(tail) if tail else None
        if isinstance(node, ast.UnaryOp):
            return self.tag(node.operand)
        if isinstance(node, ast.BinOp):
            return self._binop(node)
        if isinstance(node, ast.IfExp):
            a, b = self.tag(node.body), self.tag(node.orelse)
            if a and b and a != b:
                self._flag(node, f"conditional mixes dimensions "
                                 f"({a} vs {b})")
                return None
            return a or b
        if isinstance(node, ast.Compare):
            self._compare(node)
            return None
        return None

    def _binop(self, node: ast.BinOp) -> Optional[str]:
        lt, rt = self.tag(node.left), self.tag(node.right)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if lt and rt and lt != rt:
                self._flag(node, f"{'+' if isinstance(node.op, ast.Add) else '-'} "
                                 f"mixes dimensions: {lt} "
                                 f"{'+' if isinstance(node.op, ast.Add) else '-'} "
                                 f"{rt}")
                return None
            return lt or rt
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            if lt and rt:
                return DIV_RESULTS.get((lt, rt))
            return None
        if isinstance(node.op, ast.Mult):
            if lt and rt:
                return MUL_RESULTS.get((lt, rt))
            return None
        return None

    def _compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        tags = [self.tag(o) for o in operands]
        for (a, ta), (b, tb) in zip(zip(operands, tags),
                                    zip(operands[1:], tags[1:])):
            if ta and tb and ta != tb:
                self._flag(node, f"comparison mixes dimensions: "
                                 f"{ta} vs {tb}")

    # -- statements ---------------------------------------------------------
    def _check_assign(self, target: ast.AST, value_tag: Optional[str],
                      node: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            return  # tuple-unpack: element tags unknown from one value tag
        name = target.id if isinstance(target, ast.Name) else (
            target.attr if isinstance(target, ast.Attribute) else None)
        if name is None:
            return
        nt = _name_tag(name)
        if nt and value_tag and nt != value_tag:
            self._flag(node, f"`{name}` ({nt}) assigned a {value_tag} "
                             f"value")

    def run(self) -> List[Finding]:
        for fn in func_defs(self.f.tree):
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    vt = self.tag(node.value)
                    for tgt in node.targets:
                        self._check_assign(tgt, vt, node)
                elif isinstance(node, ast.AugAssign) and \
                        isinstance(node.op, (ast.Add, ast.Sub)):
                    vt = self.tag(node.value)
                    self._check_assign(node.target, vt, node)
                elif isinstance(node, (ast.BinOp, ast.Compare, ast.IfExp)):
                    pass  # reached via parents below
            # one tagging pass over every top-level expression: BinOp /
            # Compare flags fire inside tag()
            for node in ast.walk(fn):
                if isinstance(node, (ast.BinOp, ast.Compare)):
                    self.tag(node)
        # dedupe (same BinOp reached via parent and via walk)
        seen = set()
        uniq = []
        for fd in self.findings:
            key = (fd.line, fd.message)
            if key not in seen:
                seen.add(key)
                uniq.append(fd)
        return uniq


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for f in project.matching(_in_scope):
        findings.extend(_Tagger(f).run())
    return findings
