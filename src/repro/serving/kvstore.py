"""Compressed prefix-KV pool store (the paper's KV-disaggregated scenario).

The pool holds :class:`repro.core.pipeline.CompressedKV` payloads (or, for
the event-driven simulator, opaque payloads with the same byte accounting)
keyed by the token prefix that produced them.  Three properties matter for
reproducing the paper's TTFT path (Sec. 7.2 / Fig. 14):

  * **Prefix matching** — lookups walk block-aligned prefixes of the query
    tokens from longest to shortest, so a request whose prompt extends a
    stored prefix still hits (vLLM-style hash-chain prefix caching).
  * **Wire-byte capacity accounting** — the store is a *network-attached*
    pool; what occupies it is the compressed wire footprint, not logical
    KV bytes.  ``used_bytes == sum(entry.wire_bytes) <= capacity_bytes``
    is an invariant after every operation.
  * **SLO-aware LRU eviction** — victims are chosen lowest-SLO-class first
    (batch before standard before interactive), least-recently-used within
    a class, so latency-critical tenants keep their prefixes warm.

Since ISSUE 4 the flat pool generalizes to a :class:`TieredKVStore` — an
ordered memory hierarchy (device-adjacent HBM, host DRAM, remote pool).
Each tier owns a capacity, a serialized fetch link
(:class:`~repro.serving.network.KVWire` over its own
:class:`~repro.serving.network.BandwidthTrace`), and an optional demotion
re-compression profile.  Hits fetch from the tier that holds the prefix
and **promote** on access; capacity pressure **demotes** victims down the
hierarchy (re-compressing with the destination tier's profile) instead of
dropping them — only the last tier truly evicts.

Shared by the real-execution :class:`~repro.serving.engine.ServingRuntime`
and the event-driven :class:`~repro.serving.simulator.Simulator` so both
exercise one placement/eviction code path (DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.network import BandwidthTrace, KVWire, WireTransfer

TokenKey = Tuple[int, ...]

# Rank of each SLO class; lower = more latency-critical = evicted last.
SLO_CLASSES: Dict[str, int] = {"interactive": 0, "standard": 1, "batch": 2}


def slo_rank(slo_class: str) -> int:
    return SLO_CLASSES.get(slo_class, SLO_CLASSES["standard"])


@dataclass
class StoreEntry:
    tokens: TokenKey          # full token prefix this entry caches
    payload: Any              # CompressedKV (+ first token) or sim stand-in
    wire_bytes: int           # compressed wire footprint (capacity unit)
    kv_bytes: float = 0.0     # uncompressed payload V (for fetch modelling)
    workload: str = ""
    slo_class: str = "standard"
    created: float = 0.0
    last_used: float = 0.0
    hits: int = 0

    @property
    def rank(self) -> int:
        return slo_rank(self.slo_class)


@dataclass
class StoreStats:
    hits: int = 0
    misses: int = 0
    # full=True lookups that found no full-coverage entry but DID have a
    # usable block-aligned partial prefix — not a true miss (the prefix is
    # warm; the consumer just can't top-up-prefill the uncovered suffix).
    partial_misses: int = 0
    evictions: int = 0
    # payload alone exceeded capacity, OR making room would have evicted
    # an entry of strictly more critical SLO rank (never allowed).
    rejected_puts: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses + self.partial_misses
        return self.hits / n if n else 0.0


class PrefixKVStore:
    """Bounded pool of compressed KV prefixes with SLO-aware LRU eviction."""

    def __init__(self, capacity_bytes: int, block: int = 16):
        # capacity 0 is legal (a disabled tier in a TieredKVStore: every
        # put is oversize and falls through to the next tier).
        assert capacity_bytes >= 0 and block > 0
        self.capacity_bytes = int(capacity_bytes)
        self.block = int(block)
        self._entries: Dict[TokenKey, StoreEntry] = {}
        self.used_bytes = 0
        self.stats = StoreStats()

    # ------------------------------------------------------------------
    def _prefix_keys(self, tokens: TokenKey) -> List[TokenKey]:
        """Candidate keys, longest first: the full prefix, then every
        block-aligned truncation."""
        tokens = tuple(tokens)
        keys = [tokens]
        n = (len(tokens) - 1) // self.block * self.block
        while n > 0:
            keys.append(tokens[:n])
            n -= self.block
        return keys

    # ------------------------------------------------------------------
    def lookup(self, tokens: TokenKey, now: float = 0.0,
               full: bool = False) -> Optional[StoreEntry]:
        """Longest stored prefix of ``tokens`` (None on miss).  Updates
        recency and hit/miss counters.

        ``full=True`` only accepts an entry covering *all* of ``tokens`` —
        for consumers that cannot top-up-prefill the uncovered suffix of a
        partial prefix match (e.g. the real-execution runtime).

        Entries are only visible once their pool write has completed:
        ``put`` stamps ``created`` with the write-completion time, and a
        lookup at an earlier ``now`` misses (no time-travel hits)."""
        keys = ([tuple(tokens)] if full else self._prefix_keys(tokens))
        for key in keys:
            e = self._entries.get(key)
            if e is not None and e.created <= now:
                e.last_used = now
                e.hits += 1
                self.stats.hits += 1
                return e
        if full and any(
                e is not None and e.created <= now
                for e in (self._entries.get(k)
                          for k in self._prefix_keys(tokens)[1:])):
            # A usable partial prefix exists; the full=True consumer just
            # cannot exploit it.  Distinct from a cold miss.
            self.stats.partial_misses += 1
        else:
            self.stats.misses += 1
        return None

    def contains(self, tokens: TokenKey, now: float = 0.0) -> bool:
        """Exact-key presence under the same write-visibility rule as
        :meth:`lookup`: an entry whose pool write completes after ``now``
        is not visible yet (no time-traveling entries).  Does not touch
        recency or hit/miss counters."""
        e = self._entries.get(tuple(tokens))
        return e is not None and e.created <= now

    # ------------------------------------------------------------------
    def _evict_order(self) -> List[StoreEntry]:
        """Victims first: lowest SLO priority (highest rank), then LRU."""
        return sorted(self._entries.values(),
                      key=lambda e: (-e.rank, e.last_used))

    def _make_room(self, need: int, rank: int) -> Optional[List[StoreEntry]]:
        """Evict until ``need`` bytes fit, lowest-priority-first — but an
        insert of SLO rank ``rank`` must NEVER evict an entry of strictly
        more critical rank (lower number).  Returns the evicted entries,
        or None when room cannot be made without such an inversion (the
        caller rejects/demotes the insert; nothing is evicted then)."""
        if self.used_bytes + need <= self.capacity_bytes:
            return []
        eligible = [e for e in self._evict_order() if e.rank >= rank]
        freeable = sum(e.wire_bytes for e in eligible)
        if self.used_bytes - freeable + need > self.capacity_bytes:
            return None
        evicted: List[StoreEntry] = []
        while self.used_bytes + need > self.capacity_bytes:
            victim = eligible.pop(0)
            del self._entries[victim.tokens]
            self.used_bytes -= victim.wire_bytes
            self.stats.evictions += 1
            evicted.append(victim)
        return evicted

    # ------------------------------------------------------------------
    def discard(self, tokens: TokenKey) -> Optional[StoreEntry]:
        """Silently remove and return the exact-key entry (None if absent).
        No stats are touched — this is the tiered store's move primitive."""
        e = self._entries.pop(tuple(tokens), None)
        if e is not None:
            self.used_bytes -= e.wire_bytes
        return e

    # ------------------------------------------------------------------
    def try_put_entry(self, entry: StoreEntry
                      ) -> Tuple[str, List[StoreEntry]]:
        """Insert a pre-built entry.  Returns ``(status, evicted)`` with
        status ``"stored"`` | ``"oversize"`` (payload exceeds the whole
        pool) | ``"protected"`` (room would require evicting a strictly
        more critical SLO rank).  On non-stored statuses nothing is
        evicted and a pre-existing same-key entry is left in place."""
        entry.tokens = tuple(entry.tokens)
        entry.wire_bytes = int(entry.wire_bytes)
        if entry.wire_bytes > self.capacity_bytes:
            return "oversize", []
        old = self._entries.pop(entry.tokens, None)
        if old is not None:
            self.used_bytes -= old.wire_bytes
        evicted = self._make_room(entry.wire_bytes, entry.rank)
        if evicted is None:
            if old is not None:   # roll the refresh back untouched
                self._entries[entry.tokens] = old
                self.used_bytes += old.wire_bytes
            return "protected", []
        self._entries[entry.tokens] = entry
        self.used_bytes += entry.wire_bytes
        assert self.used_bytes <= self.capacity_bytes
        return "stored", evicted

    def put(self, tokens: TokenKey, payload: Any, wire_bytes: int,
            kv_bytes: float = 0.0, workload: str = "",
            slo_class: str = "standard", now: float = 0.0
            ) -> List[StoreEntry]:
        """Insert (or refresh) the entry for ``tokens``, evicting until it
        fits.  Returns the evicted entries.  A payload larger than the
        whole pool — or one that could only fit by evicting a strictly
        more critical SLO class — is rejected (counted, nothing evicted)."""
        entry = StoreEntry(
            tokens=tuple(tokens), payload=payload, wire_bytes=int(wire_bytes),
            kv_bytes=kv_bytes, workload=workload, slo_class=slo_class,
            created=now, last_used=now)
        status, evicted = self.try_put_entry(entry)
        if status != "stored":
            self.stats.rejected_puts += 1
            return []
        return evicted

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes

    def entries(self) -> List[StoreEntry]:
        return list(self._entries.values())

    def summary(self) -> Dict[str, float]:
        return {
            "entries": len(self._entries),
            "used_bytes": self.used_bytes,
            "capacity_bytes": self.capacity_bytes,
            "hit_rate": self.stats.hit_rate,
            "hits": self.stats.hits,
            "misses": self.stats.misses,
            "partial_misses": self.stats.partial_misses,
            "evictions": self.stats.evictions,
            "rejected_puts": self.stats.rejected_puts,
        }


# ===========================================================================
# Tiered memory hierarchy (ISSUE 4 tentpole)
# ===========================================================================
@dataclass
class TierSpec:
    """Declarative description of one tier of the KV memory hierarchy."""

    name: str                     # "hbm" | "dram" | "remote" | ...
    capacity_bytes: int           # wire-byte capacity (0 = disabled tier)
    # Fetch link: a bytes/s constant or a full BandwidthTrace.  The link is
    # ONE serialized queue (half-duplex): fetches, pool writes and demotion
    # traffic into this tier all contend on it.
    bandwidth: Any = 10 * (1 << 30)
    fetch_overhead: float = 0.0   # per-fetch RPC/setup cost (s)
    # Demotion policy: entries demoted INTO this tier are re-compressed
    # with this profile (when the owner installed a `recompress` hook and
    # it actually shrinks the payload).  None = keep the stored encoding.
    profile: Optional[Any] = None
    # Feed this tier's on-wire goodput to the shared estimator (the
    # controller's B).  Only the remote/pool tier should: local-tier
    # goodput would inflate the network estimate.
    observe_goodput: bool = False


class KVTier:
    """A built tier: its bounded store + its serialized fetch link.

    ``shared=True`` marks a tier that several TieredKVStores end in (the
    cluster-wide disaggregated pool): promotion out of a shared tier
    COPIES the entry into the fetching hierarchy's hot tier instead of
    moving it — the pool copy must stay visible to every other worker.
    """

    def __init__(self, spec: TierSpec, block: int):
        self.spec = spec
        self.name = spec.name
        self.shared = False
        self.trace = (spec.bandwidth
                      if isinstance(spec.bandwidth, BandwidthTrace)
                      else BandwidthTrace.constant(float(spec.bandwidth)))
        self.wire = KVWire(self.trace)
        self.store = PrefixKVStore(int(spec.capacity_bytes), block=block)

    @property
    def fetch_overhead(self) -> float:
        return self.spec.fetch_overhead


@dataclass
class TierHit:
    """A lookup hit, tagged with the tier that holds the bytes."""

    entry: StoreEntry
    tier_index: int
    tier: KVTier


@dataclass
class TieredStats(StoreStats):
    promotions: int = 0       # entries copied up on access
    demotions: int = 0        # victims pushed down instead of dropped
    slo_protected: int = 0    # tier-level inserts demoted by the SLO rule
    tier_hits: Dict[str, int] = field(default_factory=dict)


def default_tier_specs(remote_capacity: int, remote_bandwidth: Any,
                       *, remote_overhead: float = 0.002,
                       hot_bytes: int = 4 << 20,
                       hot_bandwidth: float = 64e9,
                       dram_bytes: int = 16 << 20,
                       dram_bandwidth: float = 8e9,
                       dram_overhead: float = 5e-4,
                       dram_profile: Optional[Any] = None,
                       remote_profile: Optional[Any] = None
                       ) -> List[TierSpec]:
    """The canonical HBM -> DRAM -> remote-pool hierarchy."""
    return [
        TierSpec("hbm", int(hot_bytes), bandwidth=hot_bandwidth),
        TierSpec("dram", int(dram_bytes), bandwidth=dram_bandwidth,
                 fetch_overhead=dram_overhead, profile=dram_profile),
        TierSpec("remote", int(remote_capacity), bandwidth=remote_bandwidth,
                 fetch_overhead=remote_overhead, profile=remote_profile,
                 observe_goodput=True),
    ]


class TieredKVStore:
    """Ordered hierarchy of :class:`PrefixKVStore` tiers with serialized
    per-tier fetch links.

    Placement: ``put`` lands at the hottest tier that fits (``tier=`` picks
    the starting tier; the PD runtime writes straight to the pool tier);
    capacity pressure *demotes* victims down the hierarchy — re-compressed
    with the destination tier's profile via the owner-installed
    ``recompress(entry, profile) -> (payload, wire_bytes) | None`` hook —
    and only the last tier truly evicts.  A tier-level insert that would
    evict a strictly more critical SLO rank demotes the incoming entry
    instead (``stats.slo_protected``).  Hits promote to the hot tier on
    access (piggybacking on the fetch — no extra link time); demotion
    transfers ARE billed on the destination tier's link, and a demoted
    entry stays invisible until its transfer lands (``created`` rule).
    """

    def __init__(self, specs: Sequence[Any], block: int = 16,
                 estimator: Optional[Any] = None,
                 recompress: Optional[
                     Callable[[StoreEntry, Any],
                              Optional[Tuple[Any, int]]]] = None):
        assert specs, "at least one tier required"
        self.block = int(block)
        # A spec list may mix TierSpec (a private tier is built) with
        # pre-built KVTier objects (adopted as-is).  Sharing one KVTier
        # across several TieredKVStores is how a cluster models worker-
        # LOCAL hot tiers over a SHARED disaggregated remote pool: each
        # decode worker's hierarchy ends in the same pool tier, so its
        # capacity, entries and serialized link are cluster-global while
        # HBM/DRAM stay per-worker.
        self.tiers: List[KVTier] = [
            s if isinstance(s, KVTier) else KVTier(s, self.block)
            for s in specs]
        self.estimator = estimator
        self.recompress = recompress
        self.stats = TieredStats()

    # ------------------------------------------------------------------
    @classmethod
    def wrap_flat(cls, store: PrefixKVStore, bandwidth: Any,
                  fetch_overhead: float = 0.0,
                  estimator: Optional[Any] = None,
                  name: str = "remote") -> "TieredKVStore":
        """Adopt an existing flat pool as a single remote tier (the
        caller's store object keeps owning entries and stats)."""
        spec = TierSpec(name, store.capacity_bytes, bandwidth=bandwidth,
                        fetch_overhead=fetch_overhead, observe_goodput=True)
        self = cls([spec], block=store.block, estimator=estimator)
        self.tiers[0].store = store
        return self

    # ------------------------------------------------------------------
    def lookup(self, tokens: TokenKey, now: float = 0.0,
               full: bool = False) -> Optional[TierHit]:
        """Walk tiers hot -> cold; first tier holding a usable prefix wins
        (the hierarchy is exclusive: a key lives in exactly one tier)."""
        partial = False
        for i, tier in enumerate(self.tiers):
            before_pm = tier.store.stats.partial_misses
            e = tier.store.lookup(tokens, now=now, full=full)
            if e is not None:
                self.stats.hits += 1
                self.stats.tier_hits[tier.name] = \
                    self.stats.tier_hits.get(tier.name, 0) + 1
                return TierHit(entry=e, tier_index=i, tier=tier)
            partial = partial or (tier.store.stats.partial_misses > before_pm)
        if partial:
            self.stats.partial_misses += 1
        else:
            self.stats.misses += 1
        return None

    def contains(self, tokens: TokenKey, now: float = 0.0) -> bool:
        return any(t.store.contains(tokens, now=now) for t in self.tiers)

    def peek(self, tokens: TokenKey, now: float = 0.0) -> Optional[TierHit]:
        """Stats- and recency-NEUTRAL exact-key probe (the routing
        layer's view): which tier holds the prefix, and at how many wire
        bytes — without counting a hit, bumping recency, or promoting.
        Same write-visibility rule as :meth:`lookup`."""
        tokens = tuple(tokens)
        for i, tier in enumerate(self.tiers):
            e = tier.store._entries.get(tokens)
            if e is not None and e.created <= now:
                return TierHit(entry=e, tier_index=i, tier=tier)
        return None

    # ------------------------------------------------------------------
    def _maybe_recompress(self, entry: StoreEntry, tier: KVTier) -> None:
        prof = tier.spec.profile
        if prof is None or self.recompress is None:
            return
        out = self.recompress(entry, prof)
        if out is None:
            return
        payload, wire_bytes = out
        if int(wire_bytes) >= entry.wire_bytes:
            return  # demotion re-compression only ever shrinks
        entry.payload = payload
        entry.wire_bytes = int(wire_bytes)

    def _place(self, entry: StoreEntry, start: int, now: float,
               fresh: bool) -> Optional[int]:
        """Insert ``entry`` at the hottest tier >= ``start`` that accepts
        it, cascading victims downward.  Returns the tier index stored at,
        or None when the entry fell off the bottom (fresh put -> rejected;
        demoted victim -> true eviction)."""
        i, demoted = start, not fresh
        while i < len(self.tiers):
            tier = self.tiers[i]
            if demoted:
                self._maybe_recompress(entry, tier)
            status, evicted = tier.store.try_put_entry(entry)
            if status == "stored":
                if demoted:
                    # The demotion transfer occupies the destination link;
                    # the entry is invisible until its bytes land.
                    tr = tier.wire.send(now, entry.wire_bytes)
                    entry.created = entry.last_used = tr.end
                for v in evicted:
                    # A victim only counts as demoted if it actually lands
                    # somewhere below; falling off the bottom is an
                    # eviction (counted inside the recursive call).
                    if self._place(v, i + 1, now, fresh=False) is not None:
                        self.stats.demotions += 1
                return i
            if status == "protected":
                self.stats.slo_protected += 1
            i, demoted = i + 1, True
        if fresh:
            self.stats.rejected_puts += 1
        else:
            self.stats.evictions += 1
        return None

    # ------------------------------------------------------------------
    def put(self, tokens: TokenKey, payload: Any, wire_bytes: int,
            kv_bytes: float = 0.0, workload: str = "",
            slo_class: str = "standard", now: float = 0.0,
            tier: int = 0) -> Optional[int]:
        """Place a fresh entry starting at tier ``tier`` (no link billing —
        use :meth:`write` to also occupy the tier's wire).  Stale copies of
        the key anywhere in the hierarchy are dropped first — but a
        refresh whose placement is rejected everywhere restores the old
        copy (same rollback rule as the flat store).  A cluster-SHARED
        tier is never pre-clobbered: other workers' hierarchies end in
        it, so one worker's local refresh must not remove a copy the
        whole cluster relies on (a placement that cascades INTO the
        shared tier still same-key-replaces there).  Returns the tier
        index the entry landed at, or None if rejected."""
        tokens = tuple(tokens)
        old: Optional[Tuple[KVTier, StoreEntry]] = None
        for t in self.tiers:
            if t.shared:
                continue
            e = t.store.discard(tokens)
            if e is not None:
                old = (t, e)
        entry = StoreEntry(tokens=tokens, payload=payload,
                           wire_bytes=int(wire_bytes), kv_bytes=kv_bytes,
                           workload=workload, slo_class=slo_class,
                           created=now, last_used=now)
        placed = self._place(entry, min(tier, len(self.tiers) - 1), now,
                             fresh=True)
        if placed is None and old is not None:
            # A fully rejected placement mutates no tier store, so the old
            # copy's slot is still free: putting it back cannot fail.
            old[0].store.try_put_entry(old[1])
        return placed

    def write(self, tokens: TokenKey, payload: Any, wire_bytes: int,
              kv_bytes: float = 0.0, workload: str = "",
              slo_class: str = "standard", ready: float = 0.0,
              tier: int = 0) -> WireTransfer:
        """A pool write: the payload crosses the target tier's serialized
        link (contending with fetches), and the entry only becomes visible
        at the transfer's completion time."""
        ti = min(tier, len(self.tiers) - 1)
        t = self.tiers[ti]
        tr = t.wire.send(ready, wire_bytes)
        self._observe(t, wire_bytes, tr.t_comm)
        self.put(tokens, payload, wire_bytes, kv_bytes=kv_bytes,
                 workload=workload, slo_class=slo_class, now=tr.end,
                 tier=ti)
        return tr

    # ------------------------------------------------------------------
    def _observe(self, tier: KVTier, nbytes: float, seconds: float) -> None:
        # KVWire-attached estimators (the PD runtime shares its transfer
        # wire with the pool tier) already observed inside send().
        if (tier.spec.observe_goodput and self.estimator is not None
                and tier.wire.estimator is None):
            self.estimator.observe(nbytes, seconds)

    def fetch(self, hit: TierHit, ready: float,
              promote: bool = True) -> WireTransfer:
        """Pull a hit's bytes over its tier's serialized link (concurrent
        fetches queue).  The returned transfer is relative to
        ``ready + tier.fetch_overhead``; on success the entry is promoted
        to the hot tier (the bytes just crossed the link — the copy is
        free, and the entry stays visible from its original write)."""
        tier = hit.tier
        tr = tier.wire.send(ready + tier.fetch_overhead,
                            hit.entry.wire_bytes)
        self._observe(tier, hit.entry.wire_bytes, tr.t_comm)
        if promote:
            self._promote(hit, tr.end)
        return tr

    def _promote(self, hit: TierHit, now: float) -> None:
        if hit.tier_index == 0:
            return
        tier0 = self.tiers[0]
        if hit.entry.wire_bytes > tier0.store.capacity_bytes:
            return  # can never fit the hot tier: stay put
        if hit.tier.shared:
            # The holding tier is a cluster-SHARED pool: other workers'
            # hierarchies end in it, so promotion COPIES the entry into
            # this hierarchy's hot tier (the bytes just crossed the link;
            # the pool copy physically remains and must stay visible to
            # every other worker).  A distinct StoreEntry keeps the two
            # copies' recency/bytes accounting independent.
            from dataclasses import replace as _dc_replace
            e = _dc_replace(hit.entry, last_used=now)
            status, evicted = tier0.store.try_put_entry(e)
            if status != "stored":
                return
            hit.entry.last_used = now
        else:
            e = hit.tier.store.discard(hit.entry.tokens)
            if e is None:
                return
            # Promotion must never make an entry LESS visible: it has
            # been servable since its original `created` (the source copy
            # would physically remain until overwritten), so a concurrent
            # lookup at the same instant still hits.  Only recency moves.
            e.last_used = now
            status, evicted = tier0.store.try_put_entry(e)
            if status != "stored":
                hit.tier.store.try_put_entry(e)  # roll back where it lived
                return
        self.stats.promotions += 1
        for v in evicted:
            if self._place(v, 1, now, fresh=False) is not None:
                self.stats.demotions += 1

    def reencode(self, hit: TierHit, profile: Any) -> bool:
        """Re-compress a stored entry in place with ``profile`` (the
        controller's "refetch smaller" route) — capacity accounting on the
        holding tier follows the shrink."""
        if self.recompress is None:
            return False
        out = self.recompress(hit.entry, profile)
        if out is None:
            return False
        payload, wire_bytes = out
        if int(wire_bytes) >= hit.entry.wire_bytes:
            return False
        hit.tier.store.used_bytes -= hit.entry.wire_bytes - int(wire_bytes)
        hit.entry.payload = payload
        hit.entry.wire_bytes = int(wire_bytes)
        return True

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return sum(len(t.store) for t in self.tiers)

    @property
    def used_bytes(self) -> int:
        return sum(t.store.used_bytes for t in self.tiers)

    @property
    def capacity_bytes(self) -> int:
        return sum(t.store.capacity_bytes for t in self.tiers)

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes

    def entries(self) -> List[StoreEntry]:
        return [e for t in self.tiers for e in t.store.entries()]

    def summary(self) -> Dict[str, float]:
        out = {
            "entries": len(self),
            "used_bytes": self.used_bytes,
            "capacity_bytes": self.capacity_bytes,
            "hit_rate": self.stats.hit_rate,
            "hits": self.stats.hits,
            "misses": self.stats.misses,
            "partial_misses": self.stats.partial_misses,
            "evictions": self.stats.evictions,
            "rejected_puts": self.stats.rejected_puts,
            "promotions": self.stats.promotions,
            "demotions": self.stats.demotions,
            "slo_protected": self.stats.slo_protected,
        }
        for i, tier in enumerate(self.tiers):
            out[f"tier{i}_{tier.name}_entries"] = len(tier.store)
            out[f"tier{i}_{tier.name}_used_bytes"] = tier.store.used_bytes
            out[f"tier{i}_{tier.name}_hits"] = \
                self.stats.tier_hits.get(tier.name, 0)
        return out
