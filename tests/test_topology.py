"""NetworkTopology, per-link estimators, routing in the event-driven
simulator, and the shared latency-distribution metrics (ISSUE 5)."""
import numpy as np
import pytest

from repro.serving import (
    GBPS,
    BandwidthTrace,
    GoodputEstimator,
    KVWire,
    NetworkTopology,
    Request,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
    latency_summary,
    route_name,
)


# ---------------------------------------------------------------------------
# Goodput estimator seeding (satellite: no more hard-coded 10 Gb/s prior)
# ---------------------------------------------------------------------------
def test_estimator_seeds_from_link_trace():
    """An unseeded estimator attached to a KVWire starts from the link's
    CONFIGURED bandwidth: on a 50 Mbps wire the controller's first
    selections must not assume a ~1600x faster network."""
    slow = BandwidthTrace.constant(0.05 * GBPS)
    est = GoodputEstimator()
    KVWire(slow, est)
    assert est.estimate == pytest.approx(0.05 * GBPS)

    # an explicit initial is never overridden
    est2 = GoodputEstimator(initial=123.0)
    KVWire(slow, est2)
    assert est2.estimate == 123.0

    # only a completely detached estimator falls back to the legacy prior
    assert GoodputEstimator().estimate == GoodputEstimator.DETACHED_INITIAL


def test_estimator_seed_never_zero_for_outage_start_trace():
    """A trace that STARTS in an outage segment (rate 0 — legal since the
    outage fix) must not seed a 0 B/s prior: that value reaches the
    latency model's divisions on the first controller decision.  The seed
    falls forward to the first positive segment (or the detached prior
    for an all-outage trace)."""
    outage_start = BandwidthTrace.steps([(0.0, 0.0), (1.0, 1e9)])
    est = GoodputEstimator()
    KVWire(outage_start, est)
    assert est.estimate == pytest.approx(1e9)

    dead = BandwidthTrace.steps([(0.0, 0.0)])
    est2 = GoodputEstimator()
    KVWire(dead, est2)
    assert est2.estimate == GoodputEstimator.DETACHED_INITIAL


def test_topology_links_are_independent_and_self_seeded():
    topo = NetworkTopology.full_mesh(
        2, 2, BandwidthTrace.constant(1 * GBPS),
        links={(0, 1): BandwidthTrace.constant(0.05 * GBPS)})
    assert topo.n_links == 4
    # per-link estimators see their own trace before any transfer
    assert topo.estimator(0, 1).estimate == pytest.approx(0.05 * GBPS)
    assert topo.estimator(0, 0).estimate == pytest.approx(1 * GBPS)
    # links are distinct serialized queues: same-link sends contend,
    # different links overlap freely
    mb = 1_000_000
    a1 = topo.link(0, 0).send(0.0, mb)
    a2 = topo.link(0, 0).send(0.0, mb)
    b1 = topo.link(1, 0).send(0.0, mb)
    assert a1.t_wait == 0.0 and a2.t_wait == pytest.approx(a1.t_comm)
    assert b1.t_wait == 0.0                       # different link: no queue
    assert topo.transfers == 3
    assert topo.bytes_moved == 3 * mb
    assert route_name(0, 1) == "p0->d1"


def test_topology_rejects_out_of_mesh_links():
    with pytest.raises(ValueError):
        NetworkTopology(1, 2, links={(1, 0): BandwidthTrace.constant(1e9)})


# ---------------------------------------------------------------------------
# Latency-distribution metrics (satellite: summaries beyond means)
# ---------------------------------------------------------------------------
def _done_req(rid, ttft, jct, slo_class="standard", t_slo=0.0,
              violated=False):
    r = Request(rid=rid, workload="qalike", arrival=0.0, ctx_tokens=10,
                out_tokens=2, kv_bytes=1.0, t_slo=t_slo,
                slo_class=slo_class)
    r.ttft, r.done, r.slo_violated = ttft, jct, violated
    return r


def test_latency_summary_percentiles_and_violation_rates():
    reqs = [_done_req(i, ttft=float(i + 1), jct=2.0 * (i + 1))
            for i in range(100)]
    reqs += [_done_req(100 + i, 1.0, 2.0, slo_class="interactive",
                       t_slo=1.5, violated=(i < 3)) for i in range(10)]
    reqs += [_done_req(110 + i, 1.0, 2.0, slo_class="batch", t_slo=9.0,
                       violated=False) for i in range(5)]
    s = latency_summary(reqs)
    assert s["ttft_p50"] <= s["ttft_p95"] <= s["ttft_p99"]
    assert s["jct_p95"] == pytest.approx(
        np.percentile([r.jct for r in reqs], 95))
    assert s["slo_violation_rate_interactive"] == pytest.approx(0.3)
    assert s["slo_violation_rate_batch"] == 0.0
    assert s["slo_violation_rate"] == pytest.approx(3 / 15)


def test_latency_summary_empty_population():
    assert latency_summary([]) == {}


# ---------------------------------------------------------------------------
# The simulator drives the same topology (large-scale sweeps)
# ---------------------------------------------------------------------------
def _prof():
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    return Profile(StrategyConfig(key_bits=8, value_bits=8), cr=2.0,
                   s_enc=1e9, s_dec=1e9)


def _topo_hetero():
    return NetworkTopology.full_mesh(
        2, 2, BandwidthTrace.constant(1 * GBPS),
        links={(0, 1): BandwidthTrace.constant(0.05 * GBPS)})


def _sim(routing, n=40):
    reqs = WorkloadMix(rate=8.0, seed=0, q_min=0.0).generate(n)
    return Simulator(SimConfig(n_prefill=2, n_decode=2),
                     StaticPolicy(_prof(), "s"),
                     BandwidthTrace.constant(1 * GBPS), reqs,
                     topology=_topo_hetero(), routing=routing).run()


def test_sim_topology_load_aware_beats_round_robin():
    """On a mesh with one 50 Mbps link, round-robin keeps pushing a
    quarter of the traffic onto the slow wire; the load-aware argmin
    (per-link estimators + link backlog + decode queue) avoids it and
    yields strictly lower mean JCT.  Deterministic: constant traces, no
    faults, fixed seeds."""
    rr = _sim("round_robin")
    la = _sim("load_aware")
    assert la.mean_jct() < rr.mean_jct()
    # every request records the route that served it
    assert all(r.route for r in la.completed())
    # the slow link carried (much) less traffic under load-aware routing
    slow_rr = sum(1 for r in rr.completed() if r.route == "p0->d1")
    slow_la = sum(1 for r in la.completed() if r.route == "p0->d1")
    assert slow_la < slow_rr


def test_sim_topology_same_link_transfers_contend():
    """Two simultaneous transfers routed over the SAME link queue: the
    second books wire_wait; distinct links never queue against each
    other."""
    reqs = [Request(rid=i, workload="qalike", arrival=0.0, ctx_tokens=1000,
                    out_tokens=2, kv_bytes=4e6, q_min=0.0)
            for i in range(2)]
    topo = NetworkTopology.full_mesh(1, 1,
                                     BandwidthTrace.constant(1e6))
    res = Simulator(SimConfig(n_prefill=2, n_decode=1, prefill_tok_s=1e6),
                    StaticPolicy(_prof(), "s"),
                    BandwidthTrace.constant(1e6), reqs,
                    topology=NetworkTopology.full_mesh(
                        2, 1, BandwidthTrace.constant(1e6),
                        # both prefill nodes feed ONE decode node; give
                        # the pair links identical traces
                    ),
                    routing="load_aware").run()
    waits = sorted(r.breakdown.get("wire_wait", 0.0)
                   for r in res.completed())
    # both requests prefill concurrently (2 nodes) and target d0; they
    # leave from different prefill nodes -> different links -> no queue
    assert waits == [0.0, 0.0]

    res2 = Simulator(SimConfig(n_prefill=1, n_decode=1, prefill_tok_s=1e6,
                               decode_tok_s=1e6),
                     StaticPolicy(_prof(), "s"),
                     BandwidthTrace.constant(1e6),
                     [Request(rid=i, workload="qalike", arrival=0.0,
                              ctx_tokens=10, out_tokens=2, kv_bytes=4e6,
                              q_min=0.0) for i in range(2)],
                     topology=topo, routing="round_robin").run()
    waits2 = sorted(r.breakdown.get("wire_wait", 0.0)
                    for r in res2.completed())
    assert waits2[0] == 0.0 and waits2[1] > 0.0


def test_sim_topology_dimension_mismatch_rejected():
    with pytest.raises(ValueError):
        Simulator(SimConfig(n_prefill=4, n_decode=2),
                  StaticPolicy(_prof(), "s"),
                  BandwidthTrace.constant(1e9), [],
                  topology=NetworkTopology.full_mesh(
                      2, 2, BandwidthTrace.constant(1e9)))


def test_sim_summary_has_tails_and_routes():
    res = _sim("load_aware", n=20)
    s = res.summary()
    for k in ("mean_jct", "jct_p50", "jct_p95", "jct_p99", "ttft_p95",
              "throughput_rps"):
        assert k in s, k
    assert any(k.startswith("route_") for k in s)
