"""Paper Fig. 13 (and Fig. 1): JCT across bandwidths in PD separation —
driven by the *continuous* PD-disaggregated runtime (DESIGN.md §9).

Every cold request's compressed KV crosses the serialized
:class:`~repro.serving.network.KVWire` on its critical path (prefill ->
controller-selected compress -> transfer -> decompress -> decode arena),
with request N+1's prefill/transfer overlapping request N's decode.
Compares Default(no compression) / 8-bit / 4-bit+zstd / KVServe
(service-aware controller) across Gbps-scale effective bandwidths.
Derived columns: mean JCT seconds and speedup over default.

Acceptance (asserted on every run, virtual clock => deterministic): at
50 Mbps a compressed profile beats identity; at 100 Gbps identity wins.

CLI: ``--smoke`` shrinks to CI-sized settings; ``--json PATH`` archives
the emitted rows as JSON.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import emit, write_json
from repro.controller import ServiceAwareController
from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.core.strategy import StrategyConfig
from repro.data.synthetic import WORKLOADS
from repro.serving import GBPS, BandwidthTrace, SchedulerConfig

BANDWIDTHS_GBPS = (0.05, 0.1, 0.25, 1.0, 10.0, 100.0)
SMOKE_BANDWIDTHS_GBPS = (0.05, 100.0)
WORKLOAD_CYCLE = ("qalike", "codelike", "mathlike", "summlike")


def _wire_profiles():
    """Hand-calibrated operating points (the wire bytes are still real
    pipeline output; cr/s only drive the controller's predictions)."""
    q8 = Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                value_bits=8, granularity="per_channel"),
                 cr=2.0, s_enc=5e8, s_dec=5e8,
                 quality={w: 0.99 for w in WORKLOADS})
    q4z = Profile(StrategyConfig(quantizer="uniform", key_bits=4,
                                 value_bits=4, granularity="per_channel",
                                 codec="zstd3"),
                  cr=6.0, s_enc=3e8, s_dec=3e8,
                  quality={w: 0.95 for w in WORKLOADS})
    return q8, q4z


def _mean_jct(trace: BandwidthTrace, n_requests: int, seq: int,
              decode_tokens: int, controller=None,
              static_profile: Optional[Profile] = None
              ) -> Tuple[float, Dict[str, float]]:
    """Drive the continuous PD runtime through a cold-request stream (all
    distinct prompts => every request crosses the wire).  Returns
    ``(mean_jct, summary)`` — the summary carries the p50/p95/p99 tails
    and violation rates."""
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    rt = ServingRuntime(
        controller=controller, static_profile=static_profile,
        config=RuntimeConfig(seq=seq, decode_tokens=decode_tokens,
                             prefill_tok_s=2000.0, decode_tok_s=500.0,
                             mode="pd"),
        trace=trace,
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=2 * n_requests))
    for i in range(n_requests):
        # spaced seeds: every prompt distinct => a genuinely cold stream
        rt.submit(WORKLOAD_CYCLE[i % 4], q_min=0.5, prompt_seed=100 + 7 * i)
        rt.step()
    done = rt.run()
    assert len(done) == n_requests
    assert all(not r.pool_hit for r in done)       # cold stream
    assert rt.wire.transfers == n_requests         # every KV crossed the wire
    return float(np.mean([r.jct for r in done])), rt.summary()


def run(smoke: bool = False) -> None:
    n_requests = 6 if smoke else 16
    seq = 48 if smoke else 96
    decode_tokens = 4 if smoke else 8
    q8, q4z = _wire_profiles()
    bandwidths = SMOKE_BANDWIDTHS_GBPS if smoke else BANDWIDTHS_GBPS

    for bw in bandwidths:
        trace = BandwidthTrace.constant(bw * GBPS)
        run_one = lambda **kw: _mean_jct(trace, n_requests, seq,
                                         decode_tokens, **kw)
        t0 = time.perf_counter()
        res: Dict[str, float] = {}
        tails: Dict[str, Dict[str, float]] = {}
        res["default"], tails["default"] = run_one(
            static_profile=IDENTITY_PROFILE)
        res["q8"], tails["q8"] = run_one(static_profile=q8)
        res["q4zstd"], tails["q4zstd"] = run_one(static_profile=q4z)
        controller = ServiceAwareController(
            {w: [q8, q4z] for w in WORKLOADS})
        res["kvserve"], tails["kvserve"] = run_one(controller=controller)
        elapsed = (time.perf_counter() - t0) * 1e6
        speedup = res["default"] / res["kvserve"]
        emit(f"fig13_pd_jct_bw{bw:g}gbps", elapsed,
             f"default={res['default']:.3f}s q8={res['q8']:.3f}s "
             f"q4zstd={res['q4zstd']:.3f}s kvserve={res['kvserve']:.3f}s "
             f"speedup={speedup:.2f}x")
        # Tail metrics (ISSUE 5 satellite): the SLO story lives in the
        # distribution, not the mean.
        emit(f"fig13_pd_tails_bw{bw:g}gbps", 0.0,
             " ".join(f"{name}_jct_p{p}={tails[name][f'jct_p{p}']:.4f}"
                      for name in ("default", "kvserve")
                      for p in (50, 95, 99)))

        # Acceptance: compression pays under scarce bandwidth, identity
        # wins when the wire is free (deterministic — virtual clock).
        if bw <= 0.05:
            assert min(res["q8"], res["q4zstd"]) < res["default"], res
            assert res["kvserve"] < res["default"], res
        if bw >= 100.0:
            assert res["default"] <= min(res["q8"], res["q4zstd"]), res


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings; crash = fail")
    ap.add_argument("--json", default="",
                    help="archive emitted rows to this JSON path")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
