"""Stage 1 of the pipeline: the Transformer ``T`` (pre-processing transforms).

All transforms are exactly (or float-exactly) invertible; they reshape the
distribution so the downstream quantizer loses less information:

  - ``delta``     (CacheGen):  tokens stored as deltas against periodic anchor
                  tokens -> smaller dynamic range on smooth token streams.
  - ``hadamard``  (QuaRot):    orthonormal rotation of the channel dim ->
                  spreads outlier channels across all channels.
  - ``affine``    (AffineQuant, diagonal): per-channel standardisation with
                  stats stored as metadata.

Each transform returns ``(y, ctx)`` where ``ctx`` holds inverse metadata, and
``meta_bytes(ctx)`` accounts for its wire cost.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

Array = np.ndarray


# ---------------------------------------------------------------------------
# Hadamard
# ---------------------------------------------------------------------------
def hadamard_matrix(n: int) -> Array:
    """Orthonormal Hadamard matrix of size n (n must be a power of two)."""
    assert n & (n - 1) == 0, f"hadamard dim {n} not a power of two"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(n)).astype(np.float32)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def hadamard_forward(x: Array) -> Tuple[Array, Dict[str, Any]]:
    d = x.shape[-1]
    dp = _next_pow2(d)
    if dp != d:
        pad = np.zeros(x.shape[:-1] + (dp - d,), dtype=x.dtype)
        x = np.concatenate([x, pad], axis=-1)
    h = hadamard_matrix(dp)
    y = x @ h
    return y.astype(np.float32), {"orig_dim": d, "pad_dim": dp}


def hadamard_inverse(y: Array, ctx: Dict[str, Any]) -> Array:
    h = hadamard_matrix(ctx["pad_dim"])
    x = y @ h.T
    return x[..., : ctx["orig_dim"]].astype(np.float32)


# ---------------------------------------------------------------------------
# Delta (anchor-token differencing along the sequence axis; axis=-2)
# ---------------------------------------------------------------------------
def delta_forward(x: Array, group: int) -> Tuple[Array, Dict[str, Any]]:
    s = x.shape[-2]
    anchors_idx = np.arange(0, s, group)
    anchors = x[..., anchors_idx, :]
    # Broadcast each token's group anchor and subtract.
    anchor_of = anchors_idx[np.minimum(np.arange(s) // group, len(anchors_idx) - 1)]
    y = x - x[..., anchor_of, :]
    # Keep anchors raw (their delta is zero; store anchor values in metadata).
    return y.astype(np.float32), {"group": group, "anchors": anchors.astype(np.float32)}


def delta_inverse(y: Array, ctx: Dict[str, Any]) -> Array:
    group = ctx["group"]
    anchors = ctx["anchors"]
    s = y.shape[-2]
    anchors_idx = np.arange(0, s, group)
    anchor_of = np.minimum(np.arange(s) // group, len(anchors_idx) - 1)
    x = y + anchors[..., anchor_of, :]
    return x.astype(np.float32)


def delta_meta_bytes(ctx: Dict[str, Any]) -> int:
    # Anchors ship at source precision (bf16 = 2 bytes logical).
    return int(ctx["anchors"].size) * 2


# ---------------------------------------------------------------------------
# Affine (diagonal): per-channel standardisation.
# ---------------------------------------------------------------------------
def affine_forward(x: Array) -> Tuple[Array, Dict[str, Any]]:
    # Stats over all axes but the channel axis.
    axes = tuple(range(x.ndim - 1))
    mu = x.mean(axis=axes, keepdims=True)
    sd = x.std(axis=axes, keepdims=True) + 1e-6
    y = (x - mu) / sd
    return y.astype(np.float32), {"mu": mu.astype(np.float32), "sd": sd.astype(np.float32)}


def affine_inverse(y: Array, ctx: Dict[str, Any]) -> Array:
    return (y * ctx["sd"] + ctx["mu"]).astype(np.float32)


def affine_meta_bytes(ctx: Dict[str, Any]) -> int:
    return int(ctx["mu"].size + ctx["sd"].size) * 2


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------
def apply_transform(name: str, x: Array, delta_group: int = 64):
    if name == "none":
        return x, {"kind": "none"}
    if name == "hadamard":
        y, ctx = hadamard_forward(x)
        ctx["kind"] = "hadamard"
        return y, ctx
    if name == "delta":
        y, ctx = delta_forward(x, delta_group)
        ctx["kind"] = "delta"
        return y, ctx
    if name == "affine":
        y, ctx = affine_forward(x)
        ctx["kind"] = "affine"
        return y, ctx
    raise ValueError(f"unknown transform {name}")


def invert_transform(y: Array, ctx: Dict[str, Any]) -> Array:
    kind = ctx["kind"]
    if kind == "none":
        return y
    if kind == "hadamard":
        return hadamard_inverse(y, ctx)
    if kind == "delta":
        return delta_inverse(y, ctx)
    if kind == "affine":
        return affine_inverse(y, ctx)
    raise ValueError(kind)


def transform_meta_bytes(ctx: Dict[str, Any]) -> int:
    kind = ctx["kind"]
    if kind in ("none", "hadamard"):
        return 0
    if kind == "delta":
        return delta_meta_bytes(ctx)
    if kind == "affine":
        return affine_meta_bytes(ctx)
    raise ValueError(kind)
