"""Pallas TPU kernel: paged multi-token *verify* attention (DESIGN.md §15).

The speculative-decode verify step feeds ``W`` consecutive query tokens
per slot — the last committed token plus ``W-1`` draft tokens — against
the slot's paged quantized KV.  This is ``paged_attention.py`` widened
with a q-tile axis: the grid and online-softmax page loop are identical,
but the query block carries ``W x Gq`` rows and the length mask becomes
*per query row*.  Query ``j`` of slot ``b`` sits at absolute position
``kv_lens[b] - 1 + j`` (``kv_lens`` counts the committed prefix PLUS the
already-scattered verify rows' first position; see below), so it may
attend cache positions ``< kv_lens[b] + j`` — the staircase causal mask
that keeps each draft position blind to its successors.  Rejected
suffixes therefore never influence any accepted output row: acceptance
is decided on the host purely from the returned rows, and the rejected
positions' KV pages are rolled back by ``PageTable.release_tail``.

Contract: the ``W`` new tokens' own K/V rows are already scattered into
the pages at positions ``kv_lens[b]-1 .. kv_lens[b]+W-2`` (the caller
writes KV before attention, as the arena does), and ``kv_lens[b] >= 1``.
Unmapped block-table entries point at scratch page 0; every position
they cover lies beyond the mask, so their content contributes zero.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_verify_kernel(bt_ref, kvl_ref, q_ref, kc_ref, ks_ref, vc_ref,
                         vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                         bits: int, group: int, page_size: int, gq: int,
                         sm_scale: float):
    del bt_ref  # consumed by the BlockSpec index maps, not the body
    b_idx = pl.program_id(0)
    p_idx = pl.program_id(2)
    n_p = pl.num_programs(2)
    kv_len = kvl_ref[b_idx]

    @pl.when(p_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _dequant(c_ref, s_ref):
        c = c_ref[0, 0]  # (PS, D') packed page
        if bits == 4:
            lo = (c & jnp.uint8(0x0F)).astype(jnp.int32) - 8
            hi = (c >> jnp.uint8(4)).astype(jnp.int32) - 8
            q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0],
                                                     c.shape[1] * 2)
        else:
            q = c.astype(jnp.int32)
        ps, d = q.shape
        sc = s_ref[0, 0].astype(jnp.float32)  # (PS, D/group)
        x = q.reshape(ps, d // group, group).astype(jnp.float32) * sc[..., None]
        return x.reshape(ps, d)

    k = _dequant(kc_ref, ks_ref)  # (PS, D) f32
    v = _dequant(vc_ref, vs_ref)
    q = q_ref[0, 0].astype(jnp.float32)  # (W*Gq, D)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale  # (W*Gq, PS)

    # Staircase causal mask: query row r belongs to verify position
    # q_idx = r // Gq and sees cache positions < kv_len + q_idx (which
    # also sends every scratch-page position to -inf).
    base = p_idx * page_size
    pos = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    q_idx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) // gq
    scores = jnp.where(pos < kv_len + q_idx, scores, -jnp.inf)

    m_prev = m_scr[...]           # (W*Gq, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)   # (W*Gq, PS)
    l_new = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(p_idx == n_p - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_verify_attention(
    q: jnp.ndarray,             # (B, Hkv, W, Gq, D)
    k_codes: jnp.ndarray,       # (P, Hkv, PS, D) int8 or (P, Hkv, PS, D/2) u8
    k_scale: jnp.ndarray,       # (P, Hkv, PS, D/group) f32
    v_codes: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, PPS) int32 page ids; 0 = unmapped
    kv_lens: jnp.ndarray,       # (B,) int32; query 0's visible length, >= 1
    *,
    bits: int = 8,
    group: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """Verify attention of ``W`` consecutive tokens per slot against paged
    quantized KV.  Query ``j`` attends positions ``< kv_lens[b] + j``
    (its own already-scattered row included).  Returns (B, Hkv, W, Gq, D).
    """
    b, hkv, w, gq, d = q.shape
    p_total, hkv_k, ps, cw = k_codes.shape
    assert hkv_k == hkv, (hkv_k, hkv)
    assert cw == (d if bits == 8 else d // 2), (cw, d, bits)
    ng = k_scale.shape[3]
    pps = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(d)
    rows = w * gq

    kernel = functools.partial(_paged_verify_kernel, bits=bits, group=group,
                               page_size=ps, gq=gq, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda i, j, p, bt, kvl: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ps, cw),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
            pl.BlockSpec((1, 1, ps, ng),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
            pl.BlockSpec((1, 1, ps, cw),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
            pl.BlockSpec((1, 1, ps, ng),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda i, j, p, bt, kvl: (i, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # running max
            pltpu.VMEM((rows, 1), jnp.float32),   # running denominator
            pltpu.VMEM((rows, d), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(kv_lens, jnp.int32),
      q.reshape(b, hkv, rows, d), k_codes, k_scale, v_codes, v_scale)
    return out.reshape(b, hkv, w, gq, d)
