"""Runtime KV sanitizer: ownership/liveness tracking for the paged arena
and the tiered store (DESIGN.md §14 — the dynamic counterpart of the
``ownership`` static rule).

The static rules catch MOVE-shaped *code*; this module catches MOVE/
lifetime bugs at *runtime*, where they would otherwise surface as silent
cross-request KV corruption long after the faulty call.  When installed
it wraps:

* :class:`~repro.core.kvcache.PageTable` — **double-release** (a slot's
  pages returned to the free pool twice, so a later ``ensure`` can hand
  the same page to two slots; both the full ``release()`` and the
  speculative-rollback ``release_tail()`` are guarded, the latter at
  page granularity so legal partial rollbacks stay silent) and
  **use-after-release** (``block_row()`` on a released slot: the decode
  kernel would read scratch/garbage pages).
* :class:`~repro.serving.kvstore.PrefixKVStore` (via its owning
  :class:`~repro.serving.kvstore.KVTier`) — **shared-tier clobber**:
  ``discard()`` on a cluster-shared tier's store.  A shared tier's
  entries leave only by SLO-aware eviction or same-key replacement
  inside ``try_put_entry``; a MOVE-shaped ``discard`` removes a copy
  every other worker's hierarchy relies on (the PR-5 bug class).
* :class:`~repro.serving.workers.DecodeWorker` /
  :class:`~repro.serving.cluster.ClusterRuntime` — **pages leaked at
  drain**: a freed slot that still owns pages, and, after a ``run()``
  that drained the scheduler, any page owned by a slot that is no
  longer live.

Switchable: ``install()`` / ``uninstall()`` patch the real classes in
place (state rides on the instances, so already-built objects are
covered too); the test suite auto-installs when ``REPRO_SANITIZE=1``
(see ``tests/conftest.py``), which is how CI runs the tier-1 suite
sanitized.  Violations raise :class:`SanitizerError` with a ``kind``
tag so fault-injection tests can assert the exact detector that fired.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, Optional

ENV_VAR = "REPRO_SANITIZE"

KINDS = ("double-release", "use-after-release", "leaked-pages",
         "shared-clobber")


class SanitizerError(RuntimeError):
    """A KV ownership/liveness violation caught at runtime."""

    def __init__(self, kind: str, message: str):
        assert kind in KINDS, kind
        super().__init__(f"[kv-sanitizer:{kind}] {message}")
        self.kind = kind


_installed = False
_orig: Dict[str, object] = {}


def enabled() -> bool:
    return _installed


def env_requested() -> bool:
    return os.environ.get(ENV_VAR, "") == "1"


# ---------------------------------------------------------------------------
# Explicit drain check (also wired into ClusterRuntime.run below)
# ---------------------------------------------------------------------------
def check_drained(table, live_slots: Iterable[int] = ()) -> None:
    """Assert that no slot outside ``live_slots`` still owns pages, and
    that the table's conservation invariants hold.  Call at any drain
    point (end of run, between workload phases)."""
    live = set(live_slots)
    leaked = {s: owned for s, owned in table.pages.items()
              if s not in live and owned}
    if leaked:
        detail = ", ".join(
            f"slot {s}: {len(p)} page(s)" for s, p in sorted(leaked.items()))
        raise SanitizerError(
            "leaked-pages",
            f"pages owned by non-live slots at drain ({detail}) — a "
            f"release path skipped page_table.release()")
    table.check()


# ---------------------------------------------------------------------------
# Wrappers (installed over the real classes; state lives per instance)
# ---------------------------------------------------------------------------
def _released_set(table) -> set:
    rel = getattr(table, "_san_released", None)
    if rel is None:
        rel = set()
        table._san_released = rel
    return rel


def _pt_ensure(self, slot: int, n_tokens: int):
    _released_set(self).discard(slot)      # (re)allocation revives the slot
    return _orig["PageTable.ensure"](self, slot, n_tokens)


def _pt_release(self, slot: int) -> int:
    rel = _released_set(self)
    if slot not in self.pages and slot in rel:
        raise SanitizerError(
            "double-release",
            f"slot {slot} released twice — its pages are already in the "
            f"free pool, so a concurrent ensure() could double-own them")
    rel.add(slot)
    return _orig["PageTable.release"](self, slot)


def _pt_release_tail(self, slot: int, n_tokens: int):
    # Speculative rollback (DESIGN.md §15) is a LEGAL partial release:
    # the slot stays live with its committed prefix and only the
    # rejected draft tail returns to the free pool, so it must not feed
    # the slot-level released set above.  The page-level hazard is a
    # rollback path freeing pages the slot no longer owns — the same
    # physical page landing in the free pool twice, double-grantable by
    # two later ensure() calls.
    owned = self.pages.get(slot, [])
    tail = owned[self.pages_for(n_tokens):]
    dup = sorted(set(tail) & set(self.free))
    if dup:
        raise SanitizerError(
            "double-release",
            f"speculative rollback on slot {slot} frees page(s) {dup} "
            f"that are already in the free pool — a rollback path "
            f"returned the tail twice")
    return _orig["PageTable.release_tail"](self, slot, n_tokens)


def _pt_block_row(self, slot: int, row_len: int):
    if slot in _released_set(self) and slot not in self.pages:
        raise SanitizerError(
            "use-after-release",
            f"block_row() on released slot {slot} — the decode kernel "
            f"would read scratch/garbage pages for this row")
    return _orig["PageTable.block_row"](self, slot, row_len)


def _kvtier_setattr(self, name: str, value) -> None:
    object.__setattr__(self, name, value)
    # keep the clobber guard in sync with the shared flag, whichever
    # order (shared=True then store swap, or the reverse) it is set in
    if name == "shared" and value:
        store = getattr(self, "store", None)
        if store is not None:
            store._san_shared_guard = True
    elif name == "store" and value is not None and \
            getattr(self, "shared", False):
        value._san_shared_guard = True


def _store_discard(self, tokens):
    if getattr(self, "_san_shared_guard", False):
        raise SanitizerError(
            "shared-clobber",
            f"discard() on a cluster-SHARED tier's store (key of "
            f"{len(tuple(tokens))} tokens) — shared-tier entries leave "
            f"only by eviction or same-key replace; a MOVE removes the "
            f"copy every other worker's hierarchy relies on")
    return _orig["PrefixKVStore.discard"](self, tokens)


def _dw_release(self, slot) -> None:
    _orig["DecodeWorker.release"](self, slot)
    pt = getattr(self, "page_table", None)
    if pt is not None and pt.pages.get(slot.idx):
        raise SanitizerError(
            "leaked-pages",
            f"decode worker {self.wid} freed slot {slot.idx} but it "
            f"still owns {len(pt.pages[slot.idx])} page(s)")


def _rt_run(self, max_steps: int = 10_000):
    out = _orig["ClusterRuntime.run"](self, max_steps)
    if self.scheduler.idle:
        for dw in self.decode_workers:
            if dw.page_table is not None:
                check_drained(
                    dw.page_table,
                    live_slots=[s.idx for s in dw.slots.values()])
    return out


# ---------------------------------------------------------------------------
def install() -> None:
    """Patch the KV classes in place (idempotent)."""
    global _installed
    if _installed:
        return
    from repro.core.kvcache import PageTable
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.kvstore import KVTier, PrefixKVStore
    from repro.serving.workers import DecodeWorker

    _orig["PageTable.ensure"] = PageTable.ensure
    _orig["PageTable.release"] = PageTable.release
    _orig["PageTable.release_tail"] = PageTable.release_tail
    _orig["PageTable.block_row"] = PageTable.block_row
    _orig["KVTier.__setattr__"] = KVTier.__setattr__
    _orig["PrefixKVStore.discard"] = PrefixKVStore.discard
    _orig["DecodeWorker.release"] = DecodeWorker.release
    _orig["ClusterRuntime.run"] = ClusterRuntime.run

    PageTable.ensure = _pt_ensure
    PageTable.release = _pt_release
    PageTable.release_tail = _pt_release_tail
    PageTable.block_row = _pt_block_row
    KVTier.__setattr__ = _kvtier_setattr
    PrefixKVStore.discard = _store_discard
    DecodeWorker.release = _dw_release
    ClusterRuntime.run = _rt_run

    # NOTE: tiers flagged shared BEFORE install() are guarded from their
    # next .shared/.store assignment on; install early (conftest does, at
    # session start) to cover construction-time flags.
    _installed = True


def uninstall() -> None:
    """Restore the original methods (idempotent)."""
    global _installed
    if not _installed:
        return
    from repro.core.kvcache import PageTable
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.kvstore import KVTier, PrefixKVStore
    from repro.serving.workers import DecodeWorker

    PageTable.ensure = _orig.pop("PageTable.ensure")
    PageTable.release = _orig.pop("PageTable.release")
    PageTable.release_tail = _orig.pop("PageTable.release_tail")
    PageTable.block_row = _orig.pop("PageTable.block_row")
    KVTier.__setattr__ = _orig.pop("KVTier.__setattr__")
    PrefixKVStore.discard = _orig.pop("PrefixKVStore.discard")
    DecodeWorker.release = _orig.pop("DecodeWorker.release")
    ClusterRuntime.run = _orig.pop("ClusterRuntime.run")
    _installed = False


def install_from_env() -> bool:
    """Install iff ``REPRO_SANITIZE=1``; returns whether installed."""
    if env_requested():
        install()
        return True
    return False
