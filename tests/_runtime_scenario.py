"""The pinned continuous-batching scenario used for token-exact parity.

The exact same driver ran against the PR-1 per-slot decode loop to produce
``tests/fixtures/pr1_runtime_tokens.json`` (pool hit/miss mix, staggered
admissions, out_tokens shorter than the decode budget for some requests);
the batched slot-arena runtime must reproduce those tokens bit-for-bit.
Only public ServingRuntime API is used so the driver is implementation-
agnostic.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Dict, List

import numpy as np

FIXTURE = Path(__file__).parent / "fixtures" / "pr1_runtime_tokens.json"

# (workload, slo_class, prompt_seed, out_tokens, steps-before-next-submit)
SCENARIO = [
    ("qalike", "standard", 0, None, 1),
    ("codelike", "interactive", 1, 4, 0),
    ("mathlike", "batch", 2, None, 2),
    ("qalike", "standard", 0, None, 1),      # pool hit on rid 0's prefix
    ("summlike", "standard", 3, 3, 0),
    ("codelike", "interactive", 1, None, 1),  # pool hit on rid 1's prefix
    ("mathlike", "batch", 2, 5, 0),           # pool hit on rid 2's prefix
    ("qalike", "batch", 4, None, 2),
]


def params_digest(params) -> str:
    """Stable digest of the reference-model weights (fixture validity key)."""
    import jax
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf, np.float32).tobytes())
    return h.hexdigest()[:16]


def build_runtime(reference_model=None, **cfg_overrides):
    """``cfg_overrides`` lands extra RuntimeConfig fields (mode, paged,
    spec_k, ...) on top of the pinned scenario config — the fixture
    parity tests sweep runtime variants over the SAME request stream."""
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    from repro.serving import BandwidthTrace, GBPS, SchedulerConfig
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    profile = Profile(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_channel"),
        cr=2.0, s_enc=5e8, s_dec=5e8)
    rt = ServingRuntime(
        static_profile=profile,
        config=RuntimeConfig(seq=64, decode_tokens=6, prefill_tok_s=2000.0,
                             decode_tok_s=500.0, **cfg_overrides),
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=32))
    if reference_model is not None:
        rt.model_cfg, rt.params = reference_model
    return rt


def run_scenario(rt) -> Dict[str, Dict]:
    """Drive the scenario; returns {rid: {workload, pool_hit, tokens}}."""
    for w, slo_class, seed, out_tokens, steps_after in SCENARIO:
        rt.submit(w, slo_class=slo_class, prompt_seed=seed,
                  out_tokens=out_tokens)
        for _ in range(steps_after):
            rt.step()
    rt.run()
    return {
        str(r.rid): {"workload": r.workload, "pool_hit": bool(r.pool_hit),
                     "tokens": [int(t) for t in r.tokens]}
        for r in rt.completed
    }


def capture_fixture() -> Dict:
    """Regenerate the fixture payload from the current runtime."""
    rt = build_runtime()
    outputs = run_scenario(rt)
    return {"params_digest": params_digest(rt.params), "outputs": outputs}


if __name__ == "__main__":
    payload = capture_fixture()
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE.write_text(json.dumps(payload, indent=1, sort_keys=True))
    print(f"wrote {FIXTURE} ({len(payload['outputs'])} requests, "
          f"digest {payload['params_digest']})")
