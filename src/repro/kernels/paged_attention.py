"""Pallas TPU kernel: paged quantized decode attention (DESIGN.md §12).

The paged decode arena stores KV as fixed-size pages in a shared pool;
each serving slot owns an ordered list of page ids (its block-table
row).  This kernel gathers a slot's pages straight out of the pool via
scalar-prefetch block-table indexing (``PrefetchScalarGridSpec``) and
fuses int8 / packed-int4 dequantization into the flash-decoding
online-softmax loop — the paged analogue of ``decode_attention.py`` —
so compressed pages are consumed in place and never materialize as
bf16 in HBM.

Grid: (B, Hkv, PPS).  Pages are the innermost (sequential) axis; the
running max / denominator / accumulator persist in VMEM scratch across
pages.  The flattened block table and the per-slot lengths ride ahead
of the grid in SMEM (``num_scalar_prefetch=2``) so the pool BlockSpecs
can do the data-dependent page lookup in their index maps.

Unmapped block-table entries point at page 0 — the arena's reserved
scratch page, never allocated to a slot — and every position they
cover lies at or beyond ``kv_lens[b]``, so the mask sends those scores
to -inf before the softmax: whatever the scratch page holds contributes
exactly zero.  ``kv_lens`` must be >= 1 per row (a fully masked row
would push NaN through the running max, same contract as
``decode_attention``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_attn_kernel(bt_ref, kvl_ref, q_ref, kc_ref, ks_ref, vc_ref,
                       vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                       bits: int, group: int, page_size: int,
                       sm_scale: float):
    del bt_ref  # consumed by the BlockSpec index maps, not the body
    b_idx = pl.program_id(0)
    p_idx = pl.program_id(2)
    n_p = pl.num_programs(2)
    kv_len = kvl_ref[b_idx]

    @pl.when(p_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _dequant(c_ref, s_ref):
        c = c_ref[0, 0]  # (PS, D') packed page
        if bits == 4:
            lo = (c & jnp.uint8(0x0F)).astype(jnp.int32) - 8
            hi = (c >> jnp.uint8(4)).astype(jnp.int32) - 8
            q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0],
                                                     c.shape[1] * 2)
        else:
            q = c.astype(jnp.int32)
        ps, d = q.shape
        sc = s_ref[0, 0].astype(jnp.float32)  # (PS, D/group)
        x = q.reshape(ps, d // group, group).astype(jnp.float32) * sc[..., None]
        return x.reshape(ps, d)

    k = _dequant(kc_ref, ks_ref)  # (PS, D) f32
    v = _dequant(vc_ref, vs_ref)
    q = q_ref[0, 0].astype(jnp.float32)  # (Gq, D)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale  # (Gq, PS)

    # Mask positions at/beyond this slot's length (covers scratch pages).
    base = p_idx * page_size
    pos = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < kv_len, scores, -jnp.inf)

    m_prev = m_scr[...]           # (Gq, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)   # (Gq, PS)
    l_new = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(p_idx == n_p - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention(
    q: jnp.ndarray,             # (B, Hkv, Gq, D)
    k_codes: jnp.ndarray,       # (P, Hkv, PS, D) int8 or (P, Hkv, PS, D/2) u8
    k_scale: jnp.ndarray,       # (P, Hkv, PS, D/group) f32
    v_codes: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, PPS) int32 page ids; 0 = unmapped
    kv_lens: jnp.ndarray,       # (B,) int32 valid lengths, each >= 1
    *,
    bits: int = 8,
    group: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode attention of one new token per slot against paged quantized KV.

    Logical position ``t`` of slot ``b`` lives at row ``t % PS`` of pool
    page ``block_tables[b, t // PS]``.  The block table and lengths are
    traced (scalar-prefetched), so page churn never recompiles.
    """
    b, hkv, gq, d = q.shape
    p_total, hkv_k, ps, cw = k_codes.shape
    assert hkv_k == hkv, (hkv_k, hkv)
    assert cw == (d if bits == 8 else d // 2), (cw, d, bits)
    ng = k_scale.shape[3]
    pps = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_paged_attn_kernel, bits=bits, group=group,
                               page_size=ps, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, gq, d),
                         lambda i, j, p, bt, kvl: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ps, cw),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
            pl.BlockSpec((1, 1, ps, ng),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
            pl.BlockSpec((1, 1, ps, cw),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
            pl.BlockSpec((1, 1, ps, ng),
                         lambda i, j, p, bt, kvl: (bt[i, p], j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gq, d),
                               lambda i, j, p, bt, kvl: (i, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gq, 1), jnp.float32),   # running max
            pltpu.VMEM((gq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((gq, d), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gq, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(kv_lens, jnp.int32),
      q, k_codes, k_scale, v_codes, v_scale)
