"""The 10 assigned architectures (exact configs from the assignment) plus the
paper's own evaluation models and a tiny byte-LM for the serving engine.

Each assigned arch also has per-file aliases under ``repro/configs/<id>.py``.
"""
from repro.configs import register
from repro.configs.base import ModelConfig

# --------------------------------------------------------------------------
# Assigned pool — LM-family transformers.
# --------------------------------------------------------------------------

# qwen3-4b [dense] 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936
#   qk_norm, GQA [hf:Qwen/Qwen3-8B]  (qwen3 family uses explicit head_dim=128)
QWEN3_4B = register(ModelConfig(
    name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
    num_heads=32, kv_heads=8, d_ff=9728, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
))

# gemma2-9b [dense] 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000
#   local+global alternating, logit softcap [arXiv:2408.00118]
GEMMA2_9B = register(ModelConfig(
    name="gemma2-9b", family="dense", num_layers=42, d_model=3584,
    num_heads=16, kv_heads=8, d_ff=14336, vocab_size=256000, head_dim=256,
    attn_softcap=50.0, final_softcap=30.0, sliding_window=4096,
    local_global_period=2,
))

# granite-20b [dense] 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
GRANITE_20B = register(ModelConfig(
    name="granite-20b", family="dense", num_layers=52, d_model=6144,
    num_heads=48, kv_heads=1, d_ff=24576, vocab_size=49152,
))

# minicpm-2b [dense] 40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760
#   vocab=122753 — WSD schedule (arch=llama-like) [arXiv:2404.06395]
MINICPM_2B = register(ModelConfig(
    name="minicpm-2b", family="dense", num_layers=40, d_model=2304,
    num_heads=36, kv_heads=36, d_ff=5760, vocab_size=122753,
    lr_schedule="wsd", tie_embeddings=True,
))

# jamba-v0.1-52b [hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
#   vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave (attn at index 4
#   of each 8-layer block), MoE every other layer [arXiv:2403.19887]
JAMBA_52B = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    num_heads=32, kv_heads=8, d_ff=14336, vocab_size=65536,
    ssm=True, attn_period=8, attn_offset=4, ssm_state=16,
    moe=True, num_experts=16, experts_per_token=2, moe_period=2,
    sub_quadratic=True,
))

# whisper-small [audio] 12L d_model=768 12H d_ff=3072 vocab=51865
#   enc-dec, conv frontend (stub) [arXiv:2212.04356]
WHISPER_SMALL = register(ModelConfig(
    name="whisper-small", family="encdec", num_layers=12, d_model=768,
    num_heads=12, kv_heads=12, d_ff=3072, vocab_size=51865,
    encoder_decoder=True, enc_layers=12, dec_seq=448, frontend="audio",
))

# qwen2-vl-72b [vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064
#   M-RoPE, dynamic resolution [arXiv:2409.12191]
QWEN2_VL_72B = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, kv_heads=8, d_ff=29568, vocab_size=152064,
    mrope=True, vision_prefix_frac=0.125, frontend="vision", rope_theta=1e6,
))

# llama4-scout-17b-a16e [moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192
#   MoE 16e top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E]
LLAMA4_SCOUT = register(ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120,
    num_heads=40, kv_heads=8, d_ff=8192, vocab_size=202048,
    moe=True, num_experts=16, experts_per_token=1, num_shared_experts=1,
    rope_theta=5e5,
))

# deepseek-moe-16b [moe] 28L d_model=2048 16H (MHA kv=16) d_ff=1408
#   vocab=102400, 2 shared + 64 routed top-6, fine-grained; dense layer 0
#   [arXiv:2401.06066]
DEEPSEEK_MOE_16B = register(ModelConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, kv_heads=16, d_ff=1408, vocab_size=102400,
    moe=True, num_experts=64, experts_per_token=6, num_shared_experts=2,
    moe_dense_prefix=1,
))

# falcon-mamba-7b [ssm] 64L d_model=4096 (attn-free) vocab=65024 ssm_state=16
FALCON_MAMBA_7B = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
    num_heads=1, kv_heads=1, d_ff=0, vocab_size=65024,
    ssm=True, ssm_state=16, sub_quadratic=True,
))

# --------------------------------------------------------------------------
# The paper's own evaluation models (Sec. 7.1) — extra configs.
# --------------------------------------------------------------------------
QWEN25_7B = register(ModelConfig(
    name="qwen2.5-7b", family="dense", num_layers=28, d_model=3584,
    num_heads=28, kv_heads=4, d_ff=18944, vocab_size=152064, rope_theta=1e6,
))

LLAMA31_8B = register(ModelConfig(
    name="llama3.1-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, kv_heads=8, d_ff=14336, vocab_size=128256, rope_theta=5e5,
))

QWEN25_32B = register(ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, kv_heads=8, d_ff=27648, vocab_size=152064, rope_theta=1e6,
))

# --------------------------------------------------------------------------
# Tiny byte-LM: the reference model for the quality proxy + serving engine.
# --------------------------------------------------------------------------
TINY_LM = register(ModelConfig(
    name="tiny-lm", family="dense", num_layers=4, d_model=128,
    num_heads=4, kv_heads=2, d_ff=384, vocab_size=259, head_dim=32,
    tie_embeddings=True,
))
