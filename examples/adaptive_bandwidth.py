"""Fig-16-right style demo: the online controller + residual bandit under a
fluctuating bandwidth trace, vs static baselines (simulator-based, fast).

    PYTHONPATH=src python examples/adaptive_bandwidth.py
"""
import numpy as np

from repro.controller import ServiceAwareController
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.data.synthetic import WORKLOADS
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    NoCompressionPolicy,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)


def synthetic_profiles():
    rng = np.random.default_rng(7)
    out = []
    for i in range(16):
        cr = float(rng.uniform(1.5, 9.0))
        s = float(rng.uniform(5e8, 2e10))
        q = {w: float(np.clip(1.0 - 0.005 * cr**1.5, 0, 1)) for w in WORKLOADS}
        out.append(Profile(StrategyConfig(key_bits=2 + (i % 7),
                                          group_size=(32, 64, 128)[i % 3]),
                           cr=cr, s_enc=2 * s, s_dec=2 * s, quality=q))
    return out


def main():
    profiles = synthetic_profiles()
    trace = lambda: BandwidthTrace.steps(
        [(0.0, 2 * GBPS), (20.0, 0.05 * GBPS), (40.0, 2 * GBPS)],
        jitter=0.2, seed=3)
    reqs = lambda: WorkloadMix(rate=1.5, seed=0, q_min=0.0).generate(80)

    rows = {}
    rows["default"] = Simulator(SimConfig(), NoCompressionPolicy(), trace(),
                                reqs()).run()
    best_static = max(profiles, key=lambda p: p.cr)
    rows["static-maxcr"] = Simulator(SimConfig(),
                                     StaticPolicy(best_static, "s"),
                                     trace(), reqs()).run()
    for name, kw in [("kvserve", {}),
                     ("kvserve(no bandit)", dict(use_bandit=False)),
                     ("kvserve(no controller)", dict(use_bandit=False,
                                                     use_envelope=False))]:
        c = ServiceAwareController({w: profiles for w in WORKLOADS}, **kw)
        rows[name] = Simulator(SimConfig(estimator_alpha=0.5),
                               KVServePolicy(c), trace(), reqs()).run()

    print(f"{'policy':24s} {'mean JCT':>9s} {'p95':>9s}")
    for name, res in rows.items():
        print(f"{name:24s} {res.mean_jct():9.2f} {res.p95_jct():9.2f}")


if __name__ == "__main__":
    main()
