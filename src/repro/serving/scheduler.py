"""Continuous-batching request scheduler with admission control and
SLO-class priorities.

One policy layer, two execution backends (DESIGN.md §9): the
real-execution :class:`~repro.serving.engine.ServingRuntime` drives
:class:`ContinuousScheduler` at iteration granularity (each ``step()``
admits up to ``max_prefills_per_step`` prefill slots and advances every
in-flight decode slot by one token), and the event-driven
:class:`~repro.serving.simulator.Simulator` uses the same
:func:`priority_key` / :class:`AdmissionController` to order and gate its
dispatch loop.  Keeping the policy functions pure (request, clock, config)
is what lets both backends share them.

Priority model: requests carry an SLO class (``interactive`` < ``standard``
< ``batch``; see :data:`repro.serving.kvstore.SLO_CLASSES`).  Within a
class, tighter-deadline-first (slack), then FIFO.  Waiting requests age
one class per ``aging_s`` seconds so batch traffic cannot starve.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.serving.kvstore import SLO_CLASSES, slo_rank
from repro.serving.request import Request


@dataclass
class SchedulerConfig:
    max_slots: int = 8            # in-flight (decode) slots
    max_prefills_per_step: int = 1  # iteration-level prefill admission
    max_queue: int = 64           # admission: bound on waiting requests
    admission: str = "reject"     # "reject" | "always" (no queue bound)
    aging_s: float = 10.0         # waiting this long promotes one SLO class


def priority_key(req: Request, now: float,
                 cfg: Optional[SchedulerConfig] = None
                 ) -> Tuple[float, float, float]:
    """Total order over waiting requests; lower sorts first.

    ``(effective_class, slo_slack, arrival)`` — effective class is the SLO
    class rank minus aging promotions; slack is seconds until the request's
    deadline (infinite without an SLO).
    """
    aging = cfg.aging_s if cfg is not None else 0.0
    rank = float(slo_rank(req.slo_class))
    waited = max(now - req.arrival, 0.0)
    if aging > 0:
        rank -= int(waited // aging)
    slack = (req.arrival + req.t_slo - now) if req.t_slo > 0 else math.inf
    return (rank, slack, req.arrival)


class AdmissionController:
    """Bounded-queue admission shared by engine and simulator."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.admitted = 0
        self.rejected = 0

    def admit(self, req: Request, queue_depth: int, now: float) -> bool:
        if self.cfg.admission != "always" and queue_depth >= self.cfg.max_queue:
            self.rejected += 1
            return False
        self.admitted += 1
        return True


class ContinuousScheduler:
    """Iteration-level scheduler: a priority queue of waiting requests plus
    a bounded set of in-flight slots."""

    def __init__(self, cfg: Optional[SchedulerConfig] = None,
                 manage_slots: bool = True):
        self.cfg = cfg or SchedulerConfig()
        self.admission = AdmissionController(self.cfg)
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self.finished: List[Request] = []
        # Physical arena slot ids, recycled LIFO so a hot slot's cache row
        # is reused first.  len(running) <= max_slots keeps this non-empty
        # whenever next_prefills admits.  A multi-worker cluster passes
        # manage_slots=False: slots are then owned by each DecodeWorker's
        # local arena (the scheduler keeps only admission + priority), and
        # requests are admitted through :meth:`admit` instead of
        # :meth:`next_prefills`.
        self.manage_slots = manage_slots
        self._free_slots: List[int] = list(range(self.cfg.max_slots))[::-1]

    # ------------------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def in_flight(self) -> int:
        return len(self.running)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float) -> bool:
        """Admission-controlled enqueue.  False = rejected (load shed)."""
        if not self.admission.admit(req, len(self.waiting), now):
            req.chosen = "rejected"
            req.state = "rejected"
            req.slo_violated = req.t_slo > 0
            req.done = req.arrival
            return False
        req.state = "waiting"
        self.waiting.append(req)
        return True

    def pop_next(self, now: float) -> Optional[Request]:
        """Highest-priority waiting request (None if queue empty).

        Re-sorts per pop because priority_key is time-varying (aging,
        slack), which a static heap can't express; the queue is bounded by
        max_queue, so the cost stays small."""
        if not self.waiting:
            return None
        self.waiting.sort(key=lambda r: priority_key(r, now, self.cfg))
        return self.waiting.pop(0)

    def peek_order(self, now: float) -> List[Request]:
        return sorted(self.waiting, key=lambda r: priority_key(r, now, self.cfg))

    # ------------------------------------------------------------------
    def next_prefills(self, now: float) -> List[Request]:
        """The iteration's prefill admissions: up to ``max_prefills_per_step``
        waiting requests, bounded by free slots.  Each returned request is
        moved into a running slot and carries its arena slot id in
        ``req.slot``."""
        free = self.cfg.max_slots - len(self.running)
        n = min(self.cfg.max_prefills_per_step, free, len(self.waiting))
        out: List[Request] = []
        for _ in range(max(n, 0)):
            req = self.pop_next(now)
            if req is None:
                break
            req.slot = self._free_slots.pop()
            req.state = "prefilling"
            self.running[req.rid] = req
            out.append(req)
        return out

    def admit(self, now: float) -> Optional[Request]:
        """Move the highest-priority waiting request into ``running``
        WITHOUT assigning an arena slot — the multi-worker path: the
        caller routes the request to a worker, which assigns a slot from
        its own local pool.  Returns None when the queue is empty."""
        req = self.pop_next(now)
        if req is None:
            return None
        req.state = "prefilling"
        self.running[req.rid] = req
        return req

    def finish(self, rid: int) -> None:
        req = self.running.pop(rid, None)
        if req is not None:
            if self.manage_slots and req.slot is not None:
                self._free_slots.append(req.slot)
            req.state = "done"
            self.finished.append(req)

    # ------------------------------------------------------------------
    def state_counts(self) -> Dict[str, int]:
        """Lifecycle census over non-terminal requests (waiting ->
        prefilling -> transferring -> decoding; see
        :data:`repro.serving.request.LIFECYCLE`)."""
        counts: Dict[str, int] = {}
        for req in list(self.waiting) + list(self.running.values()):
            counts[req.state] = counts.get(req.state, 0) + 1
        return counts
