"""Offline profiling driver: searches the strategy space with the Bayesian
Profiling Engine, measures (CR, s_enc, s_dec, quality) per candidate, and
distils the 3D Pareto frontier used by the online controller.

``python -m repro.launch.profile_offline --level module --out profiles.jsonl``

This is the "Offline Profiling" stage of KVServe's three-stage operation
(Fig. 6); the result feeds ``repro.controller.ServiceAwareController``.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    IDENTITY_PROFILE,
    KVCache,
    Profile,
    StrategyConfig,
    enumerate_space,
    measure_profile,
)
from repro.core.profiles import save_profiles
from repro.core.quality import calibrate_head_scores, evaluate_quality, get_reference_model
from repro.data.synthetic import WORKLOADS
from repro.profiling import BOConfig, pareto_frontier, run_bo
from repro.profiling.pareto import ParetoPoint, profile_latency


def build_profiles(
    strategies: Sequence[StrategyConfig],
    workloads: Sequence[str] = tuple(WORKLOADS),
    kv_samples: Optional[List[KVCache]] = None,
    with_quality: bool = True,
    quality_kwargs: Optional[Dict] = None,
    head_scores=None,
    verbose: bool = False,
) -> List[Profile]:
    """Measure the full profile triple for a set of strategies."""
    if kv_samples is None:
        kv_samples = [KVCache.random(4, 2, 192, 32, seed=s) for s in range(2)]
    ref = get_reference_model() if with_quality else None
    out: List[Profile] = [IDENTITY_PROFILE]
    qk = quality_kwargs or {}
    for i, s in enumerate(strategies):
        qf = (lambda cfg: evaluate_quality(cfg, workloads=workloads, ref=ref,
                                           head_scores=head_scores, **qk)) \
            if with_quality else None
        p = measure_profile(s, kv_samples, quality_fn=qf,
                            head_scores=head_scores)
        out.append(p)
        if verbose:
            q = min(p.quality.values()) if p.quality else 1.0
            print(f"[{i+1}/{len(strategies)}] {s.short_name():42s} "
                  f"cr={p.cr:5.2f} s={p.s_eff/1e6:8.1f}MB/s minq={q:.3f}")
    return out


def search_and_build(
    level: str = "module",
    workload: str = "qalike",
    acc_threshold: float = 0.97,
    max_iters: int = 60,
    seed: int = 0,
    unified: bool = False,
    verbose: bool = False,
) -> Tuple[List[Profile], List[ParetoPoint]]:
    """BO search (Alg. 1) on one workload (KVServe-Aware) or the workload
    mix (KVServe-Unified), then Pareto distillation."""
    ref = get_reference_model()
    head_scores = calibrate_head_scores(ref=ref)
    space = enumerate_space(level)
    kv_samples = [KVCache.random(4, 2, 192, 32, seed=s) for s in range(2)]
    workloads = tuple(WORKLOADS) if unified else (workload,)

    cache: Dict[str, Tuple[float, float]] = {}

    def evaluate(cfg: StrategyConfig) -> Tuple[float, float]:
        key = cfg.key()
        if key in cache:
            return cache[key]
        q = evaluate_quality(cfg, workloads=workloads, ref=ref,
                             head_scores=head_scores)
        p = measure_profile(cfg, kv_samples, head_scores=head_scores)
        acc = float(np.mean(list(q.values())))
        cache[key] = (acc, p.cr)
        if verbose:
            print(f"  eval {cfg.short_name():42s} acc={acc:.3f} cr={p.cr:.2f}")
        return cache[key]

    bo = run_bo(space, evaluate,
                BOConfig(acc_threshold=acc_threshold, max_iters=max_iters,
                         seed=seed))
    feas_cfgs = [o.cfg for o in bo.feasible]
    profiles = build_profiles(feas_cfgs, workloads=workloads,
                              head_scores=head_scores, verbose=verbose)
    pts = [ParetoPoint(acc=p.q(workload), cr=p.cr,
                       lat=profile_latency(p, 1e9), profile=p)
           for p in profiles]
    frontier = pareto_frontier(pts)
    return profiles, frontier


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--level", default="module",
                    choices=["pipeline", "module", "hybrid"])
    ap.add_argument("--workload", default="qalike")
    ap.add_argument("--unified", action="store_true")
    ap.add_argument("--acc-threshold", type=float, default=0.97)
    ap.add_argument("--max-iters", type=int, default=60)
    ap.add_argument("--out", default="profiles.jsonl")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    t0 = time.time()
    profiles, frontier = search_and_build(
        level=args.level, workload=args.workload, unified=args.unified,
        acc_threshold=args.acc_threshold, max_iters=args.max_iters,
        seed=args.seed, verbose=True)
    save_profiles(profiles, args.out)
    print(f"\n{len(profiles)} profiles ({len(frontier)} on the 3D Pareto "
          f"frontier) -> {args.out} in {time.time()-t0:.1f}s")
    for pt in sorted(frontier, key=lambda p: -p.cr)[:10]:
        print(f"  acc={pt.acc:.3f} cr={pt.cr:5.2f} lat/B={pt.lat:.3e} "
              f"{pt.profile.strategy.short_name()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
