"""determinism: replay-safety of the virtual-clock simulator + workloads.

The event-driven simulator and the workload generators are the repo's
replay surface: every run is a pure function of (config, seed) — PR 2
restored this invariant by hand after stateful jitter crept in, and PR 6
built the trace-replay harness on top of it.  This rule keeps the
invariant mechanical:

* **Wall-clock in virtual-clock paths** — ``time.time`` /
  ``perf_counter`` / ``monotonic`` / ``datetime.now`` have no place in a
  simulator whose clock is virtual; a replay on different hardware would
  diverge.
* **Unseeded / global-state RNG** — ``np.random.default_rng()`` with no
  seed, the legacy ``np.random.*`` module API (global state), and the
  stdlib ``random`` module all make replays irreproducible.  The
  sanctioned shape is a seeded ``np.random.Generator`` threaded
  explicitly (``rng = np.random.default_rng(cfg.seed)``).
* **``id()``-based ordering** — ``sorted(..., key=id)`` (or a key
  lambda calling ``id``) orders by allocation address, which differs
  across processes.  (``id()`` as a cache key with an identity pin —
  the simulator's ``_profile_name`` — is fine: that's caching, not
  ordering.)
* **Stateful jitter** — a ``*jitter*``/``*noise*``/``*perturb*``
  function drawing from a long-lived generator (``self.rng.normal()``)
  depends on global call order, so two runs that interleave events
  differently see different jitter.  The sanctioned shape is PR 2's
  ``_jitter_mult(seed, start, nbytes)``: a LOCAL generator derived from
  (seed, inputs) alone.

Scope: ``serving/simulator.py``, ``serving/network.py`` and
``workloads/``.  Suppression token: ``det-ok``.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Set

from repro.analysis.core import Finding, Project, SourceFile, dotted, func_defs

RULE_ID = "determinism"
TOKEN = "det-ok"

WALL_CLOCK = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "_time.time", "_time.perf_counter",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}
# bare names that are wall-clock when imported from time/datetime
WALL_CLOCK_BARE = {"time", "perf_counter", "monotonic", "process_time"}

# np.random.* tails that are NOT the global-state legacy API
NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                "Philox", "MT19937", "BitGenerator"}

JITTER_RE = re.compile(r"jitter|noise|perturb")
DRAWS = {"normal", "standard_normal", "uniform", "random", "integers",
         "choice", "exponential", "poisson", "lognormal", "gamma",
         "shuffle", "permutation"}


def _in_scope(f: SourceFile) -> bool:
    if f.in_dir("tests") or f.in_dir("benchmarks") or f.in_dir("examples"):
        return False
    name = f.parts[-1] if f.parts else ""
    return f.in_dir("workloads") or name in ("simulator.py", "network.py")


def _wallclock_imports(tree: ast.Module) -> Set[str]:
    """Bare names imported from time/datetime that read the wall clock."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and \
                node.module in ("time", "datetime"):
            for alias in node.names:
                if alias.name in WALL_CLOCK_BARE:
                    out.add(alias.asname or alias.name)
    return out


# ---------------------------------------------------------------------------
def _check_calls(f: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    bare_clock = _wallclock_imports(f.tree)
    for node in ast.walk(f.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if d in WALL_CLOCK or (isinstance(node.func, ast.Name)
                               and node.func.id in bare_clock):
            findings.append(Finding(
                RULE_ID, f.rel, node.lineno,
                f"wall-clock call `{d or node.func.id}()` in a "
                f"virtual-clock replay path — replays on different "
                f"hardware diverge",
                "derive every time from the virtual clock / event "
                "timestamps; annotate `# lint: det-ok(reason)` if this "
                "is genuinely offline instrumentation"))
            continue
        if d in ("np.random.default_rng", "numpy.random.default_rng") \
                and not node.args and not node.keywords:
            findings.append(Finding(
                RULE_ID, f.rel, node.lineno,
                "`default_rng()` with no seed — entropy-seeded, so no "
                "two replays draw the same stream",
                "seed from config: `np.random.default_rng(cfg.seed)`"))
            continue
        parts = d.split(".")
        if len(parts) >= 3 and parts[0] in ("np", "numpy") \
                and parts[1] == "random" and parts[2] not in NP_RANDOM_OK:
            findings.append(Finding(
                RULE_ID, f.rel, node.lineno,
                f"legacy global-state RNG `{d}()` — draws depend on "
                f"every other np.random call in the process",
                "thread a seeded np.random.Generator instead"))
            continue
        if parts[0] == "random" and len(parts) == 2:
            if parts[1] == "Random" and (node.args or node.keywords):
                continue   # random.Random(seed) is explicitly seeded
            findings.append(Finding(
                RULE_ID, f.rel, node.lineno,
                f"stdlib `{d}()` — module-global RNG state is not "
                f"replay-safe",
                "use a seeded np.random.Generator threaded through "
                "the call"))
    return findings


# ---------------------------------------------------------------------------
def _key_uses_id(key: ast.AST) -> bool:
    if isinstance(key, ast.Name) and key.id == "id":
        return True
    for n in ast.walk(key):
        if isinstance(n, ast.Call) and dotted(n.func) == "id":
            return True
    return False


def _check_id_ordering(f: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(f.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        is_order = d in ("sorted", "min", "max") or d.endswith(".sort")
        if not is_order:
            continue
        for kw in node.keywords:
            if kw.arg == "key" and _key_uses_id(kw.value):
                findings.append(Finding(
                    RULE_ID, f.rel, node.lineno,
                    f"`{d}(..., key=id)`-style ordering — allocation "
                    f"addresses differ across processes, so replay "
                    f"order differs",
                    "order by a stable field (rid, name, arrival) "
                    "instead of object identity"))
    return findings


# ---------------------------------------------------------------------------
def _check_stateful_jitter(f: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for fn in func_defs(f.tree):
        if not JITTER_RE.search(fn.name):
            continue
        # locals assigned from an explicitly seeded generator are pure
        seeded: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call):
                vd = dotted(node.value.func)
                if vd.rsplit(".", 1)[-1] in ("default_rng", "Random") \
                        and (node.value.args or node.value.keywords):
                    seeded.add(node.targets[0].id)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in DRAWS):
                continue
            recv = node.func.value
            if isinstance(recv, ast.Name) and recv.id in seeded:
                continue
            if dotted(recv).rsplit(".", 1)[-1] in ("random", "np", "numpy"):
                continue   # np.random.* handled by the RNG check above
            findings.append(Finding(
                RULE_ID, f.rel, node.lineno,
                f"`{fn.name}()` draws jitter from a long-lived generator "
                f"(`{dotted(recv) or '<expr>'}.{node.func.attr}`) — the "
                f"draw depends on global call order, not on "
                f"(seed, inputs)",
                "make jitter a pure function of (seed, inputs): build a "
                "local `np.random.default_rng(seed ^ hash(inputs))` "
                "per call (see BandwidthTrace._jitter_mult)"))
    return findings


# ---------------------------------------------------------------------------
def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for f in project.matching(_in_scope):
        findings.extend(_check_calls(f))
        findings.extend(_check_id_ordering(f))
        findings.extend(_check_stateful_jitter(f))
    return findings
