"""clock-accounting: virtual-clock billing invariants in serving/.

The runtime's core contract is that per-request ``breakdown`` dicts sum
exactly to the reported JCT (asserted end-to-end by the benchmarks).
Three statically checkable ways that contract has broken in past PRs:

* **dead-time-component** — a ``t_*`` local is computed but never
  consumed: the component exists in the cost model but is billed zero
  times (PR 3's identity-fallback bug shape).
* **double-billed-key** — the same breakdown key is plain-assigned twice
  on one control-flow path: the first component is silently dropped
  (use ``+=`` to accumulate, or distinct keys).
* **clock-regression** — an assignment to a ``clock``/``now``/
  ``free_at`` attribute whose right-hand side is not provably
  monotone (derived from ``max(...)``, from the attribute's own prior
  value, or from a local that is).  Virtual clocks only move forward.

Scope: modules under ``serving/`` (the virtual clock lives there).
Suppression token: ``clock-ok``.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Set, Tuple

from repro.analysis.core import Finding, Project, SourceFile, dotted, func_defs

RULE_ID = "clock-accounting"
TOKEN = "clock-ok"

T_VAR = re.compile(r"^t_\w+$")
CLOCK_ATTRS = {"clock", "now", "free_at"}
CLOCK_EXEMPT_FUNCS = {"__init__", "reset"}
BREAKDOWN_BASES = re.compile(r"(^|\.)(breakdown|bd)$")


def _in_scope(f: SourceFile) -> bool:
    return f.in_dir("serving") and not f.in_dir("tests")


# ---------------------------------------------------------------------------
# (1) dead t_* stores
# ---------------------------------------------------------------------------
def _dead_time_components(f: SourceFile, fn: ast.FunctionDef) -> List[Finding]:
    stores: Dict[str, int] = {}
    loads: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and T_VAR.match(node.id):
            if isinstance(node.ctx, ast.Store):
                stores.setdefault(node.id, node.lineno)
            else:
                loads.add(node.id)
    out = []
    for name, line in sorted(stores.items(), key=lambda kv: kv[1]):
        if name not in loads:
            out.append(Finding(
                RULE_ID, f.rel, line,
                f"time component `{name}` is computed in {fn.name}() but "
                f"never billed anywhere",
                "add it to the request breakdown / JCT sum, or drop the "
                "computation"))
    return out


# ---------------------------------------------------------------------------
# (2) double-assigned breakdown keys (path-sensitive)
# ---------------------------------------------------------------------------
def _breakdown_key(st: ast.AST) -> Tuple[str, str] | None:
    """('req.breakdown', 'queue') for `req.breakdown["queue"] = ...`."""
    if isinstance(st, ast.Subscript):
        base = dotted(st.value)
        if base and BREAKDOWN_BASES.search(base) and \
                isinstance(st.slice, ast.Constant) and \
                isinstance(st.slice.value, str):
            return base, st.slice.value
    return None


def _double_billed(f: SourceFile, fn: ast.FunctionDef) -> List[Finding]:
    out: List[Finding] = []

    def record(key, line, seen):
        if key in seen:
            out.append(Finding(
                RULE_ID, f.rel, line,
                f"breakdown key {key[1]!r} of `{key[0]}` plain-assigned "
                f"twice on one path (first assignment at line "
                f"{seen[key]}) — the earlier component is dropped",
                "accumulate with `+=`, or bill into a distinct key"))
        seen[key] = line

    def walk(stmts: List[ast.stmt], seen: Dict) -> None:
        for st in stmts:
            if isinstance(st, (ast.Return, ast.Raise, ast.Continue,
                               ast.Break)):
                seen.clear()   # path ends: later assigns are a new path
            elif isinstance(st, ast.Assign):
                for tgt in st.targets:
                    key = _breakdown_key(tgt)
                    if key:
                        record(key, st.lineno, seen)
                    # dict-literal init: bd = {"queue": ...}
                    if isinstance(tgt, (ast.Name, ast.Attribute)) and \
                            BREAKDOWN_BASES.search(dotted(tgt) or "") and \
                            isinstance(st.value, ast.Dict):
                        for k in st.value.keys:
                            if isinstance(k, ast.Constant) and \
                                    isinstance(k.value, str):
                                record((dotted(tgt), k.value),
                                       st.lineno, seen)
            elif isinstance(st, ast.Expr) and isinstance(st.value, ast.Call):
                call = st.value
                if isinstance(call.func, ast.Attribute) and \
                        call.func.attr == "update" and \
                        BREAKDOWN_BASES.search(dotted(call.func.value) or ""):
                    for kw in call.keywords:
                        if kw.arg:
                            record((dotted(call.func.value), kw.arg),
                                   st.lineno, seen)
            elif isinstance(st, ast.If):
                walk(st.body, dict(seen))
                walk(st.orelse, dict(seen))
            elif isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
                walk(st.body, {})   # fresh per-iteration state
                walk(st.orelse, dict(seen))
            elif isinstance(st, ast.With):
                walk(st.body, seen)
            elif isinstance(st, ast.Try):
                walk(st.body, dict(seen))
                for h in st.handlers:
                    walk(h.body, dict(seen))
                walk(st.orelse, dict(seen))
                walk(st.finalbody, dict(seen))

    walk(fn.body, {})
    return out


# ---------------------------------------------------------------------------
# (3) clock monotonicity
# ---------------------------------------------------------------------------
def _mentions_safe(expr: ast.AST, safe: Set[str]) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and n.attr in CLOCK_ATTRS:
            return True
        if isinstance(n, ast.Name) and n.id in safe:
            return True
        if isinstance(n, ast.Call) and dotted(n.func) == "max":
            return True
    return False


def _clock_regressions(f: SourceFile, fn: ast.FunctionDef) -> List[Finding]:
    if fn.name in CLOCK_EXEMPT_FUNCS:
        return []
    out: List[Finding] = []
    safe: Set[str] = set()
    assigns = sorted((n for n in ast.walk(fn) if isinstance(n, ast.Assign)),
                     key=lambda n: (n.lineno, n.col_offset))
    for node in assigns:
        is_safe_rhs = _mentions_safe(node.value, safe)
        for tgt in node.targets:
            els = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
            for el in els:
                if isinstance(el, ast.Name) and is_safe_rhs:
                    safe.add(el.id)
                if isinstance(el, ast.Attribute) and \
                        el.attr in CLOCK_ATTRS and not is_safe_rhs:
                    out.append(Finding(
                        RULE_ID, f.rel, node.lineno,
                        f"assignment to `{dotted(el)}` is not provably "
                        f"monotone — virtual clocks must never move "
                        f"backwards",
                        "derive the new value from max(...) or from the "
                        "clock's own prior value, or annotate "
                        "`# lint: clock-ok(reason)`"))
    return out


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for f in project.matching(_in_scope):
        for fn in func_defs(f.tree):
            findings.extend(_dead_time_components(f, fn))
            findings.extend(_double_billed(f, fn))
            findings.extend(_clock_regressions(f, fn))
    return findings
