"""Compressed prefix-KV pool store (the paper's KV-disaggregated scenario).

The pool holds :class:`repro.core.pipeline.CompressedKV` payloads (or, for
the event-driven simulator, opaque payloads with the same byte accounting)
keyed by the token prefix that produced them.  Three properties matter for
reproducing the paper's TTFT path (Sec. 7.2 / Fig. 14):

  * **Prefix matching** — lookups walk block-aligned prefixes of the query
    tokens from longest to shortest, so a request whose prompt extends a
    stored prefix still hits (vLLM-style hash-chain prefix caching).
  * **Wire-byte capacity accounting** — the store is a *network-attached*
    pool; what occupies it is the compressed wire footprint, not logical
    KV bytes.  ``used_bytes == sum(entry.wire_bytes) <= capacity_bytes``
    is an invariant after every operation.
  * **SLO-aware LRU eviction** — victims are chosen lowest-SLO-class first
    (batch before standard before interactive), least-recently-used within
    a class, so latency-critical tenants keep their prefixes warm.

Shared by the real-execution :class:`~repro.serving.engine.ServingRuntime`
and the event-driven :class:`~repro.serving.simulator.Simulator` so both
exercise one eviction code path (DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

TokenKey = Tuple[int, ...]

# Rank of each SLO class; lower = more latency-critical = evicted last.
SLO_CLASSES: Dict[str, int] = {"interactive": 0, "standard": 1, "batch": 2}


def slo_rank(slo_class: str) -> int:
    return SLO_CLASSES.get(slo_class, SLO_CLASSES["standard"])


@dataclass
class StoreEntry:
    tokens: TokenKey          # full token prefix this entry caches
    payload: Any              # CompressedKV (+ first token) or sim stand-in
    wire_bytes: int           # compressed wire footprint (capacity unit)
    kv_bytes: float = 0.0     # uncompressed payload V (for fetch modelling)
    workload: str = ""
    slo_class: str = "standard"
    created: float = 0.0
    last_used: float = 0.0
    hits: int = 0

    @property
    def rank(self) -> int:
        return slo_rank(self.slo_class)


@dataclass
class StoreStats:
    hits: int = 0
    misses: int = 0
    # full=True lookups that found no full-coverage entry but DID have a
    # usable block-aligned partial prefix — not a true miss (the prefix is
    # warm; the consumer just can't top-up-prefill the uncovered suffix).
    partial_misses: int = 0
    evictions: int = 0
    rejected_puts: int = 0    # payload alone exceeded capacity

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses + self.partial_misses
        return self.hits / n if n else 0.0


class PrefixKVStore:
    """Bounded pool of compressed KV prefixes with SLO-aware LRU eviction."""

    def __init__(self, capacity_bytes: int, block: int = 16):
        assert capacity_bytes > 0 and block > 0
        self.capacity_bytes = int(capacity_bytes)
        self.block = int(block)
        self._entries: Dict[TokenKey, StoreEntry] = {}
        self.used_bytes = 0
        self.stats = StoreStats()

    # ------------------------------------------------------------------
    def _prefix_keys(self, tokens: TokenKey) -> List[TokenKey]:
        """Candidate keys, longest first: the full prefix, then every
        block-aligned truncation."""
        tokens = tuple(tokens)
        keys = [tokens]
        n = (len(tokens) - 1) // self.block * self.block
        while n > 0:
            keys.append(tokens[:n])
            n -= self.block
        return keys

    # ------------------------------------------------------------------
    def lookup(self, tokens: TokenKey, now: float = 0.0,
               full: bool = False) -> Optional[StoreEntry]:
        """Longest stored prefix of ``tokens`` (None on miss).  Updates
        recency and hit/miss counters.

        ``full=True`` only accepts an entry covering *all* of ``tokens`` —
        for consumers that cannot top-up-prefill the uncovered suffix of a
        partial prefix match (e.g. the real-execution runtime).

        Entries are only visible once their pool write has completed:
        ``put`` stamps ``created`` with the write-completion time, and a
        lookup at an earlier ``now`` misses (no time-travel hits)."""
        keys = ([tuple(tokens)] if full else self._prefix_keys(tokens))
        for key in keys:
            e = self._entries.get(key)
            if e is not None and e.created <= now:
                e.last_used = now
                e.hits += 1
                self.stats.hits += 1
                return e
        if full and any(
                e is not None and e.created <= now
                for e in (self._entries.get(k)
                          for k in self._prefix_keys(tokens)[1:])):
            # A usable partial prefix exists; the full=True consumer just
            # cannot exploit it.  Distinct from a cold miss.
            self.stats.partial_misses += 1
        else:
            self.stats.misses += 1
        return None

    def contains(self, tokens: TokenKey, now: float = 0.0) -> bool:
        """Exact-key presence under the same write-visibility rule as
        :meth:`lookup`: an entry whose pool write completes after ``now``
        is not visible yet (no time-traveling entries).  Does not touch
        recency or hit/miss counters."""
        e = self._entries.get(tuple(tokens))
        return e is not None and e.created <= now

    # ------------------------------------------------------------------
    def _evict_order(self) -> List[StoreEntry]:
        """Victims first: lowest SLO priority (highest rank), then LRU."""
        return sorted(self._entries.values(),
                      key=lambda e: (-e.rank, e.last_used))

    def _make_room(self, need: int) -> List[StoreEntry]:
        # put() has already rejected payloads larger than the whole pool.
        evicted: List[StoreEntry] = []
        order = self._evict_order()
        while self.used_bytes + need > self.capacity_bytes and order:
            victim = order.pop(0)
            del self._entries[victim.tokens]
            self.used_bytes -= victim.wire_bytes
            self.stats.evictions += 1
            evicted.append(victim)
        return evicted

    # ------------------------------------------------------------------
    def put(self, tokens: TokenKey, payload: Any, wire_bytes: int,
            kv_bytes: float = 0.0, workload: str = "",
            slo_class: str = "standard", now: float = 0.0
            ) -> List[StoreEntry]:
        """Insert (or refresh) the entry for ``tokens``, evicting until it
        fits.  Returns the evicted entries.  A payload larger than the whole
        pool is rejected (counted, nothing evicted for it)."""
        tokens = tuple(tokens)
        wire_bytes = int(wire_bytes)
        if wire_bytes > self.capacity_bytes:
            self.stats.rejected_puts += 1
            return []
        old = self._entries.pop(tokens, None)
        if old is not None:
            self.used_bytes -= old.wire_bytes
        evicted = self._make_room(wire_bytes)
        self._entries[tokens] = StoreEntry(
            tokens=tokens, payload=payload, wire_bytes=wire_bytes,
            kv_bytes=kv_bytes, workload=workload, slo_class=slo_class,
            created=now, last_used=now)
        self.used_bytes += wire_bytes
        assert self.used_bytes <= self.capacity_bytes
        return evicted

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes

    def entries(self) -> List[StoreEntry]:
        return list(self._entries.values())

    def summary(self) -> Dict[str, float]:
        return {
            "entries": len(self._entries),
            "used_bytes": self.used_bytes,
            "capacity_bytes": self.capacity_bytes,
            "hit_rate": self.stats.hit_rate,
            "hits": self.stats.hits,
            "misses": self.stats.misses,
            "partial_misses": self.stats.partial_misses,
            "evictions": self.stats.evictions,
            "rejected_puts": self.stats.rejected_puts,
        }
