"""Domain-specific static analysis for the repro serving stack.

Six repo-specific checkers (DESIGN.md §13/§14) run over the source tree
and fail CI on any unsuppressed finding::

    python -m repro.analysis [--format=json] [--baseline=prev.json] [paths...]

Rules
-----
* ``host-sync``       — device->host syncs reachable from serving hot loops
* ``clock-accounting``— unbilled/double-billed time components, clock
                         regressions in the virtual-clock runtime
* ``units``           — bytes / seconds / bytes-per-second / token mixing
* ``kernel-contract`` — Pallas kernel <-> ref.py oracle <-> parity-test
                         correspondence
* ``ownership``       — worker-local vs cluster-shared object discipline:
                         shared-object mutation outside owner methods,
                         MOVE-shaped ops on shared tiers, unordered
                         iteration feeding routing/eviction decisions
* ``determinism``     — replay safety of the simulator + workloads:
                         wall-clock calls, unseeded/global RNG, id()
                         ordering, stateful jitter

The runtime counterpart of ``ownership`` lives in
:mod:`repro.analysis.sanitize`: an installable KV sanitizer
(``REPRO_SANITIZE=1``) that catches double-release / use-after-release
of arena pages, pages leaked at drain, and shared-tier clobbers while
the tier-1 suite runs.

Intentional patterns are documented (not silenced) inline with
``# lint: <token>(reason)`` — see repro.analysis.core.
"""
from __future__ import annotations

from repro.analysis import (
    clock,
    determinism,
    host_sync,
    kernel_contract,
    ownership,
    units,
)
from repro.analysis.cli import main, run_paths
from repro.analysis.core import Finding, Project, Rule, load_project

ALL_RULES = [
    Rule(host_sync.RULE_ID, host_sync.TOKEN,
         "device->host sync in a serving hot path", host_sync.check),
    Rule(clock.RULE_ID, clock.TOKEN,
         "virtual-clock billing invariant violation", clock.check),
    Rule(units.RULE_ID, units.TOKEN,
         "arithmetic mixing incompatible dimensions", units.check),
    Rule(kernel_contract.RULE_ID, kernel_contract.TOKEN,
         "kernel/oracle/parity-test drift", kernel_contract.check),
    Rule(ownership.RULE_ID, ownership.TOKEN,
         "cluster-shared object mutated/moved outside its owner",
         ownership.check),
    Rule(determinism.RULE_ID, determinism.TOKEN,
         "replay-unsafe construct in the simulator/workload path",
         determinism.check),
]

__all__ = ["ALL_RULES", "Finding", "Project", "Rule", "load_project",
           "main", "run_paths"]
