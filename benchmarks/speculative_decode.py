"""Speculative decode benchmark (ISSUE 10, DESIGN.md §15).

Three layers, from fully deterministic to real-model:

* **Mechanics** — the production draft/verify state machine
  (:class:`~repro.serving.speculative.NGramDraft`, ``accept_length``,
  and the worker's exact commit rule ``c = min(a + 1, needed)``) driven
  against a stream oracle instead of a model: the "target's" greedy
  output at each fed position is the stream's next token, which is
  exactly what a real model emits at every position the commit rule can
  reach (positions past the first mismatch are never committed).  A
  batch-8 repetitive-suffix workload must sustain **>=1.8x decode
  tokens per verify step**; a non-repeating stream (accept ~ 0, the
  n-gram table never matches) must take *exactly* the baseline step
  count — speculation is free to win and forbidden to lose.

* **Simulator** — the ``SimConfig.spec_k`` x ``spec_accept`` sweep: the
  per-request acceptance hash feeds the controller's own geometric
  tokens-per-step model, so simulated decode time must shrink exactly
  where the controller predicts, and ``spec_k = 0`` must be
  bit-identical to a config that predates the fields.

* **Runtime probe** (full mode only, not ``--smoke``) — the real tiny
  model serving 8 concurrent repetitive ``codelike`` requests, spec on
  vs off: >=1.8 committed tokens per verify step, substantially fewer
  serial decode iterations, >=90% token agreement (the pinned
  decode_tokens=6 scenario is bit-exact in the test suite; this longer
  generation is exposed to the bf16 merge-ulp near-tie caveat of
  DESIGN.md §15).  Token streams depend on the trained weights, so this
  layer stays out of the committed JSON.

Determinism contract (mechanics + simulator only): the payload is a
pure function of the configuration — no wall-clock values, floats
rounded to 6 significant digits.  The grid is committed at
``BENCH_speculation.json``; CI regenerates it and fails when the
committed copy is stale (``python -m benchmarks.speculative_decode
--check``).  Refresh with
``python -m benchmarks.speculative_decode --smoke --write``.
"""
from __future__ import annotations

import argparse
import json
import math
import os
from typing import Dict, List, Optional

from benchmarks.common import emit, write_json
from repro.serving.speculative import NGramDraft, accept_length

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_speculation.json")

BATCH = 8
PROMPT_LEN = 16
OUT_TOKENS = 48
PERIOD = 4
FLIP_RATES = (0.0, 0.25, 0.5)
KS = (2, 4)


# ---------------------------------------------------------------------------
# Part 1: draft/verify mechanics against a stream oracle
# ---------------------------------------------------------------------------
def _repetitive_stream(slot: int, n: int, flip_rate: float,
                       seed: int = 0) -> List[int]:
    """A period-``PERIOD`` token cycle (the repetitive-suffix workload)
    with a deterministic hash-placed fraction of off-cycle tokens —
    every flip breaks the accept run crossing it, so ``flip_rate``
    dials the realized accept rate without any RNG state."""
    out = [(11 + 7 * (i % PERIOD) + 13 * slot) % 97 for i in range(n)]
    for i in range(n):
        u = ((i * 2654435761 + slot * 40503 + seed * 97) % 1000) / 1000.0
        if u < flip_rate:
            out[i] = 97 + ((i * 31 + slot * 7) % 23)
    return out


def _adversarial_stream(slot: int, n: int) -> List[int]:
    """No suffix ever repeats within the window (quadratic hash over a
    large vocab): the n-gram table finds no continuation, so the
    speculative path must degenerate to plain 1-token decode."""
    return [(i * i * 2654435761 + slot * 7919 + i) % 50021
            for i in range(n)]


def decode_stream(streams: List[List[int]], k: int,
                  out_tokens: int = OUT_TOKENS) -> Dict[str, float]:
    """Run the worker's speculative decode loop (propose -> oracle
    verify -> commit-rule advance) over ``streams`` and count serial
    steps.  Baseline (k = 0 or no proposals every step) takes exactly
    ``out_tokens`` steps."""
    batch = len(streams)
    draft = NGramDraft()
    committed = []
    pos = []
    for i, s in enumerate(streams):
        draft.start(i, i, s[:PROMPT_LEN], s[PROMPT_LEN])
        committed.append([s[PROMPT_LEN]])
        pos.append(PROMPT_LEN)
    steps = offered = accepted = 0
    while any(len(c) < 1 + out_tokens for c in committed):
        live = [i for i in range(batch) if len(committed[i]) < 1 + out_tokens]
        items = [(i, i, committed[i][-1], pos[i]) for i in live]
        props = draft.propose_all(items, {i: k for i in live}) if k > 0 \
            else {i: [] for i in live}
        for i in live:
            drafts = props.get(i, [])
            s = streams[i]
            outputs = [s[pos[i] + 1 + j] for j in range(len(drafts) + 1)]
            a = accept_length(drafts, outputs)
            needed = 1 + out_tokens - len(committed[i])
            c = min(a + 1, max(needed, 1))
            got = outputs[:c]
            committed[i].extend(got)
            draft.commit(i, i, got)
            pos[i] += c
            offered += len(drafts)
            accepted += min(a, c - 1)
        steps += 1
    # every slot must have reproduced its stream exactly (token-exactness
    # of the commit rule, checked on every build)
    for i, s in enumerate(streams):
        assert committed[i] == s[PROMPT_LEN:PROMPT_LEN + 1 + out_tokens], i
    # Per-slot serial multiplier: all slots run in lock-step, so a plain
    # decode takes exactly out_tokens iterations and speculation's win is
    # out_tokens / steps committed tokens per verify step.
    return {"batch": batch, "k": k, "steps": steps,
            "tokens_per_step": out_tokens / steps,
            "accept_rate": accepted / offered if offered else 0.0}


def mechanics_grid() -> Dict[str, object]:
    rows = []
    for flip in FLIP_RATES:
        streams = [_repetitive_stream(i, PROMPT_LEN + OUT_TOKENS + max(KS) + 2, flip)
                   for i in range(BATCH)]
        for k in KS:
            rows.append({"workload": "repetitive", "flip_rate": flip,
                         **decode_stream(streams, k)})
    adv = [_adversarial_stream(i, PROMPT_LEN + OUT_TOKENS + max(KS) + 2)
           for i in range(BATCH)]
    for k in KS:
        rows.append({"workload": "adversarial", "flip_rate": None,
                     **decode_stream(adv, k)})
    rows.append({"workload": "repetitive", "flip_rate": 0.0,
                 **decode_stream(
                     [_repetitive_stream(i, PROMPT_LEN + OUT_TOKENS + max(KS) + 2, 0.0)
                      for i in range(BATCH)], 0)})
    return {"prompt_len": PROMPT_LEN, "out_tokens": OUT_TOKENS,
            "period": PERIOD, "rows": rows}


# ---------------------------------------------------------------------------
# Part 2: simulator accept x k sweep
# ---------------------------------------------------------------------------
def _sim_result(spec_k: int, spec_accept: float):
    import numpy as np
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    from repro.serving.network import BandwidthTrace, GBPS
    from repro.serving.request import Request
    from repro.serving.simulator import SimConfig, Simulator, StaticPolicy

    profile = Profile(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_channel"),
        cr=2.0, s_enc=5e8, s_dec=5e8)
    rng = np.random.default_rng(7)
    reqs, t = [], 0.0
    for i in range(64):
        t += float(rng.exponential(0.05))
        reqs.append(Request(rid=i, workload="qalike", arrival=t,
                            ctx_tokens=int(rng.integers(200, 2000)),
                            out_tokens=int(rng.integers(20, 200)),
                            kv_bytes=float(rng.integers(1, 8)) * 1e6))
    cfg = SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0,
                    spec_k=spec_k, spec_accept=spec_accept)
    sim = Simulator(cfg, StaticPolicy(profile, "u8"),
                    BandwidthTrace.constant(2 * GBPS), reqs)
    return sim.run()


def simulator_grid() -> Dict[str, object]:
    rows = []
    for accept in (0.0, 0.3, 0.6, 0.9):
        for k in (0, 2, 4):
            res = _sim_result(k, accept)
            rows.append({
                "spec_k": k, "spec_accept": accept,
                "mean_jct": res.mean_jct(),
                "decode_sum": sum(r.breakdown["decode"]
                                  for r in res.requests),
            })
    return {"n_requests": 64, "rows": rows}


# ---------------------------------------------------------------------------
# Part 3 (full mode): real-runtime probe, spec on vs off
# ---------------------------------------------------------------------------
def runtime_probe() -> Dict[str, object]:
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    from repro.serving import BandwidthTrace, GBPS, SchedulerConfig
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    profile = Profile(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_channel"),
        cr=2.0, s_enc=5e8, s_dec=5e8)

    def serve(spec_k: int):
        rt = ServingRuntime(
            static_profile=profile,
            config=RuntimeConfig(seq=64, decode_tokens=24,
                                 prefill_tok_s=2000.0, decode_tok_s=500.0,
                                 spec_k=spec_k),
            trace=BandwidthTrace.constant(1 * GBPS),
            scheduler=SchedulerConfig(max_slots=BATCH,
                                      max_prefills_per_step=2,
                                      max_queue=32))
        for seed in range(BATCH):   # repetitive-suffix continuations
            rt.submit("codelike", prompt_seed=seed)
        rt.run()
        tokens = {r.rid: [int(t) for t in r.tokens] for r in rt.completed}
        dw = rt.decode_workers[0]
        return tokens, dw.decode_steps, rt.summary()

    base_tokens, base_steps, _ = serve(0)
    spec_tokens, spec_steps, summary = serve(4)
    # Deep multi-token commits can flip greedy near-ties far into a long
    # generation (the bf16 online-softmax merge-ulp caveat, DESIGN.md
    # §15) — the pinned decode_tokens=6 scenario is asserted bit-exact in
    # the test suite; this longer probe is gated on high agreement.
    agree = total = 0
    for rid, toks in base_tokens.items():
        agree += sum(int(a == b) for a, b in zip(toks, spec_tokens[rid]))
        total += len(toks)
    speedup = base_steps / spec_steps
    return {"batch": BATCH, "k": 4, "steps_base": base_steps,
            "steps_spec": spec_steps, "steps_speedup": speedup,
            "token_agreement": agree / total,
            "tokens_per_step": summary.get("spec_tokens_per_step", 0.0),
            "accept_rate": summary.get("spec_accept_rate", 0.0)}


# ---------------------------------------------------------------------------
# Committed-JSON plumbing (same contract as benchmarks/paged_arena.py)
# ---------------------------------------------------------------------------
def _round(x, sig: int = 6):
    if isinstance(x, dict):
        return {k: _round(v, sig) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_round(v, sig) for v in x]
    if isinstance(x, bool) or not isinstance(x, float):
        return x
    if x == 0.0 or not math.isfinite(x):
        return x
    return round(x, sig - 1 - int(math.floor(math.log10(abs(x)))))


def build_grid(smoke: bool = True) -> Dict[str, object]:
    return _round({
        "version": 1,
        "smoke": bool(smoke),
        "mechanics": mechanics_grid(),
        "simulator": simulator_grid(),
    })


def _diff(a, b, path="") -> Optional[str]:
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            d = _diff(a.get(k), b.get(k), f"{path}.{k}")
            if d:
                return d
        return None
    if isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            return f"{path}: length {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            d = _diff(x, y, f"{path}[{i}]")
            if d:
                return d
        return None
    if a != b:
        return f"{path}: {a!r} != {b!r}"
    return None


def check_against_committed(grid: Dict[str, object]) -> None:
    if not os.path.exists(BENCH_PATH):
        raise AssertionError(
            f"{BENCH_PATH} missing — generate it with "
            f"`python -m benchmarks.speculative_decode --smoke --write`")
    with open(BENCH_PATH) as f:
        committed = json.load(f)
    d = _diff(_round(committed), grid)
    assert d is None, (
        f"BENCH_speculation.json is stale vs the current code at {d}; "
        f"refresh with `python -m benchmarks.speculative_decode "
        f"--smoke --write`")


def _assert_acceptance(grid: Dict[str, object]) -> None:
    rows = grid["mechanics"]["rows"]
    for row in rows:
        if row["workload"] == "repetitive" and row["flip_rate"] == 0.0 \
                and row["k"] > 0:
            # the ISSUE gate: >=1.8x decode tokens/step at batch 8 on the
            # repetitive-suffix workload
            assert row["batch"] == BATCH and \
                row["tokens_per_step"] >= 1.8, row
        if row["workload"] == "adversarial":
            # accept ~ 0: no proposals -> IDENTICAL step count, never worse
            assert row["steps"] == OUT_TOKENS, row
            assert row["tokens_per_step"] == 1.0, row
            assert row["accept_rate"] == 0.0, row
        if row["k"] == 0:
            assert row["steps"] == OUT_TOKENS, row
    # more drafts never hurt tokens/step on the same workload
    by_wl: Dict[object, Dict[int, float]] = {}
    for row in rows:
        by_wl.setdefault((row["workload"], row["flip_rate"]), {})[
            row["k"]] = row["tokens_per_step"]
    for tps in by_wl.values():
        for k_lo, k_hi in zip(sorted(tps), sorted(tps)[1:]):
            assert tps[k_hi] >= tps[k_lo] - 1e-9, (tps, k_lo, k_hi)

    sim = {(r["spec_k"], r["spec_accept"]): r
           for r in grid["simulator"]["rows"]}
    for (k, accept), row in sim.items():
        base = sim[(0, accept)]
        if k == 0:
            # k = 0 is bit-identical to baseline at every accept rate
            assert row == sim[(0, 0.0)] | {"spec_accept": accept}, row
        else:
            assert row["decode_sum"] <= base["decode_sum"] + 1e-12, row
    # decode time shrinks monotonically in the accept rate at fixed k > 0
    for k in (2, 4):
        decs = [sim[(k, a)]["decode_sum"] for a in (0.0, 0.3, 0.6, 0.9)]
        assert all(b <= a + 1e-12 for a, b in zip(decs, decs[1:])), decs


def _emit_rows(grid: Dict[str, object], probe=None) -> None:
    for row in grid["mechanics"]["rows"]:
        flip = row["flip_rate"]
        tag = f"{row['workload']}" + (f"_f{flip}" if flip is not None else "")
        emit(f"spec_mechanics_{tag}_k{row['k']}", 0.0,
             f"tokens_per_step={row['tokens_per_step']:.3f} "
             f"steps={row['steps']} accept={row['accept_rate']:.3f}")
    for row in grid["simulator"]["rows"]:
        emit(f"spec_sim_k{row['spec_k']}_a{row['spec_accept']}", 0.0,
             f"mean_jct={row['mean_jct']:.4f} "
             f"decode_sum={row['decode_sum']:.3f}")
    if probe is not None:
        emit("spec_runtime_probe_batch8_k4", 0.0,
             f"steps_speedup={probe['steps_speedup']:.2f}x "
             f"tokens_per_step={probe['tokens_per_step']:.3f} "
             f"accept={probe['accept_rate']:.3f} "
             f"token_agreement={probe['token_agreement']:.3f}")


def run(smoke: bool = False, write: bool = False, check: bool = False,
        json_path: str = "") -> None:
    grid = build_grid(smoke=smoke or check)
    probe = None
    if not (smoke or check or write):
        # full mode: the real tiny model, excluded from the committed JSON
        probe = runtime_probe()
        assert probe["tokens_per_step"] >= 1.8, probe
        assert probe["steps_speedup"] >= 1.4, probe
        assert probe["token_agreement"] >= 0.9, probe
    _emit_rows(grid, probe)
    _assert_acceptance(grid)
    if smoke or check:
        # Determinism: a second build must be byte-identical (stream
        # oracle + virtual clock, no RNG state consumed by speculation).
        again = build_grid(smoke=True)
        d = _diff(grid, again)
        assert d is None, f"speculation grid is non-deterministic at {d}"
    if write:
        with open(BENCH_PATH, "w") as f:
            json.dump(grid, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {BENCH_PATH}")
    elif smoke or check:
        check_against_committed(grid)
    if json_path:
        write_json(json_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings + determinism/staleness checks")
    ap.add_argument("--check", action="store_true",
                    help="regenerate the grid and fail if the committed "
                         "BENCH_speculation.json is stale")
    ap.add_argument("--write", action="store_true",
                    help="refresh the committed BENCH_speculation.json")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    run(smoke=args.smoke or args.write, write=args.write, check=args.check,
        json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
