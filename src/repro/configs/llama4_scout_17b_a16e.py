"""Config alias for --arch llama4-scout-17b-a16e (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("llama4-scout-17b-a16e")
