"""Input construction for every (arch × shape × mode) cell.

``make_inputs`` returns the exact pytree each step function consumes — as
``jax.ShapeDtypeStruct`` stand-ins (dry-run: no allocation) or concrete
arrays (smoke tests).  Modality frontends are stubs per the assignment:
audio/vision entries receive precomputed frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.layers import COMPUTE_DTYPE
from repro.models.transformer import init_cache


def _arr(shape, dtype, abstract: bool, rng: Optional[np.random.Generator],
         kind: str = "normal", maxval: int = 2):
    if abstract:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    if kind == "tokens":
        return jnp.asarray(rng.integers(0, maxval, size=shape), dtype=dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "positions":
        s = shape[-1]
        base = np.broadcast_to(np.arange(s, dtype=np.int32), shape)
        return jnp.asarray(base, dtype=dtype)
    return jnp.asarray(rng.standard_normal(shape) * 0.02, dtype=dtype)


def vision_split(cfg: ModelConfig, seq: int) -> Tuple[int, int]:
    """(S_vis, S_text) for VLM shapes."""
    s_vis = int(seq * cfg.vision_prefix_frac)
    s_vis = min(max(s_vis, 0), seq - 8)
    return s_vis, seq - s_vis


def make_inputs(
    cfg: ModelConfig,
    kind: str,  # train | prefill | decode
    seq: int,
    batch: int,
    abstract: bool = True,
    seed: int = 0,
) -> Dict[str, Any]:
    """Returns a dict with the step inputs:
       train:   {"batch": {...}}
       prefill: {"batch": {...}, "max_len": int}
       decode:  {"tokens", "pos", "caches"}"""
    rng = None if abstract else np.random.default_rng(seed)
    v = cfg.vocab_size

    if cfg.encoder_decoder:
        dec = min(cfg.dec_seq, max(seq // 8, 16))
        if kind == "train":
            b = {
                "frames": _arr((batch, seq, cfg.d_model), COMPUTE_DTYPE, abstract, rng),
                "tokens": _arr((batch, dec + 1), jnp.int32, abstract, rng, "tokens", v),
                "mask": _arr((batch, dec), jnp.float32, abstract, rng, "ones"),
            }
            return {"batch": b}
        if kind == "prefill":
            b = {
                "frames": _arr((batch, seq, cfg.d_model), COMPUTE_DTYPE, abstract, rng),
                "tokens": _arr((batch, dec), jnp.int32, abstract, rng, "tokens", v),
            }
            return {"batch": b, "max_len": dec}
        caches = init_cache(cfg, batch, max_len=dec, enc_len=seq, abstract=abstract)
        return {
            "tokens": _arr((batch, 1), jnp.int32, abstract, rng, "tokens", v),
            "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                    else jnp.asarray(dec - 1, jnp.int32)),
            "caches": caches,
        }

    if cfg.family == "vlm":
        s_vis, s_text = vision_split(cfg, seq)
        if kind in ("train", "prefill"):
            b = {
                "tokens": _arr((batch, s_text + (1 if kind == "train" else 0)),
                               jnp.int32, abstract, rng, "tokens", v),
                "patch_embeds": _arr((batch, s_vis, cfg.d_model), COMPUTE_DTYPE,
                                     abstract, rng),
                "positions": _arr((3, batch, seq), jnp.int32, abstract, rng,
                                  "positions"),
            }
            if kind == "train":
                b["mask"] = _arr((batch, s_text), jnp.float32, abstract, rng,
                                 "ones")
                return {"batch": b}
            return {"batch": b, "max_len": seq}
        caches = init_cache(cfg, batch, max_len=seq, abstract=abstract)
        return {
            "tokens": _arr((batch, 1), jnp.int32, abstract, rng, "tokens", v),
            "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                    else jnp.asarray(seq - 1, jnp.int32)),
            "caches": caches,
        }

    # ---- plain LM families (dense / moe / ssm / hybrid) ----
    if kind == "train":
        b = {
            "tokens": _arr((batch, seq + 1), jnp.int32, abstract, rng, "tokens", v),
            "mask": _arr((batch, seq), jnp.float32, abstract, rng, "ones"),
        }
        return {"batch": b}
    if kind == "prefill":
        b = {"tokens": _arr((batch, seq), jnp.int32, abstract, rng, "tokens", v)}
        return {"batch": b, "max_len": seq}
    caches = init_cache(cfg, batch, max_len=seq, abstract=abstract)
    return {
        "tokens": _arr((batch, 1), jnp.int32, abstract, rng, "tokens", v),
        "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                else jnp.asarray(seq - 1, jnp.int32)),
        "caches": caches,
    }


def make_inputs_for_shape(cfg: ModelConfig, shape: ShapeSpec,
                          abstract: bool = True, seed: int = 0):
    return make_inputs(cfg, shape.kind, shape.seq_len, shape.global_batch,
                       abstract=abstract, seed=seed)
