from repro.controller.bandit import BanditConfig, ResidualBandit
from repro.controller.controller import (
    Decision,
    FetchDecision,
    ServiceAwareController,
)
from repro.controller.envelope import (
    LowerEnvelope,
    brute_force_optimal,
    build_envelope,
)
from repro.controller.latency_model import (
    ServiceContext,
    TierFetch,
    bandwidth_threshold,
    baseline_latency,
    expected_tokens_per_step,
    is_beneficial,
    normalized_latency,
    predicted_latency,
    speculative_decode_latency,
    tier_fetch_latency,
)

__all__ = [
    "BanditConfig", "ResidualBandit", "Decision", "FetchDecision",
    "ServiceAwareController",
    "LowerEnvelope", "brute_force_optimal", "build_envelope",
    "ServiceContext", "TierFetch", "bandwidth_threshold", "baseline_latency",
    "expected_tokens_per_step", "is_beneficial", "normalized_latency",
    "predicted_latency", "speculative_decode_latency", "tier_fetch_latency",
]
