"""Small cross-version jax compatibility helpers."""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; on older
    versions it lives in ``jax.experimental.shard_map`` and the kwarg is
    ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)
