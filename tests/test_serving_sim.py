"""Serving simulator: PD + pool scenarios, adaptivity, fault tolerance."""
import numpy as np
import pytest

from repro.controller import ServiceAwareController
from repro.core.profiles import IDENTITY_PROFILE
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    NoCompressionPolicy,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)

WORKLOADS = ("mathlike", "codelike", "qalike", "summlike")


def _requests(n=40, seed=0, slo=0.0, prefix=0.0, q_min=0.5):
    # q_min=0.5 so every profile is quality-eligible: these tests compare
    # latency policy, not quality budgets (statics ignore q_min entirely).
    return WorkloadMix(rate=2.0, seed=seed, slo=slo, q_min=q_min,
                       prefix_hit_rate=prefix).generate(n)


def _static(profiles, i, name):
    return StaticPolicy(profiles[i], name)


def test_compression_helps_at_low_bandwidth(synthetic_profiles):
    reqs = _requests()
    trace = BandwidthTrace.constant(0.5 * GBPS)
    base = Simulator(SimConfig(), NoCompressionPolicy(), trace,
                     [r for r in _requests()]).run()
    best = max(synthetic_profiles, key=lambda p: p.cr)
    comp = Simulator(SimConfig(), StaticPolicy(best, "static"),
                     trace, reqs).run()
    assert comp.mean_jct() < base.mean_jct()


def test_compression_hurts_at_high_bandwidth(synthetic_profiles):
    """Negative optimization (Motivation 2): slow codec + fat pipe."""
    slow = min(synthetic_profiles, key=lambda p: p.s_eff)
    trace = BandwidthTrace.constant(500 * GBPS)
    base = Simulator(SimConfig(), NoCompressionPolicy(), trace,
                     _requests()).run()
    comp = Simulator(SimConfig(), StaticPolicy(slow, "slow"), trace,
                     _requests()).run()
    assert comp.mean_jct() > base.mean_jct()


def test_kvserve_tracks_best_static_across_bandwidths(synthetic_profiles):
    """The controller should be at least close to the best static choice in
    EVERY bandwidth regime — statics can't do that."""
    for bw in (0.2 * GBPS, 2 * GBPS, 100 * GBPS):
        trace = BandwidthTrace.constant(bw)
        results = {}
        for i, p in enumerate(synthetic_profiles[:6]):
            results[f"s{i}"] = Simulator(
                SimConfig(), StaticPolicy(p, f"s{i}"), trace,
                _requests()).run().mean_jct()
        results["default"] = Simulator(
            SimConfig(), NoCompressionPolicy(), trace, _requests()
        ).run().mean_jct()
        controller = ServiceAwareController(
            {w: synthetic_profiles for w in WORKLOADS})
        kv = Simulator(SimConfig(), KVServePolicy(controller), trace,
                       _requests()).run().mean_jct()
        best_static = min(results.values())
        assert kv <= best_static * 1.25, (bw, kv, results)


def test_breakdown_accounting(synthetic_profiles):
    trace = BandwidthTrace.constant(1 * GBPS)
    res = Simulator(SimConfig(), StaticPolicy(synthetic_profiles[0], "s"),
                    trace, _requests(10)).run()
    bd = res.breakdown()
    for r in res.requests:
        total = sum(v for k, v in r.breakdown.items())
        assert abs(total - r.jct) < 1e-6, (r.breakdown, r.jct)
    assert bd["comm"] > 0 and bd["prefill"] > 0


def test_pool_ttft_and_cachegen_fallback(synthetic_profiles):
    """Fig 14: static method falls back to recompute under tight SLO; the
    adaptive policy turns infeasible fetches into valid cache hits."""
    trace = BandwidthTrace.constant(0.6 * GBPS)
    reqs_f = _requests(30, seed=3, slo=0.35, prefix=1.0)
    static = StaticPolicy(max(synthetic_profiles, key=lambda p: p.cr),
                          "cachegen-like", slo_fallback_recompute=True)
    res_static = Simulator(SimConfig(scenario="pool", prefill_tok_s=3000),
                           static, trace, reqs_f).run()
    controller = ServiceAwareController(
        {w: synthetic_profiles for w in WORKLOADS})
    res_kv = Simulator(SimConfig(scenario="pool", prefill_tok_s=3000),
                       KVServePolicy(controller), trace,
                       _requests(30, seed=3, slo=0.35, prefix=1.0)).run()
    assert res_kv.mean_ttft() <= res_static.mean_ttft()


def test_fault_injection_all_requests_complete(synthetic_profiles):
    cfg = SimConfig(fail_rate=0.5, straggler_sigma=0.5, transient_slow_p=0.2,
                    seed=11)
    trace = BandwidthTrace.constant(1 * GBPS)
    res = Simulator(cfg, StaticPolicy(synthetic_profiles[0], "s"), trace,
                    _requests(30, seed=5)).run()
    assert len(res.requests) == 30
    assert all(r.done > r.arrival for r in res.requests)
    assert any(r.retries > 0 for r in res.requests)  # failures were injected
    # fault handling costs time but bounded: JCT still finite & reasonable
    assert np.isfinite(res.jct()).all()


def test_hedged_fetch_reduces_tail(synthetic_profiles):
    trace = BandwidthTrace.constant(1 * GBPS)
    trace_j = BandwidthTrace([0.0], [1 * GBPS], jitter=1.2, seed=4)
    reqs = lambda: _requests(60, seed=9, prefix=1.0)
    base = Simulator(SimConfig(scenario="pool", seed=1),
                     StaticPolicy(synthetic_profiles[0], "s"), trace_j,
                     reqs()).run()
    hedged = Simulator(SimConfig(scenario="pool", hedge_factor=2.0, seed=1),
                       StaticPolicy(synthetic_profiles[0], "s"),
                       BandwidthTrace([0.0], [1 * GBPS], jitter=1.2, seed=4),
                       reqs()).run()
    assert np.percentile(hedged.ttft(), 95) <= np.percentile(base.ttft(), 95)


def test_hedged_fetch_extends_to_tiered_remote():
    """Bugfix regression: hedging used to apply to the flat pool path
    only — a tiered store's remote-tier fetch (the SAME replicated pool,
    just behind a serialized tier link) silently lost its hedge.  The
    duplicate fetch now races on the replica's own wire, so a jittered
    remote link's tail shrinks and retries are booked."""
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    from repro.serving import Request
    from repro.serving.kvstore import TierSpec, TieredKVStore

    prof = Profile(StrategyConfig(key_bits=8, value_bits=8), cr=2.0,
                   s_enc=1e9, s_dec=1e9)

    def run(hf):
        tier_trace = BandwidthTrace([0.0], [1e6], jitter=1.2, seed=4)
        store = TieredKVStore(
            [TierSpec("remote", 64 << 20, bandwidth=tier_trace,
                      fetch_overhead=1e-3, observe_goodput=True)], block=8)
        for i in range(20):
            store.put((i,), prof, 100_000, kv_bytes=2e5, now=0.0)
        reqs = [Request(rid=i, workload="qalike", arrival=1.0 + 0.5 * i,
                        ctx_tokens=100, out_tokens=2, kv_bytes=2e5,
                        q_min=0.0, prefix_key=(i,)) for i in range(20)]
        return Simulator(SimConfig(scenario="pool", hedge_factor=hf, seed=1),
                         StaticPolicy(prof, "s"),
                         BandwidthTrace.constant(1e6), reqs,
                         store=store).run()

    base, hedged = run(0.0), run(2.0)
    # every request is a pool hit on the remote tier (no prefill)
    assert all(r.breakdown.get("prefill", 0) == 0 for r in base.requests)
    assert any(r.retries > 0 for r in hedged.requests)
    # hedging can only shorten a fetch: pointwise no-worse, tail better
    for b, h in zip(base.requests, hedged.requests):
        assert h.ttft <= b.ttft + 1e-12
    assert np.percentile(hedged.ttft(), 95) < np.percentile(base.ttft(), 95)


def test_simulator_paged_drops_decompress_for_eligible_profiles():
    """SimConfig.paged mirrors the runtime's fused dequant-attention
    decode (DESIGN.md §12): a paged-eligible profile's V/s_dec term
    leaves the critical path, an ineligible one still pays it."""
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig

    eligible = Profile(
        StrategyConfig(key_bits=8, value_bits=8, granularity="per_token",
                       symmetric=True, group_size=32),
        cr=2.0, s_enc=1e9, s_dec=1e5)
    ineligible = Profile(StrategyConfig(key_bits=8, value_bits=8),
                         cr=2.0, s_enc=1e9, s_dec=1e5)
    trace = BandwidthTrace.constant(1 * GBPS)

    def run(profile, paged):
        reqs = _requests(10, seed=2, prefix=1.0)
        res = Simulator(SimConfig(paged=paged),
                        StaticPolicy(profile, "s"), trace, reqs).run()
        bd = res.breakdown()
        for r in res.requests:   # terms still sum to JCT either way
            assert abs(sum(r.breakdown.values()) - r.jct) < 1e-6
        return bd["decompress"]

    assert run(eligible, paged=False) > 0
    assert run(eligible, paged=True) == 0.0
    assert run(ineligible, paged=True) > 0


def test_bandwidth_trace_integration():
    tr = BandwidthTrace.steps([(0.0, 100.0), (1.0, 50.0)])
    # 150 bytes starting at t=0: 100 in the first second, 50 in the next
    assert abs(tr.transfer_time(0.0, 150.0) - 2.0) < 1e-9
    assert abs(tr.at(0.5) - 100.0) < 1e-9 and abs(tr.at(1.5) - 50.0) < 1e-9


def test_bandwidth_trace_outage_segments():
    """Bugfix (ISSUE 4): a zero-rate segment models a link outage.  The
    transfer waits it out (no division by zero, no inf mid-trace), and a
    transfer landing entirely inside the outage resumes at recovery."""
    tr = BandwidthTrace.steps([(0.0, 100.0), (1.0, 0.0), (3.0, 100.0)])
    # 150 bytes from t=0: 100 by t=1, stalled until t=3, 50 more by t=3.5
    assert tr.transfer_time(0.0, 150.0) == pytest.approx(3.5)
    # a transfer starting mid-outage waits for recovery
    assert tr.transfer_time(2.0, 100.0) == pytest.approx(2.0)
    # an outage that never recovers yields inf, not a crash
    dead = BandwidthTrace.steps([(0.0, 100.0), (1.0, 0.0)])
    assert dead.transfer_time(0.0, 150.0) == float("inf")
    assert dead.transfer_time(5.0, 1.0) == float("inf")
    # ... and the estimator ignores the non-signal
    from repro.serving.network import GoodputEstimator, KVWire
    est = GoodputEstimator(initial=123.0)
    wire = KVWire(dead, est)
    wire.send(0.0, 150.0)
    assert est.estimate == 123.0


def test_estimator_drift():
    from repro.serving.network import GoodputEstimator
    est = GoodputEstimator(alpha=0.5, initial=100.0)
    for _ in range(10):
        est.observe(50.0, 1.0)
    assert abs(est.estimate - 50.0) < 1.0


def test_jittered_trace_replay_equality():
    """Per-transfer jitter is a pure function of (seed, start, nbytes):
    identical transfers replay identically, and interleaved callers (e.g.
    a trace shared between runtime and simulator) cannot perturb each
    other's draws."""
    tr = BandwidthTrace([0.0], [1 * GBPS], jitter=0.8, seed=7)
    t_a = tr.transfer_time(1.5, 1e6)
    t_b = tr.transfer_time(2.5, 1e6)
    # interleave unrelated transfers, then replay
    for i in range(5):
        tr.transfer_time(float(i), 1e5 * (i + 1))
    assert tr.transfer_time(1.5, 1e6) == t_a
    assert tr.transfer_time(2.5, 1e6) == t_b
    # a fresh trace object with the same seed replays the same stream
    tr2 = BandwidthTrace([0.0], [1 * GBPS], jitter=0.8, seed=7)
    assert tr2.transfer_time(1.5, 1e6) == t_a
    # different seed, start, or size actually re-draws
    tr3 = BandwidthTrace([0.0], [1 * GBPS], jitter=0.8, seed=8)
    assert tr3.transfer_time(1.5, 1e6) != t_a
    assert tr.transfer_time(1.5001, 1e6) != t_a
    assert tr.transfer_time(1.5, 1e6 + 1) != t_a
