"""Byte-level tokenizer: vocab = 256 raw bytes + BOS/EOS/PAD."""
from __future__ import annotations

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID

    def encode(self, text: str, add_bos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8", errors="replace"))
        if add_bos:
            ids = [BOS_ID] + ids
        return np.asarray(ids, dtype=np.int32)

    def decode(self, ids) -> str:
        raw = bytes(int(i) for i in ids if 0 <= int(i) < 256)
        return raw.decode("utf-8", errors="replace")

    def pad_to(self, ids: np.ndarray, length: int) -> np.ndarray:
        if len(ids) >= length:
            return ids[:length]
        out = np.full((length,), PAD_ID, dtype=np.int32)
        out[: len(ids)] = ids
        return out
