"""The 3D Pareto frontier over (Accuracy ↑, CR ↑, Latency ↓) — Sec. 5.2.3.

The frontier is the static runtime lookup table the Service-Aware Online
Controller selects from.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.profiles import Profile


@dataclass(frozen=True)
class ParetoPoint:
    acc: float   # higher better
    cr: float    # higher better
    lat: float   # lower better (s per byte at reference bandwidth)
    profile: Profile


def profile_latency(p: Profile, ref_bandwidth: float) -> float:
    """Per-byte KV latency of a profile at a reference bandwidth:
    1/s_p + 1/(B·cr_p)  (Eq. 6 with V factored out)."""
    s_term = 0.0 if p.s_eff == float("inf") else 1.0 / p.s_eff
    return s_term + 1.0 / (ref_bandwidth * p.cr)


def dominates(a: ParetoPoint, b: ParetoPoint) -> bool:
    ge = (a.acc >= b.acc) and (a.cr >= b.cr) and (a.lat <= b.lat)
    strict = (a.acc > b.acc) or (a.cr > b.cr) or (a.lat < b.lat)
    return ge and strict


def pareto_frontier(points: Sequence[ParetoPoint]) -> List[ParetoPoint]:
    """O(n^2) non-dominated filter (n is a few hundred)."""
    out: List[ParetoPoint] = []
    for i, p in enumerate(points):
        if not any(dominates(q, p) for j, q in enumerate(points) if j != i):
            out.append(p)
    return out


def frontier_from_profiles(
    profiles: Sequence[Profile], workload: str, ref_bandwidth: float = 1e9
) -> List[ParetoPoint]:
    pts = [
        ParetoPoint(acc=p.q(workload), cr=p.cr,
                    lat=profile_latency(p, ref_bandwidth), profile=p)
        for p in profiles
    ]
    return pareto_frontier(pts)
