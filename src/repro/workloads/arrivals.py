"""Composable arrival processes for trace generation.

Production traffic is neither stationary nor Poisson: request rates swing
with the day (diurnal load curves), burst on short timescales (on-off
sources), and the aggregate is a superposition of many tenants doing both
at once.  Three generators cover those shapes:

* :class:`Poisson` — the stationary baseline.
* :class:`DiurnalGammaPoisson` — a doubly-stochastic (Cox) process: a
  sinusoidal diurnal rate envelope modulated per time-bin by a
  Gamma(k, 1/k) multiplier (mean 1, CV 1/sqrt(k)), arrivals Poisson
  within each bin.  Small ``gamma_shape`` ⇒ heavy rate turbulence on top
  of the daily curve.
* :class:`OnOffMMPP` — a 2-state Markov-modulated Poisson process:
  exponentially-distributed ON/OFF dwell times, arrivals at ``rate_on``
  during ON bursts (and ``rate_off``, default 0, between them).

All generators are pure functions of ``(params, rng)`` — replaying with
the same seeded ``numpy`` Generator reproduces the same arrival vector
bit-for-bit — and return float64 arrays of sorted arrival times in
``[0, duration)``.  Superposition of tenants happens at the trace level
(:meth:`repro.workloads.trace.Trace.merge`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

import numpy as np

Rng = np.random.Generator


class ArrivalProcess:
    """Interface: ``times(duration, rng) -> sorted float64 array``."""

    def times(self, duration: float, rng: Rng) -> np.ndarray:
        raise NotImplementedError

    def mean_rate(self) -> float:
        """Long-run average arrivals/s (used to size traces)."""
        raise NotImplementedError


@dataclass(frozen=True)
class Poisson(ArrivalProcess):
    """Stationary Poisson arrivals at ``rate``/s."""

    rate: float

    def times(self, duration: float, rng: Rng) -> np.ndarray:
        if self.rate <= 0 or duration <= 0:
            return np.empty(0)
        # Draw in vectorized batches of exponential gaps until the
        # horizon is covered (amortized one rng call per ~n arrivals).
        expect = self.rate * duration
        n = max(int(expect + 6.0 * math.sqrt(expect) + 16), 16)
        gaps = rng.exponential(1.0 / self.rate, size=n)
        t = np.cumsum(gaps)
        while t[-1] < duration:
            gaps = rng.exponential(1.0 / self.rate, size=n)
            t = np.concatenate([t, t[-1] + np.cumsum(gaps)])
        return t[t < duration]

    def mean_rate(self) -> float:
        return self.rate


def _binned_poisson(edges: np.ndarray, rates: np.ndarray, rng: Rng
                    ) -> np.ndarray:
    """Arrivals of a piecewise-constant-rate Poisson process: per-bin
    counts are Poisson(rate * width), positions uniform within the bin.
    One vectorized pass regardless of bin count."""
    widths = np.diff(edges)
    counts = rng.poisson(np.maximum(rates, 0.0) * widths)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0)
    u = rng.random(total)
    starts = np.repeat(edges[:-1], counts)
    spans = np.repeat(widths, counts)
    return np.sort(starts + u * spans)


@dataclass(frozen=True)
class DiurnalGammaPoisson(ArrivalProcess):
    """Diurnal sinusoid × per-bin Gamma turbulence × Poisson thinning.

    ``rate(t) = base_rate * (1 + amplitude*sin(2π(t/period + phase)))``
    scaled per bin by an iid Gamma(shape, 1/shape) draw (mean 1).
    ``period`` defaults to 240 s — a compressed "day" so short simulated
    horizons still sweep through peak and trough.
    """

    base_rate: float
    period: float = 240.0
    amplitude: float = 0.6
    gamma_shape: float = 4.0
    phase: float = 0.0
    bins_per_period: int = 48

    def times(self, duration: float, rng: Rng) -> np.ndarray:
        if self.base_rate <= 0 or duration <= 0:
            return np.empty(0)
        bin_s = self.period / self.bins_per_period
        n_bins = max(int(math.ceil(duration / bin_s)), 1)
        edges = np.minimum(np.arange(n_bins + 1) * bin_s, duration)
        centers = (edges[:-1] + edges[1:]) / 2.0
        envelope = 1.0 + self.amplitude * np.sin(
            2.0 * math.pi * (centers / self.period + self.phase))
        turb = rng.gamma(self.gamma_shape, 1.0 / self.gamma_shape,
                         size=n_bins)
        return _binned_poisson(edges, self.base_rate * envelope * turb, rng)

    def mean_rate(self) -> float:
        return self.base_rate


@dataclass(frozen=True)
class OnOffMMPP(ArrivalProcess):
    """Bursty on-off Markov-modulated Poisson process (2-state MMPP)."""

    rate_on: float
    mean_on: float = 5.0      # mean ON dwell (s)
    mean_off: float = 15.0    # mean OFF dwell (s)
    rate_off: float = 0.0     # background rate between bursts
    start_on: bool = False

    def times(self, duration: float, rng: Rng) -> np.ndarray:
        if duration <= 0:
            return np.empty(0)
        out: List[np.ndarray] = []
        t = 0.0
        on = self.start_on
        while t < duration:
            mean = self.mean_on if on else self.mean_off
            dwell = float(rng.exponential(mean)) if mean > 0 else 0.0
            end = min(t + dwell, duration)
            rate = self.rate_on if on else self.rate_off
            if rate > 0 and end > t:
                lam = rate * (end - t)
                k = int(rng.poisson(lam))
                if k:
                    out.append(t + np.sort(rng.random(k)) * (end - t))
            t = end
            on = not on
        if not out:
            return np.empty(0)
        return np.concatenate(out)

    def mean_rate(self) -> float:
        cycle = self.mean_on + self.mean_off
        if cycle <= 0:
            return self.rate_on
        return (self.rate_on * self.mean_on
                + self.rate_off * self.mean_off) / cycle


ARRIVALS = {
    "poisson": Poisson,
    "diurnal": DiurnalGammaPoisson,
    "mmpp": OnOffMMPP,
}


def make_arrivals(kind: str, rate: float, **kw) -> ArrivalProcess:
    """Factory keyed by name; ``rate`` maps onto each process's primary
    rate parameter."""
    if kind == "poisson":
        return Poisson(rate=rate, **kw)
    if kind == "diurnal":
        return DiurnalGammaPoisson(base_rate=rate, **kw)
    if kind == "mmpp":
        return OnOffMMPP(rate_on=rate, **kw)
    raise ValueError(f"unknown arrival process {kind!r} "
                     f"(have {sorted(ARRIVALS)})")
