"""Continuous-batching ServingRuntime e2e on the real tiny model."""
import numpy as np
import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import BandwidthTrace, GBPS, PrefixKVStore, SchedulerConfig


def _profile():
    # 8-bit per-channel: real compression on the pool path, near-lossless.
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel"),
                   cr=2.0, s_enc=5e8, s_dec=5e8)


def _runtime(reference_model, **kw):
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    cfg = RuntimeConfig(seq=64, decode_tokens=6,
                        prefill_tok_s=2000.0, decode_tok_s=500.0)
    defaults = dict(
        static_profile=_profile(), config=cfg,
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=32))
    defaults.update(kw)
    rt = ServingRuntime(**defaults)
    # pin the session-cached reference model (avoids retraining paths)
    rt.model_cfg, rt.params = reference_model
    return rt


@pytest.mark.slow
def test_pool_hit_beats_cold_prefill_ttft(reference_model):
    """The paper's TTFT path: a prefix-pool hit (fetch real compressed
    bytes + decompress + inject) must beat recomputing prefill."""
    rt = _runtime(reference_model)
    cold_rid = rt.submit("qalike", prompt_seed=42)
    rt.run()
    assert len(rt.store) == 1  # prefix written back to the pool
    hit_rid = rt.submit("qalike", prompt_seed=42)  # identical prompt
    rt.run()

    by_rid = {r.rid: r for r in rt.completed}
    cold, hit = by_rid[cold_rid], by_rid[hit_rid]
    assert not cold.pool_hit and hit.pool_hit
    assert hit.ttft < cold.ttft
    assert hit.breakdown["comm"] > 0 and hit.breakdown.get("prefill", 0) == 0
    assert cold.breakdown["prefill"] > 0
    assert cold.t_pool_write > 0 and hit.t_pool_write == 0
    # real bytes moved: the hit fetched exactly what the cold request stored
    assert hit.wire_bytes == cold.wire_bytes > 0
    assert hit.wire_bytes < cold.kv_bytes  # compressed on the wire
    # both generated a full completion
    assert len(hit.tokens) == len(cold.tokens) == rt.cfg.decode_tokens + 1
    assert rt.store.stats.hits == 1


@pytest.mark.slow
def test_runtime_sustains_concurrent_in_flight_requests(reference_model):
    rt = _runtime(reference_model)
    rids = [rt.submit(w, prompt_seed=i) for i, w in enumerate(
        ("qalike", "codelike", "mathlike", "summlike", "qalike", "codelike"))]
    assert all(r is not None for r in rids)
    done = rt.run()
    assert len(done) == 6
    assert rt.max_in_flight() >= 4  # continuous batching, not one-by-one
    for r in done:
        assert r.jct >= r.ttft > 0
        total = sum(r.breakdown.values())
        assert total == pytest.approx(r.jct, abs=1e-6), (r.breakdown, r.jct)


@pytest.mark.slow
def test_runtime_admission_and_slo_priority(reference_model):
    rt = _runtime(reference_model,
                  scheduler=SchedulerConfig(max_slots=2,
                                            max_prefills_per_step=1,
                                            max_queue=4, aging_s=0.0))
    assert rt.submit("qalike", slo_class="batch", prompt_seed=0) is not None
    assert rt.submit("qalike", slo_class="batch", prompt_seed=1) is not None
    assert rt.submit("qalike", slo_class="batch", prompt_seed=2) is not None
    assert rt.submit("qalike", slo_class="interactive",
                     prompt_seed=3) is not None
    # queue bound (4) reached -> load shed
    assert rt.submit("qalike", slo_class="batch", prompt_seed=4) is None
    rt.run()
    assert len(rt.completed) == 4
    # the interactive request jumped the batch queue: first token first
    inter = [r for r in rt.completed if r.slo_class == "interactive"][0]
    batch_ttfts = [r.ttft for r in rt.completed if r.slo_class == "batch"]
    assert inter.ttft <= min(batch_ttfts)


@pytest.mark.slow
def test_store_eviction_under_tiny_capacity(reference_model):
    store = PrefixKVStore(capacity_bytes=40_000, block=16)
    rt = _runtime(reference_model, store=store)
    for i in range(4):
        rt.submit("codelike", prompt_seed=100 + i)
        rt.run()
    assert store.used_bytes <= store.capacity_bytes
    assert store.stats.evictions > 0 or store.stats.rejected_puts > 0


# ---------------------------------------------------------------------------
# Batched slot-arena decode (PR 2)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_token_exact_parity_with_pr1_fixture(reference_model):
    """The batched arena decode must emit exactly the tokens the PR-1
    per-slot loop emitted (fixture pinned before the refactor) across a
    pool hit/miss mix with staggered admissions."""
    import json
    from _runtime_scenario import (FIXTURE, build_runtime, params_digest,
                                   run_scenario)
    fix = json.loads(FIXTURE.read_text())
    rt = build_runtime(reference_model)
    if params_digest(rt.params) != fix["params_digest"]:
        pytest.skip("reference model differs from the fixture's "
                    "(e.g. CI trains a smaller REPRO_REF_STEPS model)")
    out = run_scenario(rt)
    assert set(out) == set(fix["outputs"])
    for rid, rec in fix["outputs"].items():
        assert out[rid]["pool_hit"] == rec["pool_hit"], rid
        assert out[rid]["tokens"] == rec["tokens"], rid


@pytest.mark.slow
def test_arena_decode_token_exact_vs_per_slot_loop(reference_model):
    """Decode-path equivalence, independent of the trained model: the
    masked batched arena step must reproduce the PR-1 per-slot batch-1
    decode loop token-for-token, including a lossy pool-style injection
    and staggered slot activation (mask churn)."""
    import jax.numpy as jnp
    from repro.core.pipeline import CompressionPipeline
    from repro.core.quality import (_jitted_steps, _prompts_for,
                                    copy_cache_slot, extract_kv, inject_kv)
    from repro.core.strategy import StrategyConfig
    from repro.models.transformer import init_cache

    cfg, params = reference_model
    seq, n_slots, steps = 48, 4, 6
    max_len = seq + steps + 2
    pre1, dec1, _ = _jitted_steps(cfg.name, seq, 1, max_len)
    _, _, arena_dec = _jitted_steps(cfg.name, seq, n_slots, max_len)

    slot_caches, firsts = [], []
    for i, w in enumerate(("qalike", "codelike", "mathlike", "summlike")):
        tokens, _ = _prompts_for(w, 1, seq, seed=i)
        logits, caches = pre1(params, {"tokens": tokens})
        first = int(np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))[0])
        if i == 3:  # pool-hit-like slot: lossy compress->decompress->inject
            pipe = CompressionPipeline(StrategyConfig(
                quantizer="uniform", key_bits=8, value_bits=8,
                granularity="per_channel"))
            kv = extract_kv(cfg, caches, 0, upto=seq)
            restored = pipe.decompress(pipe.compress(kv))
            caches = inject_kv(cfg, init_cache(cfg, 1, max_len), 0, restored)
        slot_caches.append(caches)
        firsts.append(first)

    # ---- reference: PR-1 style per-slot batch-1 decode loops ----
    ref_tokens = []
    for caches, first in zip(slot_caches, firsts):
        toks, c = [first], caches
        for t in range(steps):
            logits, c = dec1(params, c, jnp.asarray([[toks[-1]]], jnp.int32),
                             jnp.asarray(seq + t, jnp.int32))
            toks.append(int(np.asarray(
                jnp.argmax(logits[:, -1, :], axis=-1))[0]))
        ref_tokens.append(toks)

    # ---- batched arena, slot i activating at iteration i ----
    arena = init_cache(cfg, n_slots, max_len)
    for i, caches in enumerate(slot_caches):
        arena = copy_cache_slot(cfg, arena, caches, i)
    pos = np.full(n_slots, seq, np.int32)
    last = np.asarray(firsts, np.int32)
    got = [[f] for f in firsts]
    it = 0
    while any(len(g) < steps + 1 for g in got):
        mask = np.array([i <= it and len(got[i]) < steps + 1
                         for i in range(n_slots)])
        nxt, arena = arena_dec(params, arena, jnp.asarray(last[:, None]),
                               jnp.asarray(pos), jnp.asarray(mask))
        nxt = np.asarray(nxt)
        for i in range(n_slots):
            if mask[i]:
                got[i].append(int(nxt[i]))
                last[i] = nxt[i]
                pos[i] += 1
        it += 1
    assert got == ref_tokens


class _SpyController:
    """Static-profile controller that records every observe() call."""

    def __init__(self, profile):
        self._profile = profile
        self.observed = []

    def select(self, ctx):
        from repro.controller import Decision
        return Decision(self._profile, 0, 0, 0.0)

    def observe(self, ctx, decision, latency):
        self.observed.append(float(latency))


@pytest.mark.slow
def test_runtime_observes_critical_path_latency(reference_model):
    """Regression (PR 2): the miss path used to feed the bandit
    t_compress + t_comm of the *off-critical-path pool write*; it must
    observe the request's realized critical path.  Since PR 3 the SLO
    metric is explicit: with slo_metric="jct" the observation is the
    breakdown sum (== jct), never the off-path pool write."""
    spy = _SpyController(_profile())
    rt = _runtime(reference_model, controller=spy, static_profile=None)
    rt.submit("qalike", prompt_seed=7, slo_metric="jct")
    rt.run()
    (r,) = rt.completed
    assert not r.pool_hit
    assert len(spy.observed) == 1
    assert spy.observed[0] == pytest.approx(sum(r.breakdown.values()),
                                            abs=1e-9)
    assert spy.observed[0] == pytest.approx(r.jct, abs=1e-9)
    assert r.t_pool_write > 0  # off-path cost exists but is not charged
    # pool hit: no controller decision is made -> nothing observed
    rt.submit("qalike", prompt_seed=7)
    rt.run()
    assert rt.completed[-1].pool_hit
    assert len(spy.observed) == 1


@pytest.mark.slow
def test_runtime_slo_metric_matches_observation(reference_model):
    """Bugfix (PR 3): _finish used to flag slo_violated on TTFT while the
    bandit guardrail compared the observed latency (JCT) to the same
    t_slo.  Both now use the request's resolved slo_metric: pool-scenario
    default is ttft (observation == ttft), and a request pinning jct is
    both flagged and observed on jct."""
    spy = _SpyController(_profile())
    rt = _runtime(reference_model, controller=spy, static_profile=None)
    rt.submit("qalike", prompt_seed=11)   # pool default -> ttft
    rt.run()
    (r,) = rt.completed
    assert len(spy.observed) == 1
    assert spy.observed[0] == pytest.approx(r.ttft, abs=1e-9)
    assert spy.observed[0] < r.jct  # ttft is a strict prefix of jct here

    # a tight TTFT SLO violated by the cold prefill: flag and observation
    # agree (pre-fix, cooldown bookkeeping used jct while the runtime
    # reported ttft violations)
    spy2 = _SpyController(_profile())
    rt2 = _runtime(reference_model, controller=spy2, static_profile=None)
    rt2.submit("qalike", prompt_seed=12, t_slo=1e-6)
    rt2.run()
    (r2,) = rt2.completed
    assert r2.slo_violated and spy2.observed[0] == pytest.approx(r2.ttft,
                                                                 abs=1e-9)
    assert spy2.observed[0] > 1e-6


@pytest.mark.slow
def test_disaggregated_engine_observes_on_path_comm(reference_model):
    """One-shot PD path: compress/comm/decompress ARE on the critical
    path, so the observed latency equals that breakdown sum."""
    from repro.serving.engine import DisaggregatedEngine
    spy = _SpyController(_profile())
    eng = DisaggregatedEngine(controller=spy, seq=48, decode_tokens=4,
                              batch=2)
    b = eng.serve("qalike", BandwidthTrace.constant(1 * GBPS))
    assert len(spy.observed) == 1
    assert spy.observed[0] == pytest.approx(
        b.t_prefill + b.t_compress + b.t_comm + b.t_decompress, abs=1e-9)


@pytest.mark.slow
def test_run_budget_is_relative_to_the_call(reference_model):
    """Regression (PR 2): run(max_steps) compared against the cumulative
    step counter, so a second run() on a long-lived runtime returned
    immediately with work still queued."""
    rt = _runtime(reference_model)
    rt.submit("qalike", prompt_seed=0)
    rt.run(max_steps=3)
    assert rt.steps == 3 and not rt.scheduler.idle
    rt.run(max_steps=3)   # pre-fix: no-op (steps 3 >= budget 3)
    assert rt.steps == 6
    rt.run()
    assert rt.scheduler.idle and len(rt.completed) == 1


@pytest.mark.slow
def test_arena_slot_recycling(reference_model):
    """More requests than slots: slot ids stay in range, get recycled,
    and all return to the scheduler's free pool when idle."""
    rt = _runtime(reference_model)   # max_slots = 6
    for i, w in enumerate(("qalike", "codelike", "mathlike", "summlike",
                           "qalike", "codelike", "mathlike", "summlike")):
        rt.submit(w, prompt_seed=i)
    done = rt.run()
    assert len(done) == 8
    assert all(0 <= r.slot < rt.n_slots for r in done)
    assert len({r.slot for r in done}) <= rt.n_slots < len(done)
    assert sorted(rt.scheduler._free_slots) == list(range(rt.n_slots))
