"""repro.analysis.sanitize: fault-injection coverage (DESIGN.md §14).

Each test seeds one of the three runtime bug classes the sanitizer
exists to catch — double-release / use-after-release of arena pages,
pages leaked at drain, a MOVE-shaped clobber of a cluster-shared tier —
and asserts the matching detector fires with its ``kind`` tag.  The
clean lifecycles (slot reuse, COPY-promotion out of a shared pool,
refresh skipping the shared tier) must stay silent: a sanitizer that
cries on correct code would never be left on in CI.
"""
import pytest

from repro.analysis import sanitize
from repro.core.kvcache import PageTable
from repro.serving.kvstore import KVTier, TierSpec, TieredKVStore

KEY = tuple(range(16))


@pytest.fixture
def san():
    """Force-install the sanitizer for one test (idempotent wrt the
    session-level REPRO_SANITIZE=1 install in conftest)."""
    was = sanitize.enabled()
    sanitize.install()
    yield sanitize
    if not was:
        sanitize.uninstall()


def _shared_tiered(san):
    """A worker hierarchy ending in a cluster-shared pool tier, with one
    entry resident in the pool."""
    shared = KVTier(TierSpec("remote", 1 << 20), block=16)
    shared.shared = True
    ts = TieredKVStore([TierSpec("hbm", 1 << 20), shared], block=16)
    assert ts.put(KEY, "payload", 100, now=0.0, tier=1) == 1
    return ts


# ---------------------------------------------------------------------------
# double-release / use-after-release
# ---------------------------------------------------------------------------
def test_double_release_caught(san):
    pt = PageTable(8, 16)
    pt.ensure(3, 32)
    pt.release(3)
    with pytest.raises(san.SanitizerError) as ei:
        pt.release(3)
    assert ei.value.kind == "double-release"


def test_use_after_release_caught(san):
    pt = PageTable(8, 16)
    pt.ensure(2, 16)
    pt.release(2)
    with pytest.raises(san.SanitizerError) as ei:
        pt.block_row(2, 4)
    assert ei.value.kind == "use-after-release"


def test_slot_reuse_is_clean(san):
    """The runtime's normal recycle (release -> re-ensure -> read) must
    not trip either page detector."""
    pt = PageTable(8, 16)
    for _ in range(3):
        pt.ensure(1, 32)
        assert pt.block_row(1, 4)[0] != 0
        pt.release(1)
    pt.ensure(1, 16)
    pt.block_row(1, 4)
    pt.check()


# ---------------------------------------------------------------------------
# pages leaked at drain
# ---------------------------------------------------------------------------
def test_leaked_pages_at_drain_caught(san):
    """Seeded bug: a release path frees the slot id but skips
    page_table.release() — the slot's pages stay owned forever."""
    pt = PageTable(8, 16)
    pt.ensure(0 + 1, 48)           # slot 1 holds 3 pages
    # ... the slot is "freed" without releasing its pages (the bug) ...
    with pytest.raises(san.SanitizerError) as ei:
        san.check_drained(pt)
    assert ei.value.kind == "leaked-pages"
    assert "slot 1" in str(ei.value)


def test_drain_check_respects_live_slots(san):
    pt = PageTable(8, 16)
    pt.ensure(1, 16)
    san.check_drained(pt, live_slots=[1])     # still in flight: fine
    pt.release(1)
    san.check_drained(pt)                     # fully drained: fine


# ---------------------------------------------------------------------------
# shared-tier clobber
# ---------------------------------------------------------------------------
def test_shared_tier_clobber_caught(san):
    """The PR-5 MOVE bug, seeded: code discards the pool copy while
    'moving' an entry into its local tier."""
    ts = _shared_tiered(san)
    with pytest.raises(san.SanitizerError) as ei:
        ts.tiers[1].store.discard(KEY)
    assert ei.value.kind == "shared-clobber"


def test_copy_promotion_out_of_shared_tier_is_clean(san):
    """The CORRECT promotion path (COPY via dataclasses.replace) never
    touches discard on the shared store — and the pool copy survives."""
    ts = _shared_tiered(san)
    hit = ts.lookup(KEY, now=1.0)
    assert hit is not None and hit.tier.shared
    ts.fetch(hit, ready=1.0)                          # promotes by COPY
    assert ts.tiers[1].store.contains(KEY, now=1.0)   # pool copy intact
    assert ts.tiers[0].store.contains(KEY, now=1.0)   # hot copy landed
    assert ts.stats.promotions == 1


def test_local_refresh_skips_shared_tier(san):
    """put() pre-clobbers only worker-LOCAL stale copies; the shared
    tier's copy is left for the whole cluster (the second PR-5 bug)."""
    ts = _shared_tiered(san)
    ts.put(KEY, "refresh", 120, now=2.0)              # must not raise
    assert ts.tiers[1].store.contains(KEY, now=2.0)


def test_guard_follows_store_swap(san):
    """Flagging shared FIRST and swapping the store afterwards (the
    wrap_flat construction order) still arms the guard."""
    tier = KVTier(TierSpec("remote", 1 << 20), block=16)
    tier.shared = True
    from repro.serving.kvstore import PrefixKVStore
    tier.store = PrefixKVStore(1 << 20, block=16)
    tier.store.put(KEY, "p", 10)
    with pytest.raises(san.SanitizerError):
        tier.store.discard(KEY)


# ---------------------------------------------------------------------------
# install/uninstall contract
# ---------------------------------------------------------------------------
def test_uninstall_restores_originals():
    was = sanitize.enabled()
    sanitize.install()
    if not was:
        sanitize.uninstall()
        pt = PageTable(8, 16)
        pt.ensure(1, 16)
        pt.release(1)
        assert pt.release(1) == 0      # original silent behaviour is back
    else:
        # session runs sanitized (REPRO_SANITIZE=1): leave it installed
        assert sanitize.enabled()


def test_install_is_idempotent(san):
    before = sanitize._orig["PageTable.release"]
    sanitize.install()
    assert sanitize._orig["PageTable.release"] is before
