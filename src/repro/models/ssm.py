"""Mamba-1 selective SSM block (falcon-mamba / jamba mixer).

Faithful mamba-1 recurrence:
  h_t = exp(dt_t * A) ⊙ h_{t-1} + dt_t * B_t * x_t
  y_t = C_t · h_t + D ⊙ x_t
with depthwise causal conv front-end and SiLU gating.  Full-sequence apply
uses ``lax.scan`` (compact HLO; the per-step state (B, d_inner, n) is the
"KV-analogue" payload for attention-free archs — see DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.axes import Initializer, Pm
from repro.models.layers import COMPUTE_DTYPE


def init_mamba(ini: Initializer, cfg: ModelConfig) -> Dict[str, Pm]:
    d, di, n, r, kc = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                       cfg.ssm_conv)
    s = 1.0 / math.sqrt(d)
    # A initialised to -[1..n] per channel (S4D-real init).
    a_init = np.log(np.broadcast_to(np.arange(1, n + 1, dtype=np.float32), (di, n)))
    return {
        "in_proj": ini.normal((d, 2 * di), ("embed", "mlp"), scale=s),
        "conv_w": ini.normal((di, kc), ("mlp", None), scale=0.5),
        "conv_b": ini.zeros((di,), ("mlp",)),
        "x_proj": ini.normal((di, r + 2 * n), ("mlp", None),
                             scale=1.0 / math.sqrt(di)),
        "dt_proj_w": ini.normal((r, di), (None, "mlp"), scale=1.0 / math.sqrt(r)),
        "dt_proj_b": ini.constant(
            np.log(np.expm1(np.full((di,), 0.01, dtype=np.float32))), ("mlp",)),
        "a_log": ini.constant(a_init, ("mlp", "state")),
        "d_skip": ini.ones((di,), ("mlp",)),
        "out_proj": ini.normal((di, d), ("mlp", "embed"),
                               scale=1.0 / math.sqrt(di)),
    }


def _causal_depthwise_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """x: (B, S, di); w: (di, k). Returns (y, new_state (B, di, k-1))."""
    bsz, s, di = x.shape
    k = w.shape[1]
    xt = jnp.transpose(x, (0, 2, 1))  # (B, di, S)
    if state is None:
        pad = jnp.zeros((bsz, di, k - 1), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, xt], axis=-1)  # (B, di, S+k-1)
    y = jax.lax.conv_general_dilated(
        xp[:, :, None, :],  # (B, di, 1, S+k-1) NCHW
        w.astype(x.dtype)[:, None, None, :],  # (di, 1, 1, k) OIHW
        window_strides=(1, 1), padding="VALID", feature_group_count=di,
    )[:, :, 0, :]  # (B, di, S)
    y = y + b.astype(x.dtype)[None, :, None]
    new_state = xp[:, :, -(k - 1):] if k > 1 else jnp.zeros((bsz, di, 0), x.dtype)
    return jnp.transpose(y, (0, 2, 1)), new_state


def _ssm_params(params, cfg: ModelConfig, x_conv):
    """Input-dependent (dt, B, C) from the conv output."""
    n, r = cfg.ssm_state, cfg.dt_rank
    proj = jnp.einsum("bsd,dk->bsk", x_conv.astype(COMPUTE_DTYPE),
                      params["x_proj"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, params["dt_proj_w"].astype(jnp.float32))
        + params["dt_proj_b"].astype(jnp.float32)
    )  # (B, S, di)
    return dt, b_mat, c_mat


def apply_mamba(
    params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (y, new_state).  state = {"ssm": (B, di, n), "conv": (B, di, k-1)}.

    With state given and S small (decode), the same scan path runs the
    recurrence from the carried state."""
    bsz, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state

    xz = jnp.einsum("bsd,de->bse", x.astype(COMPUTE_DTYPE),
                    params["in_proj"].astype(COMPUTE_DTYPE))
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each

    conv_state = state["conv"] if state is not None else None
    x_conv, new_conv = _causal_depthwise_conv(
        x_in, params["conv_w"], params["conv_b"], conv_state)
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(COMPUTE_DTYPE)

    dt, b_mat, c_mat = _ssm_params(params, cfg, x_conv)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, n)

    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((bsz, di, n), jnp.float32))

    def step(h, xs):
        xt, dt_t, b_t, c_t = xs  # (B, di), (B, di), (B, n), (B, n)
        da = jnp.exp(dt_t[..., None] * a)  # (B, di, n)
        h = h * da + (dt_t * xt)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(x_conv.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat, 1, 0),
        jnp.moveaxis(c_mat, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, di)
    y = y + x_conv.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(COMPUTE_DTYPE),
                     params["out_proj"].astype(COMPUTE_DTYPE))
    new_state = {"ssm": h_final.astype(jnp.float32), "conv": new_conv}
    return out.astype(x.dtype), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    shapes = {
        "ssm": ((batch, di, n), jnp.float32),
        "conv": ((batch, di, k - 1), COMPUTE_DTYPE),
    }
    if abstract:
        return {kk: jax.ShapeDtypeStruct(sh, dt) for kk, (sh, dt) in shapes.items()}
    return {kk: jnp.zeros(sh, dt) for kk, (sh, dt) in shapes.items()}
