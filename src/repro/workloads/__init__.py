"""Trace-driven workload harness (DESIGN.md §11).

Schema (:mod:`.trace`), arrival processes (:mod:`.arrivals`), scenario
archetypes + tenant composition (:mod:`.scenarios`), and replay adapters
into both serving backends (:mod:`.replay`).  Everything here is
numpy-only — importable without the jax model stack — so million-event
traces can be generated and simulated anywhere.
"""
from repro.workloads.arrivals import (
    ARRIVALS,
    ArrivalProcess,
    DiurnalGammaPoisson,
    OnOffMMPP,
    Poisson,
    make_arrivals,
)
from repro.workloads.replay import (
    DEFAULT_GEOM,
    ModelGeom,
    replay_runtime,
    replay_simulator,
    trace_requests,
)
from repro.workloads.scenarios import (
    ARCHETYPES,
    ScenarioSpec,
    TenantSpec,
    build_tenant_trace,
    build_trace,
    default_tenants,
    generate_events,
    scaled_trace,
)
from repro.workloads.trace import (
    SLO_METRICS,
    Trace,
    TraceEvent,
    iter_chunks,
    validate,
)

__all__ = [
    "ARRIVALS", "ArrivalProcess", "DiurnalGammaPoisson", "OnOffMMPP",
    "Poisson", "make_arrivals",
    "DEFAULT_GEOM", "ModelGeom", "replay_runtime", "replay_simulator",
    "trace_requests",
    "ARCHETYPES", "ScenarioSpec", "TenantSpec", "build_tenant_trace",
    "build_trace", "default_tenants", "generate_events", "scaled_trace",
    "SLO_METRICS", "Trace", "TraceEvent", "iter_chunks", "validate",
]
