"""Codec stage: exact losslessness (property-based) + size behaviour."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import codecs


@settings(max_examples=40, deadline=None)
@given(
    bits=st.integers(1, 8),
    n=st.integers(1, 5000),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitpack_roundtrip(bits, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=n, dtype=np.uint8)
    buf = codecs.bitpack(codes, bits)
    assert len(buf) == (n * bits + 7) // 8
    out = codecs.bitunpack(buf, bits, n)
    np.testing.assert_array_equal(out, codes)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(1, 8),
    n=st.integers(1, 3000),
    codec=st.sampled_from(["none", "zstd1", "zstd3", "zstd10",
                           "bitshuffle_zstd3"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_codec_lossless_property(bits, n, codec, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=n, dtype=np.uint8)
    buf = codecs.encode_codes(codes, bits, codec)
    out = codecs.decode_codes(buf, bits, n, codec)
    np.testing.assert_array_equal(out, codes)


def test_bitshuffle_roundtrip():
    rng = np.random.default_rng(0)
    for bits in (2, 3, 4, 6):
        codes = rng.integers(0, 1 << bits, size=999, dtype=np.uint8)
        buf = codecs.bitshuffle(codes, bits)
        np.testing.assert_array_equal(
            codecs.bitunshuffle(buf, bits, 999), codes)


def test_zstd_compresses_low_entropy():
    codes = np.zeros(8192, dtype=np.uint8)  # trivially compressible
    raw = codecs.encode_codes(codes, 4, "none")
    z = codecs.encode_codes(codes, 4, "zstd3")
    assert len(z) < len(raw) / 10


@pytest.mark.skipif(not codecs.HAVE_ZSTD, reason="bit-plane gain is a "
                    "property of zstd's entropy stage; the zlib fallback "
                    "does not reproduce it")
def test_bitshuffle_helps_smooth_data():
    """Bit-plane coding wins on quantized smooth streams (CacheGen-style)."""
    t = np.arange(16384)
    codes = ((np.sin(t / 80) + 1) * 7.49).astype(np.uint8)  # 4-bit smooth
    plain = codecs.encode_codes(codes, 4, "zstd3")
    shuffled = codecs.encode_codes(codes, 4, "bitshuffle_zstd3")
    assert len(shuffled) < len(plain)


def test_f16_passthrough_roundtrip():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(500).astype(np.float16)
    for codec in ("none", "zstd3"):
        buf = codecs.encode_f16(x, codec)
        np.testing.assert_array_equal(codecs.decode_f16(buf, 500, codec), x)
