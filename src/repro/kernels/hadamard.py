"""Pallas TPU kernel: blockwise Hadamard transform.

TPU adaptation note (DESIGN.md §3): on GPUs the fast Hadamard transform is
a butterfly over warp shuffles; the TPU has no lane-shuffle analogue, and
the MXU is a 128×128 systolic array that multiplies dense 128-wide tiles at
full rate — so the TPU-optimal Hadamard for head_dim ≤ 256 *is* a dense
matmul against the (constant) H matrix, fused over token tiles.  This kernel
keeps H resident in VMEM across the whole grid (constant operand), reading
each token tile once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import hadamard_matrix


def _hadamard_kernel(x_ref, h_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)   # (BT, D)
    h = h_ref[...].astype(jnp.float32)   # (D, D)
    o_ref[...] = jnp.dot(
        x, h, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def hadamard_transform(x: jnp.ndarray, block_tokens: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """x (T, D) -> x @ H_D.  D must be a power of two (64/128/256)."""
    t, d = x.shape
    assert d & (d - 1) == 0, f"D={d} must be a power of two"
    bt = min(block_tokens, t)
    assert t % bt == 0
    h = hadamard_matrix(d)
    return pl.pallas_call(
        _hadamard_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),  # constant across grid
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(x, h)
