"""Elastic scaling: checkpoints written under one mesh restore under a
different mesh (different device count / sharding) — the restart path for
node loss or pool resize at 1000-node scale."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).parent.parent


def _run(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=400, env=env)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-3000:]}"
    return r.stdout


def test_restore_across_mesh_sizes(tmp_path):
    save_code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.checkpoint import CheckpointManager

mesh = make_mesh((2, 4), ("data", "model"))
w = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
w = jax.device_put(w, NamedSharding(mesh, P(None, "model")))
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save(1, {{"w": w}}, metadata={{"mesh": "2x4"}})
print("saved")
"""
    restore_code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.checkpoint import CheckpointManager

# HALF the devices, different topology: elastic restore
mesh = make_mesh((2, 2), ("data", "model"))
mgr = CheckpointManager({str(tmp_path)!r})
template = {{"w": jnp.zeros((8, 16), jnp.float32)}}
shardings = {{"w": NamedSharding(mesh, P("data", "model"))}}
out = mgr.restore(template, shardings=shardings)
expected = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
np.testing.assert_array_equal(np.asarray(out["w"]), expected)
assert out["w"].sharding.spec == P("data", "model")
print("restored")
"""
    assert "saved" in _run(save_code, devices=8)
    assert "restored" in _run(restore_code, devices=4)
