"""Quickstart: compress a KV cache with the unified pipeline, inspect CR
and error, and pick a profile with the analytical controller.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.controller import (
    ServiceContext,
    bandwidth_threshold,
    build_envelope,
    predicted_latency,
)
from repro.core import (
    BASELINES,
    CompressionPipeline,
    KVCache,
    StrategyConfig,
    measure_profile,
)
from repro.serving.network import GBPS


def main():
    # --- 1. a KV cache (use your own (L, H, S, D) arrays in practice) ---
    kv = KVCache.random(num_layers=8, kv_heads=4, seq=512, head_dim=64,
                        seed=0)
    print(f"KV cache: {kv.shape}, {kv.nbytes_wire()/1e6:.1f} MB on the wire")

    # --- 2. compress with a few strategies from the modular pool ---
    strategies = {
        "kivi-2bit": BASELINES["kivi"],
        "cachegen": BASELINES["cachegen"],
        "mixhq": BASELINES["mixhq"],
        "hadamard+4bit+zstd": StrategyConfig(
            transform="hadamard", quantizer="uniform", key_bits=4,
            value_bits=4, granularity="per_token", codec="zstd3"),
    }
    profiles = []
    for name, cfg in strategies.items():
        pipe = CompressionPipeline(cfg)
        restored, comp, t_enc, t_dec = pipe.roundtrip(kv)
        err = np.abs(restored.k - kv.k).mean()
        print(f"{name:22s} cr={comp.compression_ratio():5.2f}x "
              f"wire={comp.total_bytes()/1e6:6.2f}MB mae={err:.4f} "
              f"enc={t_enc*1e3:.0f}ms dec={t_dec*1e3:.0f}ms")
        profiles.append(measure_profile(cfg, [kv]))

    # --- 3. the service-aware selection (Theorems 6.1/6.2) ---
    env = build_envelope(profiles)
    print("\nbandwidth thresholds B* (compression helps only below):")
    for p in profiles:
        print(f"  {p.strategy.short_name():40s} "
              f"B*={bandwidth_threshold(p)/GBPS:.3f} Gbps(scaled)")
    for gbps in (0.02, 0.2, 2.0):
        ctx = ServiceContext("qalike", gbps * GBPS, t_slo=0.0, q_min=0.0,
                             kv_bytes=kv.nbytes_wire())
        best = env.optimal(1.0 / ctx.bandwidth)
        print(f"B={gbps:5.2f} Gbps -> optimal: "
              f"{best.strategy.short_name():40s} "
              f"T_pred={predicted_latency(best, ctx)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
