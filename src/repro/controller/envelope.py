"""Theorem 6.2: the piecewise-optimal policy as a lower envelope of lines.

Each profile p is the line T̃_p(x) = 1/s_p + x/cr_p in x = 1/B.  Minimizing
over profiles = taking the lower envelope; the optimal profile is piecewise
constant in x with breakpoints where adjacent lines intersect.  Offline we
build the envelope per (workload, quality-bucket); online an O(log m) lookup
returns the optimal profile plus its envelope neighbours (the bandit's tiny
candidate set).
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.controller.latency_model import normalized_latency


@dataclass(frozen=True)
class Line:
    intercept: float  # 1/s_p
    slope: float      # 1/cr_p
    profile: Profile

    def at(self, x: float) -> float:
        return self.intercept + self.slope * x


@dataclass
class LowerEnvelope:
    """Sorted segments: x in [breaks[i], breaks[i+1]) -> lines[i]."""

    lines: List[Line] = field(default_factory=list)
    breaks: List[float] = field(default_factory=list)  # len(lines)-1 interior

    def optimal(self, inv_bandwidth: float) -> Profile:
        i = bisect.bisect_right(self.breaks, inv_bandwidth)
        return self.lines[i].profile

    def optimal_index(self, inv_bandwidth: float) -> int:
        return bisect.bisect_right(self.breaks, inv_bandwidth)

    def candidates(self, inv_bandwidth: float, n_neighbors: int = 1
                   ) -> List[Profile]:
        """Model-optimal profile + envelope neighbours (Sec. 6.2)."""
        i = self.optimal_index(inv_bandwidth)
        lo = max(i - n_neighbors, 0)
        hi = min(i + n_neighbors + 1, len(self.lines))
        return [l.profile for l in self.lines[lo:hi]]


def line_of(p: Profile) -> Line:
    s_term = 0.0 if p.s_eff == float("inf") else 1.0 / p.s_eff
    return Line(intercept=s_term, slope=1.0 / p.cr, profile=p)


def build_envelope(profiles: Sequence[Profile],
                   include_identity: bool = True) -> LowerEnvelope:
    """Classic lower-envelope construction over lines (convex duality).

    Sort by slope descending (x→0 favours small intercept; x→∞ favours
    small slope) and run the incremental hull check."""
    lines = [line_of(p) for p in profiles]
    if include_identity:
        lines.append(line_of(IDENTITY_PROFILE))
    # dedupe: keep lowest intercept per slope
    by_slope: Dict[float, Line] = {}
    for l in lines:
        cur = by_slope.get(l.slope)
        if cur is None or l.intercept < cur.intercept:
            by_slope[l.slope] = l
    lines = sorted(by_slope.values(), key=lambda l: (-l.slope, l.intercept))

    # prune lines dominated at x=0 with steeper slope AND higher intercept
    hull: List[Line] = []
    breaks: List[float] = []

    def intersect(a: Line, b: Line) -> float:
        return (b.intercept - a.intercept) / (a.slope - b.slope)

    for l in lines:
        while hull:
            top = hull[-1]
            if l.intercept <= top.intercept:
                # l is never worse than top anywhere (slope smaller too)
                hull.pop()
                if breaks:
                    breaks.pop()
                continue
            x = intersect(top, l)
            if breaks and x <= breaks[-1]:
                hull.pop()
                breaks.pop()
                continue
            breaks.append(x)
            break
        hull.append(l)
    return LowerEnvelope(lines=hull, breaks=breaks)


def brute_force_optimal(profiles: Sequence[Profile], inv_bandwidth: float,
                        include_identity: bool = True) -> Profile:
    """O(n) argmin for property-testing the envelope."""
    cands = list(profiles) + ([IDENTITY_PROFILE] if include_identity else [])
    return min(cands, key=lambda p: normalized_latency(p, inv_bandwidth))
