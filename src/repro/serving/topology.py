"""Cluster network topology: one serialized KV wire per (src, dst) pair.

A PD-separated cluster is not a single link: every (prefill worker ->
decode worker) pair owns its own physical path, with its own —
possibly heterogeneous — bandwidth profile, its own serialized transfer
queue (:class:`~repro.serving.network.KVWire`), and its own
:class:`~repro.serving.network.GoodputEstimator` (the controller's
per-link view of B, seeded from the link's configured trace).  Transfers
on DIFFERENT links overlap freely; transfers on the SAME link contend —
which is exactly the structure load-aware routing exploits.

Build a homogeneous cluster with :meth:`NetworkTopology.full_mesh`, or a
heterogeneous one by overriding individual links::

    topo = NetworkTopology.full_mesh(
        1, 2, BandwidthTrace.constant(1 * GBPS),
        links={(0, 1): BandwidthTrace.constant(0.05 * GBPS)})

The same topology object drives the real-execution
:class:`~repro.serving.cluster.ClusterRuntime` and the event-driven
:class:`~repro.serving.simulator.Simulator` (large-scale sweeps), so
routing policies can be studied at both granularities against identical
link state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

from repro.serving.network import BandwidthTrace, GoodputEstimator, KVWire


def route_name(src: int, dst: int) -> str:
    """Canonical identity of the (prefill ``src`` -> decode ``dst``)
    placement route — also the controller's per-route bandit key."""
    return f"p{src}->d{dst}"


@dataclass
class LinkSpec:
    """Declarative description of one directed (src, dst) link."""

    src: int
    dst: int
    trace: BandwidthTrace


class NetworkTopology:
    """Per-(src, dst) serialized KV links of an N x M cluster."""

    def __init__(self, n_prefill: int = 1, n_decode: int = 1,
                 default_trace: Optional[BandwidthTrace] = None,
                 links: Optional[Dict[Tuple[int, int],
                                      BandwidthTrace]] = None):
        assert n_prefill >= 1 and n_decode >= 1
        self.n_prefill = n_prefill
        self.n_decode = n_decode
        default = default_trace or BandwidthTrace.constant(1e9)
        overrides = dict(links or {})
        for (i, j) in overrides:
            if not (0 <= i < n_prefill and 0 <= j < n_decode):
                raise ValueError(f"link ({i},{j}) outside the "
                                 f"{n_prefill}x{n_decode} mesh")
        self._traces: Dict[Tuple[int, int], BandwidthTrace] = {}
        self._wires: Dict[Tuple[int, int], KVWire] = {}
        for i in range(n_prefill):
            for j in range(n_decode):
                trace = overrides.get((i, j), default)
                self._traces[(i, j)] = trace
                # Each link's estimator starts from the link's OWN
                # configured bandwidth (KVWire seeds it), so routing can
                # tell a 50 Mbps wire from a 1 Gbps one before the first
                # transfer ever lands.
                self._wires[(i, j)] = KVWire(trace, GoodputEstimator())

    # ------------------------------------------------------------------
    @classmethod
    def full_mesh(cls, n_prefill: int, n_decode: int,
                  trace: BandwidthTrace,
                  links: Optional[Dict[Tuple[int, int],
                                       BandwidthTrace]] = None
                  ) -> "NetworkTopology":
        """Every (src, dst) pair connected at ``trace``; individual pairs
        may be overridden via ``links`` (heterogeneous meshes)."""
        return cls(n_prefill, n_decode, default_trace=trace, links=links)

    @classmethod
    def from_specs(cls, n_prefill: int, n_decode: int,
                   specs: List[LinkSpec],
                   default_trace: Optional[BandwidthTrace] = None
                   ) -> "NetworkTopology":
        return cls(n_prefill, n_decode, default_trace=default_trace,
                   links={(s.src, s.dst): s.trace for s in specs})

    # ------------------------------------------------------------------
    def link(self, src: int, dst: int) -> KVWire:
        return self._wires[(src, dst)]

    def trace(self, src: int, dst: int) -> BandwidthTrace:
        return self._traces[(src, dst)]

    def estimator(self, src: int, dst: int) -> GoodputEstimator:
        return self._wires[(src, dst)].estimator

    def pairs(self) -> Iterator[Tuple[int, int]]:
        """All (src, dst) pairs, prefill-major — the round-robin cycle
        order."""
        for i in range(self.n_prefill):
            for j in range(self.n_decode):
                yield (i, j)

    # ------------------------------------------------------------------
    @property
    def n_links(self) -> int:
        return self.n_prefill * self.n_decode

    @property
    def transfers(self) -> int:
        return sum(w.transfers for w in self._wires.values())

    @property
    def bytes_moved(self) -> int:
        return sum(w.bytes_moved for w in self._wires.values())

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"links": float(self.n_links),
                                 "transfers": float(self.transfers),
                                 "bytes_moved": float(self.bytes_moved)}
        for (i, j), wire in sorted(self._wires.items()):
            out[f"link_{route_name(i, j)}_transfers"] = float(wire.transfers)
            out[f"link_{route_name(i, j)}_bytes"] = float(wire.bytes_moved)
        return out
