"""Replay a :class:`~repro.workloads.trace.Trace` into either serving
backend.

One trace, two execution granularities (DESIGN.md §11):

* :func:`replay_simulator` — the event-driven
  :class:`~repro.serving.simulator.Simulator`: events become
  :class:`~repro.serving.request.Request` objects (KV payloads sized by
  :class:`ModelGeom`); millions of requests per sweep.
* :func:`replay_runtime` — the real-execution
  :class:`~repro.serving.cluster.ClusterRuntime` (or its 1x1
  :class:`~repro.serving.engine.ServingRuntime` facade): events are
  submitted as the runtime's virtual clock passes their arrival times,
  with ``prefix_group`` mapped onto ``prompt_seed`` so shared-prefix
  groups share REAL prompts (and therefore real pool entries).

Both adapters are deterministic given the trace: replaying the same trace
twice yields identical results.

The replay loops are serving hot paths: the ``host-sync`` static rule
(DESIGN.md §13) treats every ``replay*`` def here as a hot root, so a
stray device->host sync added to an adapter fails the lint gate the
same way one in ``decode_iteration`` would.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.serving.network import BandwidthTrace
from repro.serving.request import Request
from repro.serving.simulator import Policy, SimConfig, SimResult, Simulator
from repro.workloads.trace import Trace


@dataclass(frozen=True)
class ModelGeom:
    """KV geometry used to size simulator payloads from token counts."""

    num_layers: int = 32
    kv_heads: int = 8
    head_dim: int = 128
    bytes_per_el: int = 2

    def kv_bytes(self, ctx_tokens: int) -> float:
        return (2.0 * self.num_layers * self.kv_heads * self.head_dim
                * ctx_tokens * self.bytes_per_el)


DEFAULT_GEOM = ModelGeom()


def trace_requests(trace: Trace, geom: ModelGeom = DEFAULT_GEOM
                   ) -> List[Request]:
    """Simulator-side materialization (thin wrapper over
    :meth:`Trace.to_requests` with a :class:`ModelGeom`)."""
    return trace.to_requests(num_layers=geom.num_layers,
                             kv_heads=geom.kv_heads,
                             head_dim=geom.head_dim,
                             bytes_per_el=geom.bytes_per_el)


def replay_simulator(trace: Trace, policy: Policy,
                     bandwidth: BandwidthTrace,
                     config: Optional[SimConfig] = None,
                     geom: ModelGeom = DEFAULT_GEOM,
                     **sim_kwargs) -> SimResult:
    """Replay the trace through the event-driven simulator.  Extra
    keyword arguments (``store=``, ``scheduler=``, ``topology=``,
    ``routing=``) pass straight through to :class:`Simulator`."""
    sim = Simulator(config or SimConfig(), policy, bandwidth,
                    trace_requests(trace, geom), **sim_kwargs)
    return sim.run()


def replay_runtime(rt, trace: Trace, max_steps: int = 100_000,
                   events: Optional[Sequence] = None) -> list:
    """Replay the trace through a real-execution runtime
    (:class:`ClusterRuntime` / :class:`ServingRuntime`).

    The runtime's virtual clock only advances inside ``step()``, so the
    adapter steps until the clock passes each event's arrival (or
    fast-forwards over idle gaps), then submits it.  Mapping:

    * ``workload``      -> the runtime's prompt family,
    * ``prefix_group``  -> ``prompt_seed`` (equal groups => equal real
      prompts => real pool reuse),
    * ``out_tokens``    -> decode budget (clamped to the runtime's
      ``decode_tokens`` arena budget),
    * SLO contract      -> passed through verbatim.

    ``ctx_tokens`` is fixed by the runtime (``cfg.seq``) — the real
    model's prompt window — which is the documented fidelity gap between
    the two backends (DESIGN.md §11).  Returns the runtime's completed
    list."""
    evs = list(events) if events is not None else list(trace.events)
    evs.sort(key=lambda e: e.t)
    steps = 0
    for ev in evs:
        while rt.clock < ev.t and not rt.scheduler.idle \
                and steps < max_steps:
            rt.step()
            steps += 1
        if rt.clock < ev.t:
            rt.clock = ev.t        # idle gap: jump the virtual clock
        rt.submit(ev.workload, t_slo=ev.t_slo, q_min=ev.q_min,
                  slo_class=ev.slo_class, out_tokens=ev.out_tokens,
                  prompt_seed=ev.prefix_group, slo_metric=ev.slo_metric)
    rt.run(max_steps=max(max_steps - steps, 1))
    return rt.completed
