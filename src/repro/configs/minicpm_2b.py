"""Config alias for --arch minicpm-2b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("minicpm-2b")
