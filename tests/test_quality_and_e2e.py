"""Quality proxy + full-system integration on the real tiny model."""
import numpy as np
import pytest

from repro.core.strategy import BASELINES, IDENTITY_STRATEGY, StrategyConfig


def test_identity_quality_is_one(reference_model):
    from repro.core.quality import evaluate_quality
    q = evaluate_quality(IDENTITY_STRATEGY, ref=reference_model)
    assert all(v == 1.0 for v in q.values())


def test_quality_monotone_in_bits(reference_model):
    from repro.core.quality import evaluate_quality
    q8 = evaluate_quality(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8),
        ref=reference_model, n_prompts=4, decode_tokens=12)
    q2 = evaluate_quality(
        StrategyConfig(quantizer="uniform", key_bits=2, value_bits=2,
                       granularity="per_head"),
        ref=reference_model, n_prompts=4, decode_tokens=12)
    m8 = np.mean(list(q8.values()))
    m2 = np.mean(list(q2.values()))
    assert m8 > m2
    assert m8 > 0.7


def test_workload_dependence(reference_model):
    """Motivation 1: rankings differ across workloads for real methods."""
    from repro.core.quality import evaluate_quality
    qs = {name: evaluate_quality(BASELINES[name], ref=reference_model,
                                 n_prompts=4, decode_tokens=12)
          for name in ("kivi", "duoattention")}
    workloads = list(next(iter(qs.values())))
    rank_per_w = {}
    for w in workloads:
        rank_per_w[w] = sorted(qs, key=lambda n: -qs[n][w])
    # at least two workloads order the methods differently OR the gap
    # varies strongly (weaker but robust check)
    orders = set(tuple(v) for v in rank_per_w.values())
    gaps = [qs["kivi"][w] - qs["duoattention"][w] for w in workloads]
    assert len(orders) > 1 or (max(gaps) - min(gaps)) > 0.1


def test_kv_extract_inject_roundtrip(reference_model):
    from repro.core.quality import _jitted_steps, _prompts_for, extract_kv, inject_kv
    cfg, params = reference_model
    pre, dec, _ = _jitted_steps(cfg.name, 96, 2, 100)
    tokens, _ = _prompts_for("codelike", 2, 96, 0)
    _, caches = pre(params, {"tokens": tokens})
    kv = extract_kv(cfg, caches, 0, upto=96)
    assert kv.shape == (cfg.num_layers, cfg.kv_heads, 96,
                        cfg.resolved_head_dim)
    caches2 = inject_kv(cfg, caches, 0, kv)
    # lossless inject: caches identical (bf16 roundtrip)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(caches),
                    jax.tree_util.tree_leaves(caches2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


@pytest.mark.slow
def test_engine_end_to_end(reference_model):
    """Real PD serving: bytes on the wire, agreement, controller feedback."""
    from repro.controller import ServiceAwareController
    from repro.launch.profile_offline import build_profiles
    from repro.serving.engine import DisaggregatedEngine
    from repro.serving.network import GBPS, BandwidthTrace

    profiles = build_profiles(
        [BASELINES["kivi"], BASELINES["mixhq"],
         StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                        granularity="per_channel")],
        quality_kwargs={"n_prompts": 3, "decode_tokens": 10})
    controller = ServiceAwareController(
        {w: profiles for w in ("mathlike", "codelike", "qalike", "summlike")})
    engine = DisaggregatedEngine(controller=controller, batch=2,
                                 decode_tokens=8, seq=128)
    res = engine.serve("codelike", BandwidthTrace.constant(0.05 * GBPS))
    assert res.wire_bytes > 0 and res.wire_bytes < res.kv_bytes * 1.1
    assert 0.0 <= res.agreement <= 1.0
    assert res.jct > 0


@pytest.mark.slow
def test_full_loop_profile_to_controller_to_sim(reference_model):
    """Offline profiles (real measurements) -> controller -> simulator:
    KVServe beats every static baseline at ≥1 bandwidth and never loses
    badly anywhere (the paper's core end-to-end claim, Fig 12/13)."""
    from repro.controller import ServiceAwareController
    from repro.launch.profile_offline import build_profiles
    from repro.serving import (BandwidthTrace, GBPS, KVServePolicy,
                               NoCompressionPolicy, SimConfig, Simulator,
                               StaticPolicy, WorkloadMix)

    strategies = [
        BASELINES["kivi"], BASELINES["cachegen"],
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8),
    ]
    profiles = build_profiles(strategies,
                              quality_kwargs={"n_prompts": 3,
                                              "decode_tokens": 10})
    workloads = ("mathlike", "codelike", "qalike", "summlike")
    # q_min=0: pure latency-policy comparison — statics ignore quality
    # budgets entirely, so any q_min>0 would (correctly) handicap KVServe.
    reqs = lambda: WorkloadMix(rate=2.0, seed=0, q_min=0.0).generate(30)

    wins = 0
    for bw in (0.05 * GBPS, 50 * GBPS):
        trace = BandwidthTrace.constant(bw)
        statics = {}
        for p in profiles[1:]:
            statics[p.strategy.short_name()] = Simulator(
                SimConfig(), StaticPolicy(p, "s"), trace, reqs()).run().mean_jct()
        statics["default"] = Simulator(
            SimConfig(), NoCompressionPolicy(), trace, reqs()).run().mean_jct()
        controller = ServiceAwareController({w: profiles for w in workloads})
        kv = Simulator(SimConfig(), KVServePolicy(controller), trace,
                       reqs()).run().mean_jct()
        best = min(statics.values())
        assert kv <= best * 1.3, (bw, kv, statics)
        if kv <= best * 1.001:
            wins += 1
    assert wins >= 1
