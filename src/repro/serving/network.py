"""Network model: time-varying effective bandwidth + the controller's
goodput estimator.

The realized communication cost is governed by effective goodput under
contention, not nominal link speed (Sec. 3.1) — traces are piecewise
constant with optional per-transfer jitter; the estimator only sees
observed transfers (EWMA), which creates the offline→online drift the
bandit corrects.
"""
from __future__ import annotations

import math
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

GBPS = 1e9 / 8  # 1 Gbps in bytes/s


@dataclass
class BandwidthTrace:
    """Piecewise-constant B(t) in bytes/s."""

    times: List[float]   # segment start times, times[0] == 0
    values: List[float]  # bytes/s per segment
    jitter: float = 0.0  # multiplicative lognormal sigma per transfer
    seed: int = 0

    def __post_init__(self):
        assert self.times[0] == 0.0 and len(self.times) == len(self.values)

    @staticmethod
    def constant(bandwidth: float) -> "BandwidthTrace":
        return BandwidthTrace([0.0], [bandwidth])

    @staticmethod
    def steps(segments: Sequence[Tuple[float, float]],
              jitter: float = 0.0, seed: int = 0) -> "BandwidthTrace":
        ts, vs = zip(*segments)
        return BandwidthTrace(list(ts), list(vs), jitter=jitter, seed=seed)

    def at(self, t: float) -> float:
        i = bisect_right(self.times, t) - 1
        return self.values[max(i, 0)]

    def _jitter_mult(self, start: float, nbytes: float) -> float:
        """Per-transfer multiplier derived deterministically from
        (seed, start, nbytes): identical transfers get identical times
        across calls and replays, and a trace shared between the runtime
        and the simulator cannot cross-contaminate either's stream."""
        if self.jitter <= 0:
            return 1.0
        key = (self.seed,
               int(np.float64(start).view(np.uint64)),
               int(np.float64(nbytes).view(np.uint64)))
        rng = np.random.default_rng(key)
        return float(np.exp(rng.normal(0.0, self.jitter)))

    def transfer_time(self, start: float, nbytes: float) -> float:
        """Time to push nbytes starting at `start`, integrating over the
        trace (with optional per-transfer jitter).  Zero-rate segments
        model link outages: the transfer waits them out (nothing moves,
        time passes); a trailing outage that never recovers yields inf
        rather than a division by zero."""
        if nbytes <= 0:
            return 0.0
        if self.jitter <= 0:
            times = self.times
            n = len(times)
            if n == 1:
                # Constant trace — by far the common sweep configuration.
                # The general loop below re-scans segments per transfer,
                # which dominated million-request replays.
                rate = self.values[0]
                return nbytes / rate if rate > 0.0 else float("inf")
            i = bisect_right(times, start) - 1
            if i >= n - 1:
                # Past the last change point: one unbounded segment.
                rate = self.values[n - 1]
                return nbytes / rate if rate > 0.0 else float("inf")
            mult = 1.0
        else:
            mult = self._jitter_mult(start, nbytes)
            i = bisect_right(self.times, start) - 1
        remaining = nbytes
        t = start
        while True:
            rate = self.values[max(i, 0)] * mult
            seg_end = self.times[i + 1] if i + 1 < len(self.times) else float("inf")
            if rate <= 0.0:
                if seg_end == float("inf"):
                    return float("inf")  # outage never ends: bytes never land
                t = seg_end             # wait out the outage segment
                i += 1
                continue
            dt_seg = seg_end - t
            can = rate * dt_seg
            if can >= remaining or seg_end == float("inf"):
                return (t + remaining / rate) - start
            remaining -= can
            t = seg_end
            i += 1


@dataclass
class WireTransfer:
    """Outcome of one serialized wire send."""

    t_wait: float    # queueing behind earlier transfers (wire busy)
    t_comm: float    # on-wire time once started
    start: float     # absolute start time (after queueing)

    @property
    def total(self) -> float:
        return self.t_wait + self.t_comm

    @property
    def end(self) -> float:
        return self.start + self.t_comm


class KVWire:
    """The PD transfer link as a serialized queue: one transfer occupies the
    wire at a time, so concurrent senders contend (a request admitted while
    another's KV is in flight waits for the wire before its bytes move).
    The wire is granted in ``send`` order — a later sender whose bytes are
    ready earlier still queues behind an already-granted reservation
    (admission order is priority order, so earlier senders keep the link).
    Every send is billed from the :class:`BandwidthTrace` and reported to
    the goodput estimator as ON-WIRE goodput (``nbytes / t_comm``, the B
    of the latency model's transfer term); queueing delay is deliberately
    excluded — it reaches the controller through the residual bandit's
    observed latency (``wire_wait`` is on the critical path), not by
    deflating the bandwidth estimate, which would double-count it."""

    def __init__(self, trace: BandwidthTrace,
                 estimator: Optional["GoodputEstimator"] = None):
        self.trace = trace
        self.estimator = estimator
        if estimator is not None and estimator.initial is None:
            # An unseeded estimator attached to a link starts from the
            # link's *configured* trace, not a universal guess: on a
            # 50 Mbps wire the controller's first selections would
            # otherwise assume a ~1600x faster network until the first
            # observations arrive.
            estimator.initial = seed_bandwidth(trace)
        self.free_at = 0.0
        self.transfers = 0
        self.bytes_moved = 0

    def send(self, ready: float, nbytes: float) -> WireTransfer:
        """Push ``nbytes`` onto the wire no earlier than ``ready``; returns
        the queueing wait and on-wire time (both on the sender's critical
        path)."""
        start = max(ready, self.free_at)
        t_comm = self.trace.transfer_time(start, nbytes)
        self.free_at = start + t_comm
        self.transfers += 1
        self.bytes_moved += int(nbytes)
        if self.estimator is not None:
            self.estimator.observe(nbytes, t_comm)
        return WireTransfer(t_wait=start - ready, t_comm=t_comm, start=start)


def seed_bandwidth(trace: BandwidthTrace) -> float:
    """The estimator prior a link's configured trace implies: its rate at
    t=0, or — for a trace that STARTS in an outage segment (rate 0, legal
    since the outage fix) — the first positive segment's rate, so a zero
    prior can never reach the latency model's divisions.  A trace with no
    positive segment at all falls back to the detached prior."""
    b0 = trace.at(0.0)
    if b0 > 0:
        return b0
    return next((v for v in trace.values if v > 0),
                GoodputEstimator.DETACHED_INITIAL)


@dataclass
class GoodputEstimator:
    """EWMA over observed transfer goodputs — the controller's view of B.

    ``initial`` is the pre-observation prior.  Leave it None to have the
    first :class:`KVWire` the estimator is attached to seed it from the
    link's configured :class:`BandwidthTrace` (``trace.at(0.0)``) — the
    per-link default everywhere in the serving stack.  Only a completely
    detached estimator falls back to the legacy 10 Gb/s guess."""

    alpha: float = 0.3
    initial: Optional[float] = None
    _est: Optional[float] = None

    DETACHED_INITIAL = 10 * GBPS  # last-resort prior (no link to seed from)

    def observe(self, nbytes: float, seconds: float) -> None:
        # math.isfinite beats np.isfinite ~20x on scalars — this runs once
        # per simulated transfer.
        if seconds <= 0 or nbytes <= 0 or not math.isfinite(seconds):
            return  # outage transfers (inf) carry no goodput signal
        goodput = nbytes / seconds
        self._est = goodput if self._est is None else \
            (1 - self.alpha) * self._est + self.alpha * goodput

    @property
    def estimate(self) -> float:
        if self._est is not None:
            return self._est
        return self.initial if self.initial is not None \
            else self.DETACHED_INITIAL
