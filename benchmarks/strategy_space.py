"""Paper Fig. 5: strategy-space size per granularity (left) and the
latency-accuracy scatter of a profile collection (right)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_profiles, emit
from repro.core.strategy import space_sizes
from repro.profiling.pareto import profile_latency


def run(smoke: bool = False) -> None:
    # space enumeration + scatter over cached profiles: already CI-cheap
    t0 = time.perf_counter()
    sizes = space_sizes()
    emit("fig5_space_sizes", (time.perf_counter() - t0) * 1e6,
         f"pipeline={sizes['pipeline']} module={sizes['module']} "
         f"hybrid={sizes['hybrid']}")

    profiles = cached_profiles()
    lats = [profile_latency(p, 1e9) for p in profiles]
    accs = [min(p.quality.values()) if p.quality else 1.0 for p in profiles]
    emit("fig5_scatter", 0.0,
         f"n={len(profiles)} lat_spread={max(lats)/max(min(lats),1e-12):.1f}x "
         f"acc_range=[{min(accs):.3f},{max(accs):.3f}]")


if __name__ == "__main__":
    run()
