"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis carries PD-disaggregation traffic (see distribution/kv_transfer.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run "
            "under launch/dryrun.py which forces 512 host devices")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    n = int(np.prod(shape))
    devices = jax.devices()
    assert len(devices) >= n, (shape, len(devices))
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(data: int = 2, model: int = 2,
                   pod: Optional[int] = None) -> Mesh:
    """Small mesh for unit tests (requires forced host device count)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
