"""3D Pareto frontier: dominance properties (hypothesis vs brute force)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.profiling import ParetoPoint, dominates, pareto_frontier


def _points(seed, n):
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        pts.append(ParetoPoint(
            acc=float(rng.uniform(0.5, 1.0)),
            cr=float(rng.uniform(1, 10)),
            lat=float(rng.uniform(1e-10, 1e-8)),
            profile=Profile(StrategyConfig(key_bits=(i % 7) + 2), cr=1.0,
                            s_enc=1.0, s_dec=1.0),
        ))
    return pts


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 60))
def test_frontier_is_exactly_nondominated(seed, n):
    pts = _points(seed, n)
    frontier = pareto_frontier(pts)
    fs = set(id(p) for p in frontier)
    for p in pts:
        dominated = any(dominates(q, p) for q in pts if q is not p)
        assert (id(p) in fs) == (not dominated)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_no_mutual_domination_on_frontier(seed):
    frontier = pareto_frontier(_points(seed, 40))
    for a in frontier:
        for b in frontier:
            if a is not b:
                assert not dominates(a, b)


def test_single_point():
    pts = _points(0, 1)
    assert pareto_frontier(pts) == pts
