"""Worker abstractions of the disaggregated serving runtime.

The paper's deployment target is a PD-separated *cluster*: many prefill
workers feeding many decode workers over heterogeneous links.  This module
holds the two worker types that
:class:`~repro.serving.cluster.ClusterRuntime` composes N x M (and that the
1x1 :class:`~repro.serving.engine.ServingRuntime` facade is built from):

* :class:`PrefillWorker` — one prefill engine: its own jitted batch-1
  prefill stream, the codec-cost model for the compress stage it feeds the
  egress link, and the controller/static profile selection for the KV it
  emits.  Within an iteration, requests assigned to the same prefill
  worker serialize on it (the ``busy`` offset); requests on different
  workers run concurrently.
* :class:`DecodeWorker` — one decode engine: its own fixed-capacity slot
  arena (ONE cache pytree with a leading slot axis, advanced by a single
  masked jitted decode per iteration), its own local slot-id pool, and its
  own decode-side KV tier hierarchy (HBM/DRAM are worker-local; the remote
  pool tier may be shared cluster-wide — see
  :class:`~repro.serving.kvstore.TieredKVStore`).

Both workers read the model through a shared mutable :class:`ModelHandle`
so a runtime-level swap of (cfg, params) — the test fixtures pin the
session-cached reference model this way — reaches every worker.

This module also owns the pieces the old monolithic engine shared between
its one-shot and continuous paths: :class:`RuntimeConfig`,
:class:`ServedRequest`, the PD codec stages (:func:`compress_kvs` /
:func:`decompress_kvs`) and the demotion re-compression hook
(:func:`recompress_entry`).  ``repro.serving.engine`` re-exports them, so
existing imports keep working.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.controller import Decision, ServiceAwareController, ServiceContext
from repro.core import codecs
from repro.core.kvcache import PageTable
from repro.core.pipeline import CompressedKV, CompressionPipeline
from repro.core.profiles import Profile
from repro.core.quality import (
    _jitted_steps,
    _paged_steps,
    copy_cache_slot,
    copy_cache_slot_paged,
    extract_kv,
    init_paged_pools,
    inject_kv,
    inject_kv_paged,
    inject_quant_pages,
)
from repro.core.strategy import StrategyConfig, paged_eligible
from repro.serving.kvstore import TierSpec
from repro.serving.request import Request


def _select_profile(controller: Optional[ServiceAwareController],
                    static_profile: Optional[Profile],
                    ctx: ServiceContext
                    ) -> Tuple[Profile, Optional[Decision]]:
    """Shared controller / static / identity three-way profile choice."""
    if controller is not None:
        d = controller.select(ctx)
        return d.profile, d
    if static_profile is not None:
        return static_profile, None
    from repro.core.profiles import IDENTITY_PROFILE
    return IDENTITY_PROFILE, None


# ---------------------------------------------------------------------------
# Shared PD codec stages (one-shot engine AND per-request continuous runtime)
# ---------------------------------------------------------------------------
def compress_kvs(strategy: StrategyConfig, kvs: Sequence[Any]
                 ) -> Tuple[List[Any], int, float]:
    """Compress each KV prefix for the wire.  Returns
    ``(payloads, wire_bytes, measured_seconds)``."""
    pipe = CompressionPipeline(strategy)
    t0 = time.perf_counter()
    comps = [pipe.compress(kv) for kv in kvs]
    t_wall = time.perf_counter() - t0
    return comps, sum(c.total_bytes() for c in comps), t_wall


def decompress_kvs(comps: Sequence[CompressedKV]
                   ) -> Tuple[List[Any], float]:
    """Restore wire payloads to KV.  Returns ``(kvs, measured_seconds)``."""
    t0 = time.perf_counter()
    kvs = [CompressionPipeline(c.strategy).decompress(c) for c in comps]
    t_wall = time.perf_counter() - t0
    return kvs, t_wall


def quant_entry_arrays(comp: CompressedKV):
    """Unpack a paged-eligible :class:`CompressedKV` into page-pool form:
    ``((k_codes, k_scales), (v_codes, v_scales))`` with codes (L, H, S, D)
    signed int8 and scales (L, H, S, D) per-channel f32 (the stored fp16
    group scale broadcast across its group — numerically identical to the
    grouped multiply, so the fused dequant is bit-for-bit equal to
    ``group_dequantize`` + materialized injection).

    Only valid when ``paged_eligible(comp.strategy)``: one symmetric
    per-token bucket per tensor, codec "none", no transform."""
    L, H, S, D = comp.shape
    out = []
    for wires in (comp.k_buckets, comp.v_buckets):
        assert len(wires) == 1, "paged-eligible strategies are single-bucket"
        w = wires[0]
        count = int(np.prod(w.codes_shape))
        codes = codecs.decode_codes(w.payload, w.bits, count,
                                    comp.strategy.codec)
        codes = codes.reshape(w.codes_shape)          # (N, S, D) uint8
        signed = (codes.astype(np.int16)
                  - (1 << (w.bits - 1))).astype(np.int8)
        sc = w.scale.astype(np.float32)[..., 0]       # (N, S, D/group)
        sc = np.repeat(sc, w.group_size, axis=2)[:, :, :D]
        arr = np.zeros((L, H, S, D), np.int8)
        sarr = np.zeros((L, H, S, D), np.float32)
        ls, hs = w.lh_index[:, 0], w.lh_index[:, 1]
        arr[ls, hs] = signed
        sarr[ls, hs] = sc
        out.append((arr, sarr))
    return out[0], out[1]


def recompress_entry(entry, profile: Profile) -> Optional[Tuple[Any, int]]:
    """Tier demotion / refetch-smaller hook: really re-encode a stored
    ``(CompressedKV, first, s_dec)`` payload with ``profile``.  Returns
    None when it would not shrink."""
    comp, first, _ = entry.payload
    if comp.strategy == profile.strategy:
        return None
    restored, _ = decompress_kvs([comp])
    comps, wire, _ = compress_kvs(profile.strategy, restored)
    if wire >= entry.wire_bytes:
        return None
    return (comps[0], first, profile.s_dec), wire


# ---------------------------------------------------------------------------
# Runtime configuration / outcomes
# ---------------------------------------------------------------------------
@dataclass
class RuntimeConfig:
    seq: int = 96                 # prompt tokens (padded/truncated)
    decode_tokens: int = 12       # generation budget per request
    # Serving scenario: "pool" = KV-disaggregated prefix caching (cold
    # requests prefill locally, pool writes are off the critical path);
    # "pd" = PD separation (every cold request's compressed KV crosses the
    # serialized wire prefill -> compress -> transfer -> decompress ->
    # decode, ON the critical path).
    mode: str = "pool"
    # Virtual-clock cost model.  None = measure wall-clock (real execution
    # time of the tiny model); a float models a loaded cluster, which is the
    # paper's pool regime where prefill is the expensive path.  When set,
    # codec stages are modelled from the profile's measured throughputs
    # (V/s_enc, V/s_dec — Eq. 1) so sweeps are deterministic.
    prefill_tok_s: Optional[float] = None
    decode_tok_s: Optional[float] = None
    pool_fetch_overhead: float = 0.002   # pool RPC setup cost (s)
    store_capacity: int = 64 << 20       # wire bytes (remote/pool tier)
    store_block: int = 16
    # KV memory hierarchy (ISSUE 4).  None builds the default: pool mode
    # gets HBM -> DRAM -> remote (hot/dram capacities below; HBM/DRAM are
    # per-decode-worker, the remote pool tier is shared cluster-wide over
    # the runtime's BandwidthTrace); PD mode gets, per decode worker, a
    # single remote tier sharing that worker's ingress link (the pool
    # lives across the same wire the compressed KV crosses).  Pass an
    # explicit TierSpec list to override either (each worker then builds
    # its own private tiers from the specs; pass pre-built
    # :class:`~repro.serving.kvstore.KVTier` objects to share tiers).
    tiers: Optional[Sequence[TierSpec]] = None
    hot_tier_bytes: int = 4 << 20
    dram_tier_bytes: int = 16 << 20
    # PD cold path: what the decode arena is materialized from.  False
    # (default) keeps the prefill worker's exact cache — cold decode is
    # numerically identical to the pool scenario (token-exact vs the
    # pinned PR-1 fixture); the compressed payload still crosses the wire
    # byte-for-byte and is what later pool hits decode from, so the
    # profile's quality loss surfaces exactly where the pool path's does.
    # True injects the wire-restored KV instead (quality-faithful decode;
    # tokens then reflect the selected profile's loss immediately).
    pd_inject_restored: bool = False
    # Paged decode arena (DESIGN.md §12): the dense (n_slots, max_len)
    # cache becomes (num_pages, page_size, ...) pools with per-slot block
    # tables over a shared free pool — slot capacity is allocated page by
    # page on demand, and pool/PD hits whose stored strategy is
    # paged-eligible (symmetric per-token uniform int4/int8, see
    # ``repro.core.strategy.paged_eligible``) land as packed quantized
    # pages with NO materialized decompress on the TTFT critical path.
    # For token-exact parity with the dense arena, pick a ``page_size``
    # that divides ``seq + decode_tokens + 2``.
    paged: bool = False
    page_size: int = 16
    # Total pool pages (including the reserved scratch page 0).  None
    # sizes it worst-case-safe: n_slots * ceil(max_len / page_size) + 1.
    # Smaller values oversubscribe HBM (more slots than worst-case fit);
    # a slot that cannot grow raises ``ArenaOutOfPages``.
    arena_pages: Optional[int] = None
    # Speculative + lookahead decoding (DESIGN.md §15).  spec_k = 0
    # (default) keeps today's one-token-per-iteration arena decode,
    # bit-identical.  spec_k > 0 turns each decode iteration into a draft
    # phase (up to k proposed tokens per slot) + ONE masked multi-token
    # verify step; greedy verification keeps the token stream exact.
    spec_k: int = 0
    # Draft source: "ngram" = draft-free per-slot suffix-match lookahead
    # over prompt + generated tokens; "model" = two-model path (a draft
    # model's own dense arena proposes its greedy continuations).
    spec_kind: str = "ngram"
    # Controller-adaptive speculation length: the controller's per-route
    # accept-rate estimate picks each request's k from spec_candidates
    # (capped at spec_k); False applies spec_k uniformly.
    spec_adaptive: bool = False
    spec_candidates: Tuple[int, ...] = (0, 2, 4)

    @property
    def arena_max_len(self) -> int:
        """Arena row length.  The speculative path scatters up to spec_k
        extra in-flight KV rows past the last committed position, so the
        margin grows with the speculation width (spec_k = 0 keeps the
        historical seq + decode_tokens + 2 exactly)."""
        return self.seq + self.decode_tokens + 2 + self.spec_k


@dataclass
class ServedRequest:
    """Per-request outcome of the continuous runtime (the per-request
    analogue of :class:`~repro.serving.engine.ServedBatch`)."""

    rid: int
    workload: str
    slo_class: str
    text: str
    tokens: np.ndarray
    profile: str
    pool_hit: bool
    kv_bytes: int
    wire_bytes: int               # bytes this request moved over the wire
    arrival: float
    done: float
    ttft: float
    slot: int = -1                # arena slot that served the request
    # Placement: which (prefill worker -> decode worker) route served the
    # request ("p0->d0"; the slot id above is LOCAL to that decode worker).
    route: str = ""
    # Critical-path decomposition; sums exactly to jct.  Keys: queue,
    # prefill | comm+decompress (pool hit), decode, stall (time spent
    # waiting on the iteration's other stream), and — PD mode — compress,
    # wire_wait (queueing behind other transfers on the serialized wire),
    # comm, decompress, all on the request's critical path.
    breakdown: Dict[str, float] = field(default_factory=dict)
    # Off-critical-path cost of writing the compressed prefix to the pool
    # (compress + wire), charged to the background writer, not the request.
    # Always 0.0 in PD mode: there the transfer IS the critical path, and
    # the transferred bytes seed the decode-side pool for free.
    t_pool_write: float = 0.0
    # Which latency the SLO bounded ("ttft" | "jct"), the bound itself,
    # and whether it was violated — the bandit observed the SAME metric.
    slo_metric: str = "jct"
    t_slo: float = 0.0
    slo_violated: bool = False
    # Speculative-decode outcome (DESIGN.md §15): the k this request ran
    # with, verify steps taken, tokens committed by them, and the draft
    # offer/accept tallies behind the controller's accept-rate feedback.
    spec_k: int = 0
    verify_steps: int = 0
    spec_committed: int = 0
    drafts_offered: int = 0
    drafts_accepted: int = 0

    @property
    def jct(self) -> float:
        return self.done - self.arrival

    @property
    def tokens_per_step(self) -> float:
        """Mean committed tokens per verify step (1.0 when not run
        speculatively — every plain iteration commits one token)."""
        if self.verify_steps <= 0:
            return 1.0
        return self.spec_committed / self.verify_steps


@dataclass
class Slot:
    """Host-side bookkeeping for one occupied arena slot (the device-side
    state — cache row, position, live flag — lives in the owning
    :class:`DecodeWorker`'s arena arrays)."""

    req: Request
    idx: int                      # arena slot index (row in the cache pytree)
    toks: List[int]               # generated tokens (incl. first)
    pool_hit: bool
    profile: str
    wire_bytes: int
    breakdown: Dict[str, float]
    ttft: float
    route: str = ""               # placement route ("p0->d1")
    pool_write: float = 0.0       # off-path compress+write cost (misses)
    # Controller feedback deferred to _finish so the bandit observes the
    # request's realized critical-path latency (= breakdown sum = jct),
    # not the off-critical-path pool write.
    ctx: Optional[ServiceContext] = None
    decision: Optional[Decision] = None
    # Speculative decode state (DESIGN.md §15): this slot's draft budget
    # and its running verify/accept tallies.
    spec_k: int = 0
    verify_steps: int = 0
    spec_committed: int = 0
    drafts_offered: int = 0
    drafts_accepted: int = 0


@dataclass
class ModelHandle:
    """Shared mutable reference to the serving model.  Workers read
    (cfg, params) through this handle at call time, so a runtime-level
    swap — e.g. the tests pinning the session-cached reference model —
    reaches every worker without rebuilding them."""

    cfg: Any
    params: Any


def codec_cost(cfg: RuntimeConfig, measured: float, nbytes: float,
               speed: float) -> float:
    """Codec stage cost: measured wall-clock, or — under the virtual
    clock — modelled from the profile's throughput (V/s, Eq. 1)."""
    if cfg.prefill_tok_s is None:
        return measured
    return 0.0 if speed == float("inf") else nbytes / speed


# ---------------------------------------------------------------------------
# Prefill worker
# ---------------------------------------------------------------------------
class PrefillWorker:
    """One prefill engine of the cluster: runs real batch-1 prefills,
    selects/compresses the KV it ships, and carries the codec-cost model.
    Requests placed on the same worker within an iteration serialize on it
    (the caller threads the ``busy`` offset); distinct workers overlap."""

    def __init__(self, wid: int, model: ModelHandle, cfg: RuntimeConfig,
                 controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None):
        self.wid = wid
        self.name = f"p{wid}"
        self.model = model
        self.cfg = cfg
        self.controller = controller
        self.static_profile = static_profile
        self.prefills = 0             # lifetime prefill count
        self.busy_seconds = 0.0       # lifetime prefill-stream occupancy
        # EWMA of measured prefill wall-clock: the router's t_model
        # estimate when no virtual clock is configured.
        self._ewma_prefill: Optional[float] = None
        self._pre1 = None

    # ------------------------------------------------------------------
    def _prefill_fn(self):
        if self._pre1 is None:
            self._pre1, _, _ = _jitted_steps(
                self.model.cfg.name, self.cfg.seq, 1, self.cfg.arena_max_len)
        return self._pre1

    def expected_prefill_s(self, ctx_tokens: int) -> float:
        """The router's estimate of this worker's prefill time: exact
        under the virtual clock, EWMA of measured wall-clock otherwise."""
        if self.cfg.prefill_tok_s:
            return ctx_tokens / self.cfg.prefill_tok_s
        return self._ewma_prefill if self._ewma_prefill is not None else 0.0

    # ------------------------------------------------------------------
    def prefill(self, req: Request, tokens: np.ndarray):
        """Real batch-1 prefill.  Returns ``(caches, first_token,
        t_prefill)`` with ``t_prefill`` under the configured cost model."""
        pre1 = self._prefill_fn()
        t0 = time.perf_counter()
        logits, caches = pre1(self.model.params, {"tokens": tokens[None, :]})
        # lint: sync-ok(measures real prefill wall-clock for the EWMA model)
        jax.block_until_ready(logits)
        t_wall = time.perf_counter() - t0
        t_prefill = (req.ctx_tokens / self.cfg.prefill_tok_s
                     if self.cfg.prefill_tok_s else t_wall)
        self.prefills += 1
        self.busy_seconds += t_prefill
        self._ewma_prefill = t_wall if self._ewma_prefill is None \
            else 0.7 * self._ewma_prefill + 0.3 * t_wall
        # lint: sync-ok(one first-token pull per prefill seeds the decode slot)
        first = int(np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))[0])
        return caches, first, t_prefill

    # ------------------------------------------------------------------
    def select_and_compress(self, req: Request, caches, t_prefill: float,
                            bandwidth: float, slo_default: str,
                            route: str = ""):
        """Controller decision + real compression of the prefix KV.
        ``bandwidth`` is the selecting route's goodput estimate (per-link
        in a cluster) and ``route`` its identity, so the controller's
        residual bandit learns each link's drift separately.  Returns
        ``(comp, ctx, decision, profile, t_compress)``."""
        kv = extract_kv(self.model.cfg, caches, 0, upto=self.cfg.seq)
        # Serial decode-stream time under the virtual clock feeds the
        # controller's speculation-length choice (DESIGN.md §15); 0 when
        # wall-clock-measured (the k-selection then ranks on modelled
        # throughput alone).
        t_decode = (req.out_tokens / self.cfg.decode_tok_s
                    if self.cfg.decode_tok_s else 0.0)
        ctx = ServiceContext(
            workload=req.workload, bandwidth=bandwidth,
            t_slo=req.t_slo, q_min=req.q_min, t_model=t_prefill,
            kv_bytes=kv.nbytes_wire(),
            slo_metric=req.resolved_slo_metric(slo_default),
            route=route, decode_time=t_decode)
        profile, decision = _select_profile(self.controller,
                                            self.static_profile, ctx)
        comps, _, t_wall = compress_kvs(profile.strategy, [kv])
        t_compress = codec_cost(self.cfg, t_wall, kv.nbytes_wire(),
                                profile.s_enc)
        return comps[0], ctx, decision, profile, t_compress


# ---------------------------------------------------------------------------
# Decode worker
# ---------------------------------------------------------------------------
class DecodeWorker:
    """One decode engine of the cluster: a fixed-capacity slot arena (ONE
    cache pytree, leading axis ``n_slots``), a LIFO local slot-id pool,
    and the worker's decode-side KV tier hierarchy."""

    def __init__(self, wid: int, model: ModelHandle, cfg: RuntimeConfig,
                 n_slots: int, store: Any):
        self.wid = wid
        self.name = f"d{wid}"
        self.model = model
        self.cfg = cfg
        self.n_slots = n_slots
        self.store = store
        self.max_len = cfg.arena_max_len
        self.slots: Dict[int, Slot] = {}
        # LIFO so a hot slot's cache row is reused first (same recycling
        # discipline the scheduler used when it owned the slot ids).
        self.free_slots: List[int] = list(range(n_slots))[::-1]
        self._dec_arena = None
        self._arena: Any = None          # cache pytree, leading axis n_slots
        self._positions = np.zeros(n_slots, np.int32)  # next write pos
        self._last_tok = np.zeros(n_slots, np.int32)   # last emitted tok
        self.decode_steps = 0            # lifetime arena decode calls
        # Paged-arena state (cfg.paged; DESIGN.md §12).  The fp pool
        # replaces the dense arena in self._arena; the parallel quant
        # pools hold packed pages for fused-dequant decode, valid per
        # slot below its _quant_len watermark.
        self.page_table: Optional[PageTable] = None
        self._qcodes: Any = None
        self._qscales: Any = None
        self._quant_len = np.zeros(n_slots, np.int32)
        # Speculative decode state (DESIGN.md §15): the draft proposer
        # (built lazily when a speculative slot first lands) and the
        # worker-lifetime accept tallies behind the benchmark's
        # tokens-per-step metric.
        self._draft: Any = None
        self._verify_fns: Dict[int, Any] = {}   # width -> jitted verify
        self.verify_steps = 0
        self.spec_committed = 0

    @property
    def _pps(self) -> int:
        """Block-table row length: pages per worst-case slot."""
        return -(-self.max_len // self.cfg.page_size)

    # ------------------------------------------------------------------
    @property
    def free_slot_count(self) -> int:
        return len(self.free_slots)

    @property
    def occupancy(self) -> int:
        return len(self.slots)

    # ------------------------------------------------------------------
    def ensure_arena(self):
        if self._arena is None:
            from repro.models.transformer import init_cache, plan_stack
            plan = plan_stack(self.model.cfg)
            if any(s.kind != "attn"
                   for s in plan.prefix_specs + plan.period_specs):
                raise NotImplementedError(
                    "slot arena masking assumes attention-only caches "
                    "(SSM states advance unmasked)")
            if self.cfg.paged:
                num_pages = (self.cfg.arena_pages
                             or self.n_slots * self._pps + 1)
                self.page_table = PageTable(num_pages, self.cfg.page_size)
                # Per-channel scale layout in the sim pools (group=1):
                # any strategy group maps onto it by broadcasting its
                # group scale, so one pool serves every eligible profile.
                self._arena, self._qcodes, self._qscales = init_paged_pools(
                    self.model.cfg, num_pages, self.cfg.page_size, group=1)
            else:
                self._arena = init_cache(self.model.cfg, self.n_slots,
                                         self.max_len)
        return self._arena

    def _arena_fn(self):
        if self._dec_arena is None:
            if self.cfg.paged:
                self._dec_arena, _ = _paged_steps(self.model.cfg.name,
                                                  self.cfg.page_size)
            else:
                _, _, self._dec_arena = _jitted_steps(
                    self.model.cfg.name, self.cfg.seq, self.n_slots,
                    self.max_len)
        return self._dec_arena

    # ------------------------------------------------------------------
    def _block_tables(self) -> np.ndarray:
        bt = np.zeros((self.n_slots, self._pps), np.int32)
        for s, owned in self.page_table.pages.items():
            bt[s, :len(owned)] = owned
        return bt

    def copy_from_caches(self, caches, idx: int) -> None:
        """Materialize arena row ``idx`` from a prefill worker's batch-1
        cache (the cold path's slot hand-off)."""
        self.ensure_arena()
        if self.cfg.paged:
            self.page_table.ensure(idx, self.cfg.seq)
            row = self.page_table.block_row(idx, self._pps)
            self._arena = copy_cache_slot_paged(
                self.model.cfg, self._arena, caches, row,
                self.cfg.page_size)
            self._quant_len[idx] = 0
            return
        self._arena = copy_cache_slot(self.model.cfg, self._arena,
                                      caches, idx)

    def inject_restored(self, kv, idx: int) -> None:
        """Materialize arena row ``idx`` from a wire-restored KV."""
        self.ensure_arena()
        if self.cfg.paged:
            self.page_table.ensure(idx, kv.seq)
            row = self.page_table.block_row(idx, self._pps)
            self._arena = inject_kv_paged(self.model.cfg, self._arena,
                                          row, kv, self.cfg.page_size)
            self._quant_len[idx] = 0
            return
        self._arena = inject_kv(self.model.cfg, self._arena, idx, kv)

    def fetch_entry(self, entry, idx: int) -> Tuple[int, float]:
        """Land a stored pool entry in arena slot ``idx``.  Returns
        ``(first_token, t_decompress)``.

        Paged arena + paged-eligible stored strategy: the packed codes
        and fp16 group scales scatter STRAIGHT into the quantized page
        pools — no fp16 materialization, so the decompress stage leaves
        the TTFT critical path (the fused dequant runs inside decode
        attention; under the virtual clock the remaining adapter cost
        models as V/inf = 0).  Everything else decompresses and injects
        fp16 pages/rows as before.  Cache injection is host-side
        bookkeeping of the miniature (the cold path's equivalent writes
        happen inside prefill), so it is not billed to the virtual
        clock."""
        comp, first, s_dec = entry.payload
        if (self.cfg.paged and isinstance(comp, CompressedKV)
                and paged_eligible(comp.strategy, head_dim=comp.shape[3])):
            t0 = time.perf_counter()
            (kc, ks), (vc, vs) = quant_entry_arrays(comp)
            self.ensure_arena()
            seq = comp.shape[2]
            self.page_table.ensure(idx, seq)
            row = self.page_table.block_row(idx, self._pps)
            self._qcodes, self._qscales = inject_quant_pages(
                self.model.cfg, self._qcodes, self._qscales, row,
                kc, ks, vc, vs, seq, self.cfg.page_size)
            self._quant_len[idx] = seq
            t_wall = time.perf_counter() - t0
            return int(first), codec_cost(self.cfg, t_wall,
                                          entry.kv_bytes, float("inf"))
        restored, t_wall = decompress_kvs([comp])
        t_decompress = codec_cost(self.cfg, t_wall, entry.kv_bytes, s_dec)
        self.inject_restored(restored[0], idx)
        return int(first), t_decompress

    # ------------------------------------------------------------------
    def draft(self):
        """The worker's draft proposer (cfg.spec_kind), built lazily."""
        if self._draft is None:
            from repro.serving.speculative import ModelDraft, NGramDraft
            if self.cfg.spec_kind == "model":
                self._draft = ModelDraft(self.model, self.cfg.seq,
                                         self.n_slots, self.max_len)
            else:
                self._draft = NGramDraft()
        return self._draft

    def _verify_fn(self, width: int):
        """Jitted multi-token verify for ``width`` (one compile per
        speculation width; the per-slot accept length stays traced)."""
        fn = self._verify_fns.get(width)
        if fn is None:
            from repro.core.quality import _paged_verify_steps, _verify_steps
            if self.cfg.paged:
                fn = _paged_verify_steps(self.model.cfg.name,
                                         self.cfg.page_size, width)
            else:
                fn = _verify_steps(self.model.cfg.name, self.max_len, width)
            self._verify_fns[width] = fn
        return fn

    def occupy(self, slot: Slot, first: int,
               prompt: Optional[Sequence[int]] = None) -> None:
        self.slots[slot.req.rid] = slot
        self._positions[slot.idx] = self.cfg.seq
        self._last_tok[slot.idx] = first
        if slot.spec_k > 0 and prompt is not None:
            self.draft().start(slot.idx, slot.req.rid, prompt, first)

    def release(self, slot: Slot) -> None:
        self.free_slots.append(slot.idx)
        del self.slots[slot.req.rid]
        if self.cfg.paged and self.page_table is not None:
            self.page_table.release(slot.idx)
            self._quant_len[slot.idx] = 0
        if slot.spec_k > 0 and self._draft is not None:
            self._draft.stop(slot.idx, slot.req.rid)

    # ------------------------------------------------------------------
    def decode_iteration(self, active: List[Slot]) -> float:
        """Advance every slot in ``active`` with a SINGLE masked jitted
        arena call.  Without speculation (or when no slot has a draft this
        round) that is the historical one-token decode, bit-identical to
        pre-speculative builds.  With drafts it is ONE multi-token verify
        step: each slot commits the longest draft prefix the target would
        have emitted plus the bonus token (DESIGN.md §15) — token-exact
        with sequential decode, 1..width tokens per slot per iteration.
        Returns the measured wall seconds."""
        proposals: Dict[int, List[int]] = {}
        if self.cfg.spec_k > 0:
            spec = [s for s in active if s.spec_k > 0]
            if spec:
                items = [(s.idx, s.req.rid, int(self._last_tok[s.idx]),
                          int(self._positions[s.idx])) for s in spec]
                budgets = {s.idx: s.spec_k for s in spec}
                proposals = {i: d for i, d in
                             self.draft().propose_all(items, budgets).items()
                             if d}
        if proposals:
            return self._verify_iteration(active, proposals)
        mask = np.zeros(self.n_slots, bool)
        for slot in active:
            mask[slot.idx] = True
        dec = self._arena_fn()
        self.ensure_arena()
        if self.cfg.paged:
            # Grow each live slot to cover this step's write position —
            # the on-demand allocation that replaces worst-case sizing.
            for slot in active:
                self.page_table.ensure(slot.idx,
                                       int(self._positions[slot.idx]) + 1)
            t0 = time.perf_counter()
            nxt, self._arena = dec(
                self.model.params, self._arena, self._qcodes,
                self._qscales, jnp.asarray(self._block_tables()),
                jnp.asarray(self._quant_len),
                jnp.asarray(self._last_tok[:, None]),
                jnp.asarray(self._positions), jnp.asarray(mask))
        else:
            t0 = time.perf_counter()
            nxt, self._arena = dec(
                self.model.params, self._arena,
                jnp.asarray(self._last_tok[:, None]),
                jnp.asarray(self._positions), jnp.asarray(mask))
        # lint: sync-ok(the step's single sanctioned sync - one batched pull)
        nxt = np.asarray(nxt)
        wall = time.perf_counter() - t0
        for slot in active:
            t = int(nxt[slot.idx])
            slot.toks.append(t)
            self._last_tok[slot.idx] = t
            self._positions[slot.idx] += 1
            if slot.spec_k > 0 and self._draft is not None:
                self._draft.commit(slot.idx, slot.req.rid, [t])
        self.decode_steps += 1
        return wall

    def _verify_iteration(self, active: List[Slot],
                          proposals: Dict[int, List[int]]) -> float:
        """One masked multi-token verify step over the arena.  Every
        active slot rides along at its own draft length (no drafts = a
        plain one-token step inside the wide call); rejected draft
        positions never advance a slot and — paged — their over-ensured
        tail pages are rolled back before the pages can leak."""
        from repro.serving.speculative import accept_length
        width = max(len(d) for d in proposals.values()) + 1
        mask = np.zeros(self.n_slots, bool)
        toks = np.zeros((self.n_slots, width), np.int32)
        for slot in active:
            mask[slot.idx] = True
            toks[slot.idx, 0] = self._last_tok[slot.idx]
            for j, d in enumerate(proposals.get(slot.idx, [])):
                toks[slot.idx, 1 + j] = d
        fn = self._verify_fn(width)
        self.ensure_arena()
        if self.cfg.paged:
            # Ensure through the worst-case commit (all drafts accepted);
            # the rejected tail is released again right after the verify.
            for slot in active:
                need = (int(self._positions[slot.idx]) + 1
                        + len(proposals.get(slot.idx, [])))
                self.page_table.ensure(slot.idx, need)
            t0 = time.perf_counter()
            out, self._arena = fn(
                self.model.params, self._arena, self._qcodes,
                self._qscales, jnp.asarray(self._block_tables()),
                jnp.asarray(self._quant_len), jnp.asarray(toks),
                jnp.asarray(self._positions), jnp.asarray(mask))
        else:
            t0 = time.perf_counter()
            out, self._arena = fn(
                self.model.params, self._arena, jnp.asarray(toks),
                jnp.asarray(self._positions), jnp.asarray(mask))
        # lint: sync-ok(the step's single sanctioned sync - one batched pull)
        out = np.asarray(out)
        wall = time.perf_counter() - t0
        for slot in active:
            drafts = proposals.get(slot.idx, [])
            row = out[slot.idx]
            a = accept_length(drafts, row)
            needed = slot.req.out_tokens + 1 - len(slot.toks)
            c = min(a + 1, max(needed, 1))
            committed = [int(row[j]) for j in range(c)]
            slot.toks.extend(committed)
            self._last_tok[slot.idx] = committed[-1]
            self._positions[slot.idx] += c
            slot.verify_steps += 1
            slot.spec_committed += c
            slot.drafts_offered += len(drafts)
            slot.drafts_accepted += min(a, c - 1)
            self.spec_committed += c
            if slot.spec_k > 0 and self._draft is not None:
                self._draft.commit(slot.idx, slot.req.rid, committed)
            if self.cfg.paged and drafts:
                self.page_table.release_tail(
                    slot.idx, int(self._positions[slot.idx]))
        self.decode_steps += 1
        self.verify_steps += 1
        return wall
