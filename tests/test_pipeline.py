"""End-to-end pipeline: real byte roundtrips, CR accounting, baselines."""
import numpy as np
import pytest

from repro.core import (
    BASELINES,
    CompressionPipeline,
    IDENTITY_STRATEGY,
    KVCache,
    StrategyConfig,
    measure_profile,
)


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_roundtrip(kv_sample, name):
    pipe = CompressionPipeline(BASELINES[name])
    restored, comp, t_enc, t_dec = pipe.roundtrip(kv_sample)
    assert restored.shape == kv_sample.shape
    assert comp.compression_ratio() > 1.0
    assert np.isfinite(restored.k).all() and np.isfinite(restored.v).all()
    assert t_enc > 0 and t_dec > 0


def test_identity_near_exact(kv_sample):
    pipe = CompressionPipeline(IDENTITY_STRATEGY)
    restored, comp, _, _ = pipe.roundtrip(kv_sample)
    # identity ships logical bf16 -> fp16 wire; error is rounding only
    assert np.abs(restored.k - kv_sample.k).max() < 0.05
    assert abs(comp.compression_ratio() - 1.0) < 0.05


def test_kivi_metadata_ceiling(kv_sample):
    """KIVI 2-bit g=32: payload 2b + (16+16)/32 metadata = 3 bits/elem ->
    CR ceiling ~5.33x (paper Sec. 7.3)."""
    comp = CompressionPipeline(BASELINES["kivi"]).compress(kv_sample)
    assert 5.0 < comp.compression_ratio() < 5.4


def test_cr_increases_with_fewer_bits(kv_sample):
    crs = []
    for bits in (8, 4, 2):
        cfg = StrategyConfig(quantizer="uniform", key_bits=bits,
                             value_bits=bits, granularity="per_head")
        comp = CompressionPipeline(cfg).compress(kv_sample)
        crs.append(comp.compression_ratio())
    assert crs[0] < crs[1] < crs[2]


def test_codec_stacking_improves_cr():
    # smooth token stream -> delta+zstd should beat plain bitpack
    t = np.linspace(0, 6, 256, dtype=np.float32)
    base = np.sin(t)[None, None, :, None]
    kv = KVCache(
        np.broadcast_to(base, (3, 2, 256, 32)).copy() +
        0.01 * np.random.default_rng(0).standard_normal((3, 2, 256, 32)).astype(np.float32),
        np.broadcast_to(base, (3, 2, 256, 32)).copy())
    plain = CompressionPipeline(StrategyConfig(
        quantizer="uniform", key_bits=4, value_bits=4, codec="none"))
    coded = CompressionPipeline(StrategyConfig(
        transform="delta", quantizer="uniform", key_bits=4, value_bits=4,
        codec="bitshuffle_zstd3"))
    assert coded.compress(kv).total_bytes() < plain.compress(kv).total_bytes()


def test_cross_method_recomposition(kv_sample):
    """The paper's point: arbitrary T x Q x C combinations compose."""
    cfg = StrategyConfig(transform="hadamard", quantizer="cachegen",
                         tier_bits=(6, 4, 2), codec="zstd3")
    restored, comp, _, _ = CompressionPipeline(cfg).roundtrip(kv_sample)
    assert comp.compression_ratio() > 3.0
    assert np.isfinite(restored.k).all()


def test_hadamard_helps_outlier_channels():
    rng = np.random.default_rng(3)
    k = rng.standard_normal((4, 4, 128, 64)).astype(np.float32)
    k[..., 5] *= 30.0  # outlier channel
    kv = KVCache(k, rng.standard_normal(k.shape).astype(np.float32))
    def mse(cfg):
        r, _, _, _ = CompressionPipeline(cfg).roundtrip(kv)
        return float(((r.k - kv.k) ** 2).mean())
    plain = mse(StrategyConfig(quantizer="uniform", key_bits=3,
                               value_bits=3, granularity="per_token",
                               group_size=64))
    rotated = mse(StrategyConfig(transform="hadamard", quantizer="uniform",
                                 key_bits=3, value_bits=3,
                                 granularity="per_token", group_size=64))
    assert rotated < plain


def test_measure_profile(kv_sample):
    p = measure_profile(BASELINES["kivi"], [kv_sample])
    assert p.cr > 4 and p.s_enc > 0 and p.s_dec > 0 and p.mse > 0
    assert p.s_eff < min(p.s_enc, p.s_dec)
    # json roundtrip
    from repro.core.profiles import Profile
    p2 = Profile.from_json(p.to_json())
    assert p2.strategy == p.strategy and abs(p2.cr - p.cr) < 1e-9
