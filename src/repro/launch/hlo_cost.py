"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (verified empirically), which would corrupt every roofline term
for scan-over-layers models.  This module parses the post-SPMD HLO text,
walks computations recursively, and multiplies while-loop bodies by their
trip counts:

  flops      — dot (2*result*K), convolution (2*out*kernel*in/group), plus
               1/elem for transcendental elementwise ops
  bytes      — operand + result bytes at fusion granularity (XLA-style)
  collective — operand bytes of all-gather / all-reduce / reduce-scatter /
               all-to-all / collective-permute, × enclosing trips

Validated against cost_analysis on loop-free programs and against
trip×body on scans (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "token": 0,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# elementwise ops that plausibly cost ~1 flop per output element
_EW_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "compare",
    "select", "and", "or", "convert", "exponential-minus-one",
}


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jaxlib versions:
    older jaxlibs return ``[dict]``, newer ones return the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> float:
    total = 0.0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(shapes) -> float:
    total = 0
    for _, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    op: str
    result_text: str
    rhs: str
    operands: List[str]

    def result_shapes(self):
        return _shape_list(self.result_text)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_COMP_HEAD = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_INSTR = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+([a-z][a-z0-9\-]*)\((.*)$")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        # Instruction lines always contain " = " (spaces); computation
        # headers never do (but may contain "=" inside /*index=k*/ comments).
        if " = " not in line:
            mh = _COMP_HEAD.match(line)
            if mh:
                cur = Computation(mh.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry_name = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, result_text, op, rest = mi.groups()
        # operand names: inside the first balanced paren group
        depth, end = 1, None
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = rest[:end] if end is not None else rest
        attrs = rest[end + 1:] if end is not None else ""
        # Operand entries are comma-separated and may be typed
        # ("f32[256,256]{1,0} %Arg_0.1" on newer jaxlibs) or bare ("%a");
        # strip bracket/brace groups, then the name is the entry's last token.
        operands = []
        if args.strip():
            clean = re.sub(r"\[[^\]]*\]|\{[^}]*\}", "", args)
            for entry in clean.split(","):
                toks = entry.split()
                if toks:
                    operands.append(toks[-1].lstrip("%"))
        operands = [o for o in operands if o and not o[0].isdigit()]
        instr = Instr(name=name, op=op, result_text=result_text,
                      rhs=args + "|" + attrs, operands=operands)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _attr(rhs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rhs)
    return m.group(1) if m else None


def _attr_braces(rhs: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([0-9, ]*)\}", rhs)
    if not m:
        return []
    body = m.group(1).strip()
    return [int(x) for x in body.split(",")] if body else []


def trip_count(cond: Computation) -> int:
    """Loop bound: the max integer constant in the condition computation."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rhs)
            if not m:
                m = re.search(r"(-?\d+)", ins.rhs)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_breakdown.items():
            self.coll_breakdown[k] = self.coll_breakdown.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_breakdown.items()})


def _operand_shapes(comp: Computation, ins: Instr):
    shapes = []
    for o in ins.operands:
        src = comp.by_name.get(o)
        if src is not None:
            shapes.extend(src.result_shapes())
    return shapes


_SLICE_OPS = ("dynamic-slice", "gather", "dynamic-update-slice")


def _fusion_root_is_dus(callee: Computation) -> bool:
    """True when the fusion computes an in-place slice update (possibly via
    a bitcast/copy root): its result tensor is the full aliased buffer, but
    the actual traffic is the updated region only."""
    roots = [i for i in callee.instrs if i.name and i is callee.instrs[-1]]
    # walk back through bitcast/copy chains from the last instruction
    cur = callee.instrs[-1] if callee.instrs else None
    seen = 0
    while cur is not None and seen < 4:
        if cur.op == "dynamic-update-slice":
            return True
        if cur.op in ("bitcast", "copy", "convert") and cur.operands:
            cur = callee.by_name.get(cur.operands[0])
            seen += 1
            continue
        return False
    return False


def _fusion_operand_bytes(callee: Computation) -> float:
    """Memory traffic of a fusion's inputs, counting parameters that are only
    sliced inside (stacked scan weights / KV buffers) at slice size — the
    HloCostAnalysis convention — instead of full buffer size."""
    total = 0.0
    for p in callee.instrs:
        if p.op != "parameter":
            continue
        uses = [u for u in callee.instrs if p.name in u.operands]
        if uses and all(u.op in _SLICE_OPS for u in uses):
            for u in uses:
                if u.op == "dynamic-update-slice":
                    # read+write of the updated region only
                    upd = callee.by_name.get(u.operands[1]) if len(u.operands) > 1 else None
                    if upd is not None and p.name == u.operands[0]:
                        total += 2 * _nbytes(upd.result_shapes())
                    else:
                        total += _nbytes(p.result_shapes()) if upd is None else _nbytes(upd.result_shapes())
                else:
                    total += 2 * _nbytes(u.result_shapes())
        else:
            total += _nbytes(p.result_shapes())
    return total


def _dot_flops(comp: Computation, ins: Instr) -> float:
    res = ins.result_shapes()
    if not res:
        return 0.0
    out_elems = _nelems(res)
    lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
    if lhs is None:
        return 2.0 * out_elems  # unknown K
    lshapes = lhs.result_shapes()
    if not lshapes:
        return 2.0 * out_elems
    ldims = lshapes[0][1]
    cdims = _attr_braces(ins.rhs, "lhs_contracting_dims")
    k = 1
    for c in cdims:
        if c < len(ldims):
            k *= ldims[c]
    return 2.0 * out_elems * max(k, 1)


def _conv_flops(comp: Computation, ins: Instr) -> float:
    res = ins.result_shapes()
    out_elems = _nelems(res)
    if len(ins.operands) < 2:
        return 2.0 * out_elems
    ker = comp.by_name.get(ins.operands[1])
    kshapes = ker.result_shapes() if ker else []
    kelems = _nelems(kshapes) if kshapes else 1
    # flops ≈ 2 * out_elems * (kernel_elems / out_features); feature_group
    # handling is safely approximated for depthwise (kernel IO=1).
    m = re.search(r"feature_group_count=(\d+)", ins.rhs)
    groups = int(m.group(1)) if m else 1
    if groups > 1:
        # depthwise-style: each output element sees kernel_elems/groups taps
        # (layout-independent — XLA may transpose the kernel operand)
        return 2.0 * out_elems * kelems / groups
    if kshapes:
        kdims = kshapes[0][1]
        out_feat = max(kdims[0], 1) if kdims else 1
        per_out = kelems / max(out_feat, 1)
        return 2.0 * out_elems * per_out
    return 2.0 * out_elems


def computation_cost(comps: Dict[str, Computation], name: str,
                     memo: Dict[str, Cost], fusion: bool = False) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps[name]
    total = Cost()
    for ins in comp.instrs:
        op = ins.op
        if op == "while":
            body = _attr(ins.rhs, "body")
            cond = _attr(ins.rhs, "condition")
            mt = _TRIP_RE.search(ins.rhs)
            if mt:
                trips = int(mt.group(1))
            else:
                trips = trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                total += computation_cost(comps, body, memo).scaled(max(trips, 1))
        elif op == "fusion":
            callee = _attr(ins.rhs, "calls")
            if callee in comps:
                sub = computation_cost(comps, callee, memo, fusion=True)
                total.flops += sub.flops
                total.coll_bytes += sub.coll_bytes
                for k, v in sub.coll_breakdown.items():
                    total.coll_breakdown[k] = total.coll_breakdown.get(k, 0) + v
                # bytes at fusion granularity (slice-aware for stacked bufs)
                total.bytes += _fusion_operand_bytes(comps[callee])
                # in-place DUS fusions: result aliases the input buffer —
                # update-region traffic is already counted on the param side
                if not _fusion_root_is_dus(comps[callee]):
                    total.bytes += _nbytes(ins.result_shapes())
            else:
                total.bytes += _nbytes(_operand_shapes(comp, ins))
                total.bytes += _nbytes(ins.result_shapes())
        elif op in ("call", "conditional"):
            callee = _attr(ins.rhs, "to_apply") or _attr(ins.rhs, "branch_computations")
            if callee in comps:
                total += computation_cost(comps, callee, memo)
        elif op == "dot":
            total.flops += _dot_flops(comp, ins)
            total.bytes += _nbytes(_operand_shapes(comp, ins))
            total.bytes += _nbytes(ins.result_shapes())
        elif op == "convolution":
            total.flops += _conv_flops(comp, ins)
            total.bytes += _nbytes(_operand_shapes(comp, ins))
            total.bytes += _nbytes(ins.result_shapes())
        elif any(op == k or op.startswith(k + "-start") or op.startswith(k + ".")
                 for k in _COLLECTIVES):
            kind = next(k for k in _COLLECTIVES
                        if op == k or op.startswith(k + "-start") or op.startswith(k + "."))
            b = _nbytes(_operand_shapes(comp, ins)) or _nbytes(ins.result_shapes())
            total.coll_bytes += b
            total.coll_breakdown[kind] = total.coll_breakdown.get(kind, 0.0) + b
            total.bytes += b + _nbytes(ins.result_shapes())
        elif op in ("parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all", "partition-id", "replica-id"):
            continue
        elif op == "dynamic-slice":
            if not fusion:
                total.bytes += 2 * _nbytes(ins.result_shapes())
        elif op == "dynamic-update-slice":
            if not fusion:
                upd = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
                total.bytes += 2 * _nbytes(upd.result_shapes() if upd else ins.result_shapes())
        else:
            # standalone elementwise / reduce / copy etc.
            if not fusion:
                total.bytes += _nbytes(_operand_shapes(comp, ins))
                total.bytes += _nbytes(ins.result_shapes())
            if op in _EW_FLOP_OPS or op in ("reduce", "scatter", "gather"):
                total.flops += _nelems(ins.result_shapes())
    memo[name] = total
    return total


def analyze_hlo_text(text: str) -> Cost:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    memo: Dict[str, Cost] = {}
    entry = comps["__entry__"].name
    return computation_cost(comps, entry, memo)
