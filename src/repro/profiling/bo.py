"""Algorithm 1: Constraint-Aware Bayesian Optimization with Gaussian
Processes (Sec. 5.2).

    max_c CR(c)   s.t.  Acc(c) >= Acc_threshold

over the heterogeneous strategy space, with the paper's four engine
optimizations: heterogeneous-parameter encoding, decaying
exploration-exploitation weight λ_t, bi-directional pruning on the monotone
CR–Acc trade-off, and early stopping.  ``evaluate_fn`` runs the expensive
end-to-end profiling (sampled-subset accuracy + measured CR); the engine
minimises how often it is called.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.strategy import StrategyConfig, estimate_cr
from repro.profiling.encoding import encode_batch
from repro.profiling.gp import GaussianProcess


@dataclass
class BOConfig:
    acc_threshold: float = 0.97      # relative accuracy constraint
    prune_eps: float = 0.25          # ε pruning buffer (in CR units)
    max_iters: int = 300
    max_consecutive_failures: int = 25
    lambda0: float = 1.0             # initial exploration weight
    lambda_decay: float = 0.97       # λ_t = λ0 * decay^t
    hard_fail_margin: float = 0.10   # "Acc << thres" margin for pruning
    seed: int = 0
    # ablations (Sec. 7.4)
    use_encoding: bool = True
    use_exploration: bool = True
    use_pruning: bool = True
    use_early_stop: bool = True


@dataclass
class Observation:
    cfg: StrategyConfig
    acc: float
    cr: float
    feasible: bool


@dataclass
class BOResult:
    feasible: List[Observation]
    history: List[Observation]
    iterations: int
    best: Optional[Observation]
    evaluations: int

    def best_cr(self) -> float:
        return self.best.cr if self.best else 0.0


def run_bo(
    space: Sequence[StrategyConfig],
    evaluate_fn: Callable[[StrategyConfig], Tuple[float, float]],
    config: BOConfig = BOConfig(),
) -> BOResult:
    """evaluate_fn(cfg) -> (acc, cr): the expensive end-to-end profiling."""
    rng = np.random.default_rng(config.seed)
    space = list(space)
    n = len(space)

    if config.use_encoding:
        emb = encode_batch(space)
    else:
        # ablation: naive integer indexing (no structural similarity)
        emb = np.arange(n, dtype=np.float64)[:, None] / max(n - 1, 1)

    est_cr = np.asarray([estimate_cr(c) for c in space])
    est_cr_norm = est_cr / max(est_cr.max(), 1e-9)

    alive = np.ones(n, dtype=bool)
    evaluated = np.zeros(n, dtype=bool)

    gp = GaussianProcess(length_scale=math.sqrt(emb.shape[1]) * 0.5)
    xs: List[np.ndarray] = []
    ys: List[float] = []

    history: List[Observation] = []
    feasible: List[Observation] = []
    k_fail = 0
    it = 0

    for it in range(1, config.max_iters + 1):
        cand_idx = np.nonzero(alive & ~evaluated)[0]
        if len(cand_idx) == 0:
            break

        lam = config.lambda0 * (config.lambda_decay ** it) \
            if config.use_exploration else 0.0

        if xs:
            gp.fit(np.stack(xs), np.asarray(ys))
            p_feas = gp.prob_greater(emb[cand_idx], config.acc_threshold)
            _, std = gp.predict(emb[cand_idx])
            std_norm = std / max(std.max(), 1e-9)
        else:
            p_feas = np.full(len(cand_idx), 0.5)
            std_norm = np.ones(len(cand_idx))

        # Acquisition (Eq. 4): exploitation = CR * P(feasible); exploration
        # = λ_t * normalized posterior std.
        af = est_cr_norm[cand_idx] * p_feas + lam * std_norm
        pick = cand_idx[int(np.argmax(af + rng.normal(0, 1e-9, len(af))))]

        acc, cr = evaluate_fn(space[pick])
        evaluated[pick] = True
        obs = Observation(space[pick], acc, cr, acc >= config.acc_threshold)
        history.append(obs)
        xs.append(emb[pick])
        ys.append(acc)

        if obs.feasible:
            feasible.append(obs)
            k_fail = 0
            if config.use_pruning:
                # discard lower-CR candidates: they cannot beat this one
                alive &= ~((est_cr < cr - config.prune_eps) & ~evaluated)
        else:
            k_fail += 1
            if config.use_pruning and \
                    acc < config.acc_threshold - config.hard_fail_margin:
                # Acc << thres: higher-CR candidates are hopeless too
                alive &= ~((est_cr > cr + config.prune_eps) & ~evaluated)

        if config.use_early_stop:
            if k_fail >= config.max_consecutive_failures:
                break
            if not (alive & ~evaluated).any():
                break

    best = max(feasible, key=lambda o: o.cr) if feasible else None
    return BOResult(feasible=feasible, history=history, iterations=it,
                    best=best, evaluations=len(history))


def run_random_search(
    space: Sequence[StrategyConfig],
    evaluate_fn: Callable[[StrategyConfig], Tuple[float, float]],
    config: BOConfig = BOConfig(),
) -> BOResult:
    """Baseline for the ablation: uniform random sampling, same budget."""
    rng = np.random.default_rng(config.seed)
    order = rng.permutation(len(space))[: config.max_iters]
    history, feasible = [], []
    for i, idx in enumerate(order, start=1):
        acc, cr = evaluate_fn(space[idx])
        obs = Observation(space[idx], acc, cr, acc >= config.acc_threshold)
        history.append(obs)
        if obs.feasible:
            feasible.append(obs)
    best = max(feasible, key=lambda o: o.cr) if feasible else None
    return BOResult(feasible=feasible, history=history,
                    iterations=len(history), best=best,
                    evaluations=len(history))
