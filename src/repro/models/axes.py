"""Logical-axis annotated parameters (MaxText-style).

``Pm(value, axes)`` tags every parameter leaf with logical axis names; the
distribution layer maps logical axes to mesh axes with divisibility fallback
(see ``repro.distribution.sharding``).  ``split_tree`` separates values from
axis specs after init.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Pm:
    """A param leaf paired with its logical axes (one name per dim)."""

    value: Any  # jnp array or jax.ShapeDtypeStruct
    axes: Tuple[Optional[str], ...]

    def __post_init__(self):
        assert len(self.axes) == len(self.value.shape), (self.axes, self.value.shape)


def is_pm(x) -> bool:
    return isinstance(x, Pm)


def split_tree(tree):
    """-> (values_tree, axes_tree)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_pm)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_pm)
    return values, axes


class Initializer:
    """Creates parameter leaves; abstract mode emits ShapeDtypeStructs only
    (used by the dry-run so no host memory is ever allocated)."""

    def __init__(self, seed: int = 0, abstract: bool = False,
                 dtype=jnp.float32):
        self.abstract = abstract
        self.dtype = dtype
        self._rng = np.random.default_rng(seed)

    def normal(self, shape, axes, scale: float = 0.02) -> Pm:
        if self.abstract:
            return Pm(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        arr = (self._rng.standard_normal(shape) * scale).astype(np.float32)
        return Pm(jnp.asarray(arr, dtype=self.dtype), tuple(axes))

    def zeros(self, shape, axes) -> Pm:
        if self.abstract:
            return Pm(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        return Pm(jnp.zeros(shape, dtype=self.dtype), tuple(axes))

    def ones(self, shape, axes) -> Pm:
        if self.abstract:
            return Pm(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        return Pm(jnp.ones(shape, dtype=self.dtype), tuple(axes))

    def constant(self, value: np.ndarray, axes) -> Pm:
        if self.abstract:
            return Pm(jax.ShapeDtypeStruct(tuple(value.shape), self.dtype), tuple(axes))
        return Pm(jnp.asarray(value, dtype=self.dtype), tuple(axes))


def stack_block_params(block_list):
    """Stack per-block param trees along a new leading 'layers' axis."""
    def _stack(*leaves):
        vals = [l.value for l in leaves]
        axes = ("layers",) + leaves[0].axes
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            shape = (len(vals),) + tuple(vals[0].shape)
            return Pm(jax.ShapeDtypeStruct(shape, vals[0].dtype), axes)
        return Pm(jnp.stack(vals), axes)

    return jax.tree_util.tree_map(_stack, *block_list, is_leaf=is_pm)


def abstract_like_block(block, n: int):
    """Add a leading 'layers' dim of size n to an abstract block tree."""
    def _lift(p: Pm) -> Pm:
        shape = (n,) + tuple(p.value.shape)
        return Pm(jax.ShapeDtypeStruct(shape, p.value.dtype), ("layers",) + p.axes)

    return jax.tree_util.tree_map(_lift, block, is_leaf=is_pm)
