"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig13,...]``
prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


SUITES = [
    ("fig5_strategy_space", "benchmarks.strategy_space"),
    ("fig4_kv_latency_thresholds", "benchmarks.kv_latency_thresholds"),
    ("fig8_profiling_stability", "benchmarks.profiling_stability"),
    ("fig9_16l_bo_convergence", "benchmarks.bo_convergence"),
    ("fig10_pareto_frontier", "benchmarks.pareto_frontier"),
    ("tab1_acc_cr", "benchmarks.acc_cr_table"),
    ("fig13_jct_vs_bandwidth", "benchmarks.jct_vs_bandwidth"),
    ("fig14_ttft_prefix_caching", "benchmarks.ttft_prefix_caching"),
    ("fig15_latency_breakdown", "benchmarks.latency_breakdown"),
    ("fig16r_online_adaptivity", "benchmarks.online_adaptivity"),
    ("fig12_hardware_tiers", "benchmarks.hardware_tiers"),
    ("serving_continuous_batching", "benchmarks.continuous_batching"),
    ("serving_tiered_kv", "benchmarks.tiered_kv"),
    ("kernels", "benchmarks.kernel_throughput"),
    ("roofline", "benchmarks.roofline"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list of suite prefixes")
    args = ap.parse_args(argv)
    only = [s for s in args.only.split(",") if s]

    print("name,us_per_call,derived")
    failures = 0
    for name, module in SUITES:
        if only and not any(name.startswith(o) or o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"# suite {name} done in {time.time()-t0:.1f}s")
        except Exception as e:
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
