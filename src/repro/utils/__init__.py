from repro.utils.timing import Timer, timed
from repro.utils.trees import tree_bytes, tree_size

__all__ = ["Timer", "timed", "tree_bytes", "tree_size"]
