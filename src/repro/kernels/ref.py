"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

All kernels are validated against these in interpret mode across
shape/dtype sweeps (tests/test_kernels_*.py).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Group quantization (symmetric, per-group along the last axis)
# ---------------------------------------------------------------------------
def quantize_ref(x: jnp.ndarray, bits: int, group: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., D) -> (codes int8 (..., D), scales f32 (..., D/group))."""
    d = x.shape[-1]
    assert d % group == 0
    qmax = (1 << (bits - 1)) - 1
    xg = x.reshape(x.shape[:-1] + (d // group, group)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1)
    scale = jnp.maximum(amax / qmax, 1e-8)
    q = jnp.clip(jnp.round(xg / scale[..., None]), -qmax - 1, qmax)
    return q.reshape(x.shape).astype(jnp.int8), scale


def dequantize_ref(codes: jnp.ndarray, scale: jnp.ndarray, group: int,
                   dtype=jnp.float32) -> jnp.ndarray:
    d = codes.shape[-1]
    qg = codes.reshape(codes.shape[:-1] + (d // group, group)).astype(jnp.float32)
    x = qg * scale[..., None].astype(jnp.float32)
    return x.reshape(codes.shape).astype(dtype)


def pack_int4_ref(codes: jnp.ndarray) -> jnp.ndarray:
    """int8 codes in [-8,7] -> packed uint8 (last dim halved)."""
    u = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)
    return (u[..., 0::2] | (u[..., 1::2] << 4)).astype(jnp.uint8)


def unpack_int4_ref(packed: jnp.ndarray) -> jnp.ndarray:
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int32) - 8
    hi = (packed >> jnp.uint8(4)).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(packed.shape[:-1] + (packed.shape[-1] * 2,)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Hadamard transform (orthonormal; D power of two)
# ---------------------------------------------------------------------------
def hadamard_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    assert n & (n - 1) == 0
    h = jnp.array([[1.0]], dtype=jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return (h / math.sqrt(n)).astype(dtype)


def hadamard_ref(x: jnp.ndarray) -> jnp.ndarray:
    h = hadamard_matrix(x.shape[-1])
    return (x.astype(jnp.float32) @ h).astype(x.dtype)


# ---------------------------------------------------------------------------
# Quantized flash-decode attention
# ---------------------------------------------------------------------------
def decode_attention_ref(
    q: jnp.ndarray,        # (B, Hkv, Gq, D) f32/bf16 — query heads grouped per kv head
    k_codes: jnp.ndarray,  # (B, Hkv, S, D) int8
    k_scale: jnp.ndarray,  # (B, Hkv, S, D/group) f32
    v_codes: jnp.ndarray,  # (B, Hkv, S, D) int8
    v_scale: jnp.ndarray,  # (B, Hkv, S, D/group) f32
    group: int,
    kv_len: Optional[jnp.ndarray] = None,  # scalar, or (B,) per-slot lengths
) -> jnp.ndarray:
    """Attention of one new token against a quantized KV cache.  A (B,)
    ``kv_len`` masks each batch row at its own slot length (the ragged
    slot-arena decode)."""
    b, hkv, gq, d = q.shape
    s = k_codes.shape[2]
    k = dequantize_ref(k_codes, k_scale, group)  # (B,Hkv,S,D)
    v = dequantize_ref(v_codes, v_scale, group)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k)
    scores = scores / math.sqrt(d)
    if kv_len is not None:
        lens = jnp.atleast_1d(jnp.asarray(kv_len))          # (1,) or (B,)
        mask = jnp.arange(s)[None, :] < lens[:, None]       # (B|1, S)
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, v)
    return out.astype(q.dtype)
