"""Latency-distribution metrics shared by every serving backend.

Means hide exactly what SLO serving is about: the tail.  This module
computes the p50/p95/p99 TTFT and JCT quantiles plus per-SLO-class
violation rates from any population of finished requests — the
real-execution :class:`~repro.serving.engine.ServingRuntime`, the
multi-worker :class:`~repro.serving.cluster.ClusterRuntime`, and the
event-driven :class:`~repro.serving.simulator.Simulator` all feed their
completions through :func:`latency_summary` so their ``summary()``
outputs are directly comparable.

Requests are duck-typed: anything with ``ttft``, ``jct``, ``slo_class``,
``t_slo`` and ``slo_violated`` attributes works (both
:class:`~repro.serving.request.Request` and the runtime's
``ServedRequest`` qualify).
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

import numpy as np

PERCENTILES = (50, 95, 99)


def percentile_row(values: Sequence[float], prefix: str
                   ) -> Dict[str, float]:
    """``{prefix_p50: ..., prefix_p95: ..., prefix_p99: ...}`` (empty when
    there are no values — absent keys beat fabricated zeros)."""
    vals = np.asarray(list(values), dtype=float)
    if vals.size == 0:
        return {}
    return {f"{prefix}_p{p}": float(np.percentile(vals, p))
            for p in PERCENTILES}


def violation_rates(requests: Iterable) -> Dict[str, float]:
    """Per-SLO-class violation rates over requests that carry an SLO
    (``t_slo > 0``); ``slo_violation_rate`` is the all-class aggregate."""
    with_slo: Dict[str, list] = {}
    for r in requests:
        if getattr(r, "t_slo", 0.0) > 0:
            with_slo.setdefault(r.slo_class, []).append(bool(r.slo_violated))
    out: Dict[str, float] = {}
    all_flags = [f for flags in with_slo.values() for f in flags]
    if all_flags:
        out["slo_violation_rate"] = float(np.mean(all_flags))
    for cls, flags in sorted(with_slo.items()):
        out[f"slo_violation_rate_{cls}"] = float(np.mean(flags))
    return out


def route_counts(requests: Iterable) -> Dict[str, float]:
    """``{route_<name>_completed: n}`` over requests that carry a
    placement route — one shared implementation for the cluster runtime
    and the topology-driven simulator."""
    by_route: Dict[str, int] = {}
    for r in requests:
        route = getattr(r, "route", "")
        if route:
            by_route[route] = by_route.get(route, 0) + 1
    return {f"route_{name}_completed": float(n)
            for name, n in sorted(by_route.items())}


def latency_summary(requests: Sequence) -> Dict[str, float]:
    """The shared distribution block: TTFT/JCT p50/p95/p99 plus per-class
    violation rates."""
    out: Dict[str, float] = {}
    out.update(percentile_row([r.ttft for r in requests], "ttft"))
    out.update(percentile_row([r.jct for r in requests], "jct"))
    out.update(violation_rates(requests))
    return out
