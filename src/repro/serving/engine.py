"""Real-execution disaggregated serving runtime (CPU, tiny reference model).

A faithful miniature of the paper's vLLM integration, in two granularities:

* :class:`DisaggregatedEngine` — the one-shot PD path: ``serve`` runs a
  single synchronous batch end-to-end (prefill -> compress -> wire ->
  decompress -> decode) and reports a :class:`ServedBatch` breakdown.  It
  is a thin wrapper over the same stage helpers (:func:`compress_kvs`,
  :func:`decompress_kvs`, :class:`~repro.serving.network.KVWire`) the
  continuous runtime pipelines per request.

* :class:`ServingRuntime` — the continuous-batching, multi-tenant runtime
  (DESIGN.md §9): ``submit`` enqueues :class:`~repro.serving.request.Request`
  objects through the shared :class:`~repro.serving.scheduler.ContinuousScheduler`
  (admission control + SLO-class priorities), and each ``step()`` is one
  iteration of TWO overlapped streams joined by a compressed-KV wire:

  - the **prefill stream** admits up to ``max_prefills_per_step`` waiting
    requests and runs each one's start-of-life stages;
  - the **decode stream** advances every *previously running* slot one
    token with a SINGLE jitted batched decode over the fixed-capacity
    slot arena.

  The streams run on separate workers, so an iteration costs
  ``max(prefill stream, decode stream)`` and the difference is charged to
  each request as ``stall`` — per-request breakdowns still sum exactly to
  JCT.  Two serving scenarios share this loop (``RuntimeConfig.mode``):

  - ``"pool"`` (KV-disaggregated prefix caching, the paper's TTFT path):
    the prefix pool is a :class:`~repro.serving.kvstore.TieredKVStore`
    memory hierarchy (HBM -> DRAM -> remote by default); hits fetch real
    compressed bytes over the holding tier's serialized link (concurrent
    fetches/writes contend) and promote on access, misses prefill locally
    and write the compressed prefix back through the hierarchy *off* the
    critical path (capacity pressure demotes entries down the tiers,
    re-compressing with the destination tier's profile).
  - ``"pd"`` (PD separation, the paper's JCT path): every cold request's
    prefix KV crosses the network — prefill -> controller-selected
    compress -> serialized :class:`~repro.serving.network.KVWire`
    transfer -> decompress -> inject into the decode arena — all ON the
    request's critical path, with concurrent transfers contending for
    the wire.  The transferred bytes then seed the decode-side prefix
    pool, so identical prompts hit without re-crossing the wire's cold
    path.  Requests move through an explicit lifecycle
    (waiting -> prefilling -> transferring -> decoding).

The slot arena is ONE cache pytree with a leading slot axis of size
``max_slots``.  Each slot owns a cache row, a per-slot position, and a
live flag; the batched decode step masks free/fresh rows (parked at a
scratch position) instead of branching per slot, so decode wall-clock is
one model call per iteration regardless of occupancy — the continuous-
batching amortization the per-slot loop of PR 1 lacked.

Every byte on the "wire" is real pipeline output.  Compute time is either
measured wall-clock or (for deterministic benchmarks) modelled from
``prefill_tok_s`` / ``decode_tok_s`` (codec stages then follow the
profile's measured throughputs, ``V/s_enc`` + ``V/s_dec``, per Eq. 1);
communication time always comes from the
:class:`~repro.serving.network.BandwidthTrace`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.controller import (
    Decision,
    ServiceAwareController,
    ServiceContext,
    TierFetch,
)
from repro.core.pipeline import CompressedKV, CompressionPipeline
from repro.core.profiles import Profile
from repro.core.quality import (
    _greedy_decode,
    _jitted_steps,
    _prompts_for,
    copy_cache_slot,
    extract_kv,
    get_reference_model,
    inject_kv,
)
from repro.core.strategy import StrategyConfig, is_identity
from repro.data.tokenizer import ByteTokenizer
from repro.serving.kvstore import (
    PrefixKVStore,
    TierHit,
    TierSpec,
    TieredKVStore,
    default_tier_specs,
)
from repro.serving.network import BandwidthTrace, GoodputEstimator, KVWire
from repro.serving.request import Request, kv_bytes_for
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig


def _select_profile(controller: Optional[ServiceAwareController],
                    static_profile: Optional[Profile],
                    ctx: ServiceContext
                    ) -> Tuple[Profile, Optional[Decision]]:
    """Shared controller / static / identity three-way profile choice."""
    if controller is not None:
        d = controller.select(ctx)
        return d.profile, d
    if static_profile is not None:
        return static_profile, None
    from repro.core.profiles import IDENTITY_PROFILE
    return IDENTITY_PROFILE, None


# ---------------------------------------------------------------------------
# Shared PD stages (one-shot engine AND per-request continuous runtime)
# ---------------------------------------------------------------------------
def compress_kvs(strategy: StrategyConfig, kvs: Sequence[Any]
                 ) -> Tuple[List[Any], int, float]:
    """Compress each KV prefix for the wire.  Returns
    ``(payloads, wire_bytes, measured_seconds)``."""
    pipe = CompressionPipeline(strategy)
    t0 = time.perf_counter()
    comps = [pipe.compress(kv) for kv in kvs]
    t_wall = time.perf_counter() - t0
    return comps, sum(c.total_bytes() for c in comps), t_wall


def decompress_kvs(comps: Sequence[CompressedKV]
                   ) -> Tuple[List[Any], float]:
    """Restore wire payloads to KV.  Returns ``(kvs, measured_seconds)``."""
    t0 = time.perf_counter()
    kvs = [CompressionPipeline(c.strategy).decompress(c) for c in comps]
    t_wall = time.perf_counter() - t0
    return kvs, t_wall


@dataclass
class ServedBatch:
    workload: str
    text: List[str]
    tokens: np.ndarray
    profile: str
    kv_bytes: int
    wire_bytes: int
    t_prefill: float
    t_compress: float
    t_comm: float
    t_decompress: float
    t_decode: float
    agreement: float  # vs uncompressed decode

    @property
    def jct(self) -> float:
        return (self.t_prefill + self.t_compress + self.t_comm
                + self.t_decompress + self.t_decode)


class DisaggregatedEngine:
    """One-shot PD-separated serving of the tiny reference model: a thin
    synchronous wrapper over the shared stage helpers (the continuous
    :class:`ServingRuntime` pipelines the same stages per request)."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 seq: int = 192, decode_tokens: int = 20, batch: int = 4):
        self.cfg, self.params = get_reference_model()
        self.controller = controller
        self.static_profile = static_profile
        self.seq = seq
        self.decode_tokens = decode_tokens
        self.batch = batch
        self.estimator = GoodputEstimator()
        self._pre, self._dec, _ = _jitted_steps(
            self.cfg.name, seq, batch, seq + decode_tokens + 2)
        self.tok = ByteTokenizer()

    # ------------------------------------------------------------------
    def serve(self, workload: str, trace: BandwidthTrace, now: float = 0.0,
              t_slo: float = 0.0, q_min: float = 0.97, seed: int = 0
              ) -> ServedBatch:
        tokens, _ = _prompts_for(workload, self.batch, self.seq, seed)

        # ---- prefill worker ----
        t0 = time.perf_counter()
        logits, caches = self._pre(self.params, {"tokens": tokens})
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        first = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

        # reference decode for agreement scoring
        ref_toks = _greedy_decode(self._dec, self.params, caches, first,
                                  self.seq, self.decode_tokens)

        # ---- controller decision ----
        kvs = [extract_kv(self.cfg, caches, b, upto=self.seq)
               for b in range(self.batch)]
        v_bytes = sum(kv.nbytes_wire() for kv in kvs)
        ctx = ServiceContext(workload=workload,
                             bandwidth=self.estimator.estimate,
                             t_slo=t_slo, q_min=q_min, t_model=t_prefill,
                             kv_bytes=v_bytes, slo_metric="jct")
        profile, decision = _select_profile(self.controller,
                                            self.static_profile, ctx)

        # ---- compress -> wire -> decompress (shared PD stages) ----
        comps, wire_bytes, t_compress = compress_kvs(profile.strategy, kvs)
        wire = KVWire(trace, self.estimator)
        t_comm = wire.send(now + t_prefill + t_compress, wire_bytes).t_comm
        restored, t_decompress = decompress_kvs(comps)

        # ---- decode worker ----
        comp_caches = caches
        if not is_identity(profile.strategy):
            for b in range(self.batch):
                comp_caches = inject_kv(self.cfg, comp_caches, b, restored[b])
        t0 = time.perf_counter()
        test_toks = _greedy_decode(self._dec, self.params, comp_caches, first,
                                   self.seq, self.decode_tokens)
        t_decode = time.perf_counter() - t0

        agreement = float((ref_toks == test_toks).mean())
        # One-shot PD: compress/comm/decompress ARE the critical path.
        observed = t_compress + t_comm + t_decompress + ctx.t_model
        if self.controller is not None and decision is not None:
            self.controller.observe(ctx, decision, observed)

        texts = [self.tok.decode(row[1:]) for row in test_toks]
        return ServedBatch(
            workload=workload, text=texts, tokens=test_toks,
            profile=profile.strategy.short_name(), kv_bytes=int(v_bytes),
            wire_bytes=int(wire_bytes), t_prefill=t_prefill,
            t_compress=t_compress, t_comm=t_comm,
            t_decompress=t_decompress, t_decode=t_decode,
            agreement=agreement)


# ===========================================================================
# Continuous-batching runtime
# ===========================================================================
@dataclass
class RuntimeConfig:
    seq: int = 96                 # prompt tokens (padded/truncated)
    decode_tokens: int = 12       # generation budget per request
    # Serving scenario: "pool" = KV-disaggregated prefix caching (cold
    # requests prefill locally, pool writes are off the critical path);
    # "pd" = PD separation (every cold request's compressed KV crosses the
    # serialized wire prefill -> compress -> transfer -> decompress ->
    # decode, ON the critical path).
    mode: str = "pool"
    # Virtual-clock cost model.  None = measure wall-clock (real execution
    # time of the tiny model); a float models a loaded cluster, which is the
    # paper's pool regime where prefill is the expensive path.  When set,
    # codec stages are modelled from the profile's measured throughputs
    # (V/s_enc, V/s_dec — Eq. 1) so sweeps are deterministic.
    prefill_tok_s: Optional[float] = None
    decode_tok_s: Optional[float] = None
    pool_fetch_overhead: float = 0.002   # pool RPC setup cost (s)
    store_capacity: int = 64 << 20       # wire bytes (remote/pool tier)
    store_block: int = 16
    # KV memory hierarchy (ISSUE 4).  None builds the default: pool mode
    # gets HBM -> DRAM -> remote (hot/dram capacities below, remote =
    # store_capacity over the runtime's BandwidthTrace); PD mode gets a
    # single remote tier sharing the PD transfer wire (the pool lives
    # across the same link the compressed KV crosses).  Pass an explicit
    # TierSpec list to override either.
    tiers: Optional[Sequence[TierSpec]] = None
    hot_tier_bytes: int = 4 << 20
    dram_tier_bytes: int = 16 << 20
    # PD cold path: what the decode arena is materialized from.  False
    # (default) keeps the prefill worker's exact cache — cold decode is
    # numerically identical to the pool scenario (token-exact vs the
    # pinned PR-1 fixture); the compressed payload still crosses the wire
    # byte-for-byte and is what later pool hits decode from, so the
    # profile's quality loss surfaces exactly where the pool path's does.
    # True injects the wire-restored KV instead (quality-faithful decode;
    # tokens then reflect the selected profile's loss immediately).
    pd_inject_restored: bool = False


@dataclass
class ServedRequest:
    """Per-request outcome of the continuous runtime (the per-request
    analogue of :class:`ServedBatch`)."""

    rid: int
    workload: str
    slo_class: str
    text: str
    tokens: np.ndarray
    profile: str
    pool_hit: bool
    kv_bytes: int
    wire_bytes: int               # bytes this request moved over the wire
    arrival: float
    done: float
    ttft: float
    slot: int = -1                # arena slot that served the request
    # Critical-path decomposition; sums exactly to jct.  Keys: queue,
    # prefill | comm+decompress (pool hit), decode, stall (time spent
    # waiting on the iteration's other stream), and — PD mode — compress,
    # wire_wait (queueing behind other transfers on the serialized wire),
    # comm, decompress, all on the request's critical path.
    breakdown: Dict[str, float] = field(default_factory=dict)
    # Off-critical-path cost of writing the compressed prefix to the pool
    # (compress + wire), charged to the background writer, not the request.
    # Always 0.0 in PD mode: there the transfer IS the critical path, and
    # the transferred bytes seed the decode-side pool for free.
    t_pool_write: float = 0.0
    # Which latency the SLO bounded ("ttft" | "jct") and whether it was
    # violated — the bandit observed the SAME metric.
    slo_metric: str = "jct"
    slo_violated: bool = False

    @property
    def jct(self) -> float:
        return self.done - self.arrival


@dataclass
class _Slot:
    """Host-side bookkeeping for one occupied arena slot (the device-side
    state — cache row, position, live flag — lives in the arena arrays)."""

    req: Request
    idx: int                      # arena slot index (row in the cache pytree)
    toks: List[int]               # generated tokens (incl. first)
    pool_hit: bool
    profile: str
    wire_bytes: int
    breakdown: Dict[str, float]
    ttft: float
    pool_write: float = 0.0       # off-path compress+write cost (misses)
    # Controller feedback deferred to _finish so the bandit observes the
    # request's realized critical-path latency (= breakdown sum = jct),
    # not the off-critical-path pool write.
    ctx: Optional[ServiceContext] = None
    decision: Optional[Decision] = None


class ServingRuntime:
    """Iteration-level (continuous-batching) serving of the tiny reference
    model against a compressed prefix-KV pool, on a batched slot arena."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 config: Optional[RuntimeConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 store: Optional[Any] = None,
                 trace: Optional[BandwidthTrace] = None):
        self.cfg = config or RuntimeConfig()
        self.controller = controller
        self.static_profile = static_profile
        self.scheduler = ContinuousScheduler(scheduler or SchedulerConfig())
        self.trace = trace or BandwidthTrace.constant(1e9)
        self.estimator = GoodputEstimator(initial=self.trace.at(0.0))
        # The PD transfer link: one serialized queue, so transfers of
        # concurrently admitted requests contend.
        self.wire = KVWire(self.trace, self.estimator)
        # The prefix pool is a tiered memory hierarchy; every fetch and
        # write is routed through the holding tier's serialized link, so
        # concurrent pool traffic contends (a flat PrefixKVStore passed in
        # is adopted as a single remote tier over the runtime's trace).
        if store is None:
            specs = self.cfg.tiers
            if specs is None:
                if self.cfg.mode == "pd":
                    specs = [TierSpec(
                        "remote", self.cfg.store_capacity,
                        bandwidth=self.trace,
                        fetch_overhead=self.cfg.pool_fetch_overhead,
                        observe_goodput=True)]
                else:
                    specs = default_tier_specs(
                        self.cfg.store_capacity, self.trace,
                        remote_overhead=self.cfg.pool_fetch_overhead,
                        hot_bytes=self.cfg.hot_tier_bytes,
                        dram_bytes=self.cfg.dram_tier_bytes)
            self.store = TieredKVStore(specs, block=self.cfg.store_block,
                                       estimator=self.estimator,
                                       recompress=self._recompress_entry)
            if self.cfg.mode == "pd":
                # PD transfers and pool fetches/writes share ONE physical
                # link — the pool sits across the same wire the compressed
                # KV crosses.
                self.store.tiers[-1].wire = self.wire
        elif isinstance(store, TieredKVStore):
            self.store = store
            if store.estimator is None:
                store.estimator = self.estimator
            if store.recompress is None:
                store.recompress = self._recompress_entry
        else:
            self.store = TieredKVStore.wrap_flat(
                store, self.trace,
                fetch_overhead=self.cfg.pool_fetch_overhead,
                estimator=self.estimator)
            self.store.recompress = self._recompress_entry
        self.model_cfg, self.params = get_reference_model()
        self.max_len = self.cfg.seq + self.cfg.decode_tokens + 2
        self._pre1, _, _ = _jitted_steps(
            self.model_cfg.name, self.cfg.seq, 1, self.max_len)
        self.n_slots = self.scheduler.cfg.max_slots
        _, _, self._dec_arena = _jitted_steps(
            self.model_cfg.name, self.cfg.seq, self.n_slots, self.max_len)
        self.tok = ByteTokenizer()
        self.clock = 0.0
        self.steps = 0
        self.completed: List[ServedRequest] = []
        self.step_log: List[Dict[str, float]] = []
        self._slots: Dict[int, _Slot] = {}
        self._prompts: Dict[int, np.ndarray] = {}
        self._next_rid = 0
        # ---- device-side slot arena (lazily materialised) ----
        self._arena: Any = None          # cache pytree, leading axis n_slots
        self._positions = np.zeros(self.n_slots, np.int32)  # next write pos
        self._last_tok = np.zeros(self.n_slots, np.int32)   # last emitted tok

    # ------------------------------------------------------------------
    def _ensure_arena(self):
        if self._arena is None:
            from repro.models.transformer import init_cache, plan_stack
            plan = plan_stack(self.model_cfg)
            if any(s.kind != "attn"
                   for s in plan.prefix_specs + plan.period_specs):
                raise NotImplementedError(
                    "slot arena masking assumes attention-only caches "
                    "(SSM states advance unmasked)")
            self._arena = init_cache(self.model_cfg, self.n_slots,
                                     self.max_len)
        return self._arena

    # ------------------------------------------------------------------
    @property
    def slo_metric_default(self) -> str:
        """Scenario default for requests that don't pin one: the pool
        scenario's SLO is time-to-first-token, PD separation's is JCT."""
        return "jct" if self.cfg.mode == "pd" else "ttft"

    def submit(self, workload: str, t_slo: float = 0.0, q_min: float = 0.97,
               slo_class: str = "standard", out_tokens: Optional[int] = None,
               prompt_seed: int = 0,
               slo_metric: Optional[str] = None) -> Optional[int]:
        """Admit one request at the current virtual time.  Two submissions
        with the same (workload, prompt_seed) share a prompt, so the second
        can be served from the prefix pool.  Returns the request id, or
        None if admission control shed it."""
        if slo_metric not in (None, "ttft", "jct"):
            raise ValueError(f"slo_metric must be 'ttft' or 'jct', "
                             f"got {slo_metric!r}")
        rid = self._next_rid
        self._next_rid += 1
        tokens, _ = _prompts_for(workload, 1, self.cfg.seq, prompt_seed)
        tokens = np.asarray(tokens)[0]
        m = self.model_cfg
        req = Request(
            rid=rid, workload=workload, arrival=self.clock,
            ctx_tokens=self.cfg.seq,
            out_tokens=(self.cfg.decode_tokens if out_tokens is None
                        else min(out_tokens, self.cfg.decode_tokens)),
            kv_bytes=kv_bytes_for(self.cfg.seq, m.num_layers, m.kv_heads,
                                  m.resolved_head_dim),
            t_slo=t_slo, q_min=q_min, slo_class=slo_class,
            slo_metric=slo_metric,
            prefix_key=tuple(int(t) for t in tokens))
        if not self.scheduler.submit(req, self.clock):
            return None
        self._prompts[rid] = tokens
        return rid

    # ------------------------------------------------------------------
    # Start-of-life stages, shared by the pool and PD paths
    # ------------------------------------------------------------------
    def _codec_cost(self, measured: float, nbytes: float,
                    speed: float) -> float:
        """Codec stage cost: measured wall-clock, or — under the virtual
        clock — modelled from the profile's throughput (V/s, Eq. 1)."""
        if self.cfg.prefill_tok_s is None:
            return measured
        return 0.0 if speed == float("inf") else nbytes / speed

    def _run_prefill(self, req: Request, tokens: np.ndarray):
        """Real batch-1 prefill on the prefill worker.  Returns
        ``(caches, first_token, t_prefill)``."""
        t0 = time.perf_counter()
        logits, caches = self._pre1(self.params, {"tokens": tokens[None, :]})
        jax.block_until_ready(logits)
        t_wall = time.perf_counter() - t0
        t_prefill = (req.ctx_tokens / self.cfg.prefill_tok_s
                     if self.cfg.prefill_tok_s else t_wall)
        first = int(np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))[0])
        return caches, first, t_prefill

    def _select_and_compress(self, req: Request, caches, t_prefill: float):
        """Controller decision + real compression of the prefix KV.
        Returns ``(comp, ctx, decision, profile, t_compress)``."""
        kv = extract_kv(self.model_cfg, caches, 0, upto=self.cfg.seq)
        ctx = ServiceContext(
            workload=req.workload, bandwidth=self.estimator.estimate,
            t_slo=req.t_slo, q_min=req.q_min, t_model=t_prefill,
            kv_bytes=kv.nbytes_wire(),
            slo_metric=req.resolved_slo_metric(self.slo_metric_default))
        profile, decision = _select_profile(self.controller,
                                            self.static_profile, ctx)
        comps, _, t_wall = compress_kvs(profile.strategy, [kv])
        t_compress = self._codec_cost(t_wall, kv.nbytes_wire(),
                                      profile.s_enc)
        return comps[0], ctx, decision, profile, t_compress

    def _fetch_entry(self, entry, idx: int):
        """Decompress a stored pool entry and inject it into arena slot
        ``idx``.  Returns ``(first_token, t_decompress)``.  Cache injection
        is host-side bookkeeping of the miniature (the cold path's
        equivalent writes happen inside prefill), so it is not billed to
        the virtual clock."""
        comp, first, s_dec = entry.payload
        restored, t_wall = decompress_kvs([comp])
        t_decompress = self._codec_cost(t_wall, entry.kv_bytes, s_dec)
        self._arena = inject_kv(self.model_cfg, self._ensure_arena(), idx,
                                restored[0])
        return int(first), t_decompress

    # ------------------------------------------------------------------
    def _recompress_entry(self, entry, profile: Profile
                          ) -> Optional[Tuple[Any, int]]:
        """Tier demotion / refetch-smaller hook: really re-encode a stored
        ``(CompressedKV, first, s_dec)`` payload with ``profile``.  Returns
        None when it would not shrink."""
        comp, first, _ = entry.payload
        if comp.strategy == profile.strategy:
            return None
        restored, _ = decompress_kvs([comp])
        comps, wire, _ = compress_kvs(profile.strategy, restored)
        if wire >= entry.wire_bytes:
            return None
        return (comps[0], first, profile.s_dec), wire

    def _maybe_refetch_smaller(self, req: Request, hit: TierHit,
                               now: float) -> float:
        """Tier-aware fetch routing: ask the controller to trade fetching
        the stored encoding over the holding tier's link against
        re-encoding it with the pool tier's (most aggressive) demotion
        profile before the transfer — the "refetch smaller" route that
        pays encode time to cross a slow link with fewer bytes.  Returns
        the source-side re-encode time spent ON the request's critical
        path (0.0 when the stored route wins)."""
        select_fetch = getattr(self.controller, "select_fetch", None)
        if select_fetch is None:
            return 0.0
        tier, e = hit.tier, hit.entry
        small = self.store.tiers[-1].spec.profile
        if small is None or small.q(req.workload) < req.q_min:
            return 0.0
        bandwidth = (self.estimator.estimate if tier.spec.observe_goodput
                     else tier.trace.at(now))
        common = dict(tier=tier.name, kv_bytes=e.kv_bytes,
                      bandwidth=bandwidth, overhead=tier.fetch_overhead)
        stored = TierFetch(variant="stored", wire_bytes=e.wire_bytes,
                           s_dec=e.payload[2], **common)
        small_bytes = e.kv_bytes / max(small.cr, 1.0)
        if small_bytes >= e.wire_bytes:
            return 0.0
        reenc = TierFetch(variant="reencoded", wire_bytes=small_bytes,
                          s_enc=small.s_enc, s_dec=small.s_dec, **common)
        ctx = ServiceContext(
            workload=req.workload, bandwidth=bandwidth, t_slo=req.t_slo,
            q_min=req.q_min, kv_bytes=e.kv_bytes,
            slo_metric=req.resolved_slo_metric(self.slo_metric_default))
        decision = select_fetch(ctx, [stored, reenc])
        if decision is None or decision.option.variant != "reencoded":
            return 0.0
        t0 = time.perf_counter()
        if not self.store.reencode(hit, small):
            return 0.0
        # The re-encode happens before the bytes can cross the link: its
        # cost (the enc term of the fetch decision) is on the critical
        # path — measured wall-clock, or V/s_enc under the virtual clock.
        return self._codec_cost(time.perf_counter() - t0, e.kv_bytes,
                                small.s_enc)

    # ------------------------------------------------------------------
    def _start_request(self, req: Request, now: float,
                       busy: float) -> Tuple[float, float]:
        """Pool-mode start: prefill-or-fetch one admitted request into its
        arena slot (``req.slot``, assigned by the scheduler).  A hit never
        touches the prefill worker — its fetch starts at ``now`` and
        contends on the holding tier's serialized link; a miss serializes
        on the prefill worker (``busy``) and writes the compressed prefix
        back through the hot tier's link off the critical path.  Returns
        ``(end_offset, new_busy)`` relative to ``now``."""
        tokens = self._prompts[req.rid]
        key = req.prefix_key
        idx = req.slot
        arena = self._ensure_arena()
        # full=True: a partial (block-aligned) prefix hit would leave the
        # uncovered prompt suffix without KV — the runtime has no top-up
        # prefill, so only a full-coverage entry counts as a pool hit.
        hit = self.store.lookup(key, now=now, full=True)
        bd: Dict[str, float] = {"queue": now - req.arrival}

        if hit is not None:
            # ---- pool hit: fetch real compressed bytes over the holding
            # tier's serialized link, decompress, inject into the slot
            entry = hit.entry
            req.state = "transferring"
            t_reencode = self._maybe_refetch_smaller(req, hit, now)
            tr = self.store.fetch(hit, ready=now + t_reencode)
            first, t_decompress = self._fetch_entry(entry, idx)
            cost = (t_reencode + hit.tier.fetch_overhead + tr.t_wait
                    + tr.t_comm + t_decompress)
            bd.update(wire_wait=tr.t_wait,
                      comm=hit.tier.fetch_overhead + tr.t_comm,
                      decompress=t_decompress)
            if t_reencode > 0:
                bd["compress"] = t_reencode
            req.state = "decoding"
            slot = _Slot(req=req, idx=idx, toks=[first],
                         pool_hit=True,
                         profile=entry.payload[0].strategy.short_name(),
                         wire_bytes=int(entry.wire_bytes), breakdown=bd,
                         ttft=(now + cost) - req.arrival)
            self._occupy(slot, first)
            return cost, busy

        # ---- miss: real prefill into the slot (serialized on the prefill
        # worker), then write the compressed prefix back to the hierarchy
        bd["queue"] += busy
        caches, first, t_prefill = self._run_prefill(req, tokens)
        bd.update(prefill=t_prefill)
        self._arena = copy_cache_slot(self.model_cfg, arena, caches, idx)

        comp, ctx, decision, profile, t_compress = \
            self._select_and_compress(req, caches, t_prefill)
        wire = comp.total_bytes()
        # The pool write crosses the hot tier's link off the request's
        # critical path (it still contends with fetches there); its cost
        # is booked to pool_write, and the controller observes the
        # request's critical-path latency at _finish instead.
        wr = self.store.write(
            key, (comp, first, profile.s_dec), wire, kv_bytes=ctx.kv_bytes,
            workload=req.workload, slo_class=req.slo_class,
            ready=now + busy + t_prefill + t_compress, tier=0)
        req.state = "decoding"
        end = busy + t_prefill
        slot = _Slot(req=req, idx=idx, toks=[first], pool_hit=False,
                     profile=profile.strategy.short_name(),
                     wire_bytes=int(wire), breakdown=bd,
                     ttft=(now + end) - req.arrival,
                     pool_write=t_compress + wr.t_wait + wr.t_comm,
                     ctx=ctx, decision=decision)
        self._occupy(slot, first)
        return end, end

    # ------------------------------------------------------------------
    def _start_request_pd(self, req: Request, now: float,
                          busy: float) -> Tuple[float, float]:
        """PD-mode start: run one admitted request through its critical
        path — prefill (on the prefill worker, serialized at ``busy``) ->
        controller-selected compress -> serialized wire transfer ->
        decompress -> inject into the decode arena.  A decode-side pool
        hit skips the whole cold path (the prefix's bytes crossed the wire
        earlier).  Returns ``(end_offset, new_busy)`` relative to ``now``.
        """
        tokens = self._prompts[req.rid]
        key = req.prefix_key
        idx = req.slot
        bd: Dict[str, float] = {"queue": now - req.arrival}

        hit = self.store.lookup(key, now=now, full=True)
        if hit is not None:
            # ---- decode-side prefix hit: the compressed prefix already
            # crossed the wire for an earlier request; fetch it from the
            # pool tier (contending for the same wire) instead of
            # re-prefilling.
            entry = hit.entry
            req.state = "transferring"
            tr = self.store.fetch(hit, ready=now)
            first, t_decompress = self._fetch_entry(entry, idx)
            end = (hit.tier.fetch_overhead + tr.t_wait + tr.t_comm
                   + t_decompress)
            bd.update(wire_wait=tr.t_wait,
                      comm=hit.tier.fetch_overhead + tr.t_comm,
                      decompress=t_decompress)
            req.state = "decoding"
            slot = _Slot(req=req, idx=idx, toks=[first], pool_hit=True,
                         profile=entry.payload[0].strategy.short_name(),
                         wire_bytes=int(entry.wire_bytes), breakdown=bd,
                         ttft=(now + end) - req.arrival)
            self._occupy(slot, first)
            return end, busy

        # ---- cold request: the full PD critical path.  The prefill
        # worker is serialized within the iteration (``busy``); the wire
        # is serialized across ALL transfers (self.wire).
        bd["queue"] += busy
        caches, first, t_prefill = self._run_prefill(req, tokens)
        comp, ctx, decision, profile, t_compress = \
            self._select_and_compress(req, caches, t_prefill)
        busy = busy + t_prefill + t_compress
        wire_bytes = comp.total_bytes()
        req.state = "transferring"
        tr = self.wire.send(now + busy, wire_bytes)
        # The arena row comes from the restored bytes or (default) from
        # the prefill cache — see RuntimeConfig.pd_inject_restored.  The
        # real decompress only runs when its output or its measured time
        # is actually consumed (virtual-clock default models the cost from
        # profile.s_dec, so running it would be pure benchmark tax).
        if self.cfg.pd_inject_restored or self.cfg.prefill_tok_s is None:
            restored, t_wall = decompress_kvs([comp])
        else:
            restored, t_wall = None, 0.0
        t_decompress = self._codec_cost(t_wall, ctx.kv_bytes, profile.s_dec)
        if self.cfg.pd_inject_restored:
            self._arena = inject_kv(self.model_cfg, self._ensure_arena(),
                                    idx, restored[0])
        else:
            self._arena = copy_cache_slot(self.model_cfg,
                                          self._ensure_arena(), caches, idx)
        # The bytes that just crossed the wire seed the decode-side pool
        # tier (no extra transfer): later identical prompts hit it.
        self.store.put(key, (comp, first, profile.s_dec), wire_bytes,
                       kv_bytes=ctx.kv_bytes, workload=req.workload,
                       slo_class=req.slo_class, now=tr.end,
                       tier=len(self.store.tiers) - 1)
        end = busy + tr.t_wait + tr.t_comm + t_decompress
        bd.update(prefill=t_prefill, compress=t_compress,
                  wire_wait=tr.t_wait, comm=tr.t_comm,
                  decompress=t_decompress)
        req.state = "decoding"
        slot = _Slot(req=req, idx=idx, toks=[first], pool_hit=False,
                     profile=profile.strategy.short_name(),
                     wire_bytes=int(wire_bytes), breakdown=bd,
                     ttft=(now + end) - req.arrival,
                     ctx=ctx, decision=decision)
        self._occupy(slot, first)
        return end, busy

    # ------------------------------------------------------------------
    def _occupy(self, slot: _Slot, first: int) -> None:
        self._slots[slot.req.rid] = slot
        self._positions[slot.idx] = self.cfg.seq
        self._last_tok[slot.idx] = first

    # ------------------------------------------------------------------
    def _finish(self, slot: _Slot, now: float) -> None:
        req = slot.req
        toks = np.asarray(slot.toks, dtype=np.int32)
        req.ttft = slot.ttft
        req.done = now
        req.chosen = slot.profile
        req.breakdown = slot.breakdown
        # One SLO metric end to end: the same latency (ttft or jct,
        # request-pinned or scenario default) is compared to t_slo here
        # AND fed to the bandit, so its violation cooldown fires on the
        # metric the runtime reports — not a different one.
        metric = req.resolved_slo_metric(self.slo_metric_default)
        observed = (slot.ttft if metric == "ttft"
                    else sum(slot.breakdown.values()))
        req.slo_violated = req.t_slo > 0 and observed > req.t_slo
        if self.controller is not None and slot.decision is not None:
            # Residual-bandit feedback: the realized critical-path latency
            # of the SLO metric (jct == the ServedRequest breakdown sum).
            self.controller.observe(slot.ctx, slot.decision, observed)
        self.completed.append(ServedRequest(
            rid=req.rid, workload=req.workload, slo_class=req.slo_class,
            text=self.tok.decode(toks), tokens=toks, profile=slot.profile,
            pool_hit=slot.pool_hit, kv_bytes=int(req.kv_bytes),
            wire_bytes=slot.wire_bytes, arrival=req.arrival, done=now,
            ttft=slot.ttft, slot=slot.idx, breakdown=slot.breakdown,
            t_pool_write=slot.pool_write, slo_metric=metric,
            slo_violated=req.slo_violated))
        self.scheduler.finish(req.rid)   # releases the arena slot id
        del self._slots[req.rid]
        self._prompts.pop(req.rid, None)

    # ------------------------------------------------------------------
    def _prefill_stream(self, now: float) -> List[Tuple[_Slot, float]]:
        """The iteration's prefill stream: admit up to
        ``max_prefills_per_step`` waiting requests and run each through
        its start-of-life stages.  Returns ``(slot, end_offset)`` pairs;
        the stream's cost is the max end offset.  In both modes only the
        prefill worker serializes (``busy``): pool hits are pure fetches
        that start at ``now`` and contend on their tier's serialized link,
        misses/cold requests queue for the prefill worker, and in PD mode
        a request's transfer overlaps the next request's prefill."""
        started: List[Tuple[_Slot, float]] = []
        busy = 0.0                # prefill-worker occupancy offset
        for req in self.scheduler.next_prefills(now):
            if self.cfg.mode == "pd":
                end, busy = self._start_request_pd(req, now, busy)
            else:
                end, busy = self._start_request(req, now, busy)
            started.append((self._slots[req.rid], end))
        return started

    def step(self) -> Dict[str, float]:
        """One iteration of the two overlapped streams: the prefill stream
        admits prefill/fetch/transfer work, the decode stream advances
        every *previously running* decode slot by one token (a request's
        first decode token comes the iteration after its prefill) — all
        slots in ONE masked batched decode call.  The iteration costs
        ``max(streams)``; the difference is charged as stall."""
        now = self.clock
        started = self._prefill_stream(now)
        prefill_cost = max((end for _, end in started), default=0.0)
        new_rids = {s.req.rid for s, _ in started}

        # Iteration-level decode: every in-flight slot emits one token via
        # a single jitted arena step (per-slot positions, on-device argmax,
        # one (B,) token pull per iteration — no per-slot host round-trips).
        decode_wall = 0.0
        active = [s for rid, s in self._slots.items() if rid not in new_rids]
        if active:
            mask = np.zeros(self.n_slots, bool)
            for slot in active:
                mask[slot.idx] = True
            t0 = time.perf_counter()
            nxt, self._arena = self._dec_arena(
                self.params, self._ensure_arena(),
                jnp.asarray(self._last_tok[:, None]),
                jnp.asarray(self._positions), jnp.asarray(mask))
            nxt = np.asarray(nxt)        # the step's single host sync
            decode_wall = time.perf_counter() - t0
            for slot in active:
                t = int(nxt[slot.idx])
                slot.toks.append(t)
                self._last_tok[slot.idx] = t
                self._positions[slot.idx] += 1
        decode_cost = 0.0
        if active:
            decode_cost = (1.0 / self.cfg.decode_tok_s
                           if self.cfg.decode_tok_s else decode_wall)

        # An iteration costs the slower of the prefill and decode streams
        # (PD-separated workers run them concurrently); the difference is
        # charged to each slot as "stall" so breakdowns sum exactly to jct.
        iter_cost = max(prefill_cost, decode_cost)
        for slot in active:
            slot.breakdown["decode"] = \
                slot.breakdown.get("decode", 0.0) + decode_cost
            slot.breakdown["stall"] = \
                slot.breakdown.get("stall", 0.0) + iter_cost - decode_cost
        for slot, end_offset in started:
            slot.breakdown["stall"] = \
                slot.breakdown.get("stall", 0.0) + iter_cost - end_offset
        self.clock = now + iter_cost
        self.steps += 1
        for slot in list(self._slots.values()):
            if len(slot.toks) > slot.req.out_tokens:
                self._finish(slot, self.clock)

        stats = {"step": float(self.steps), "clock": self.clock,
                 "in_flight": float(len(active) + len(started)),
                 "queue_depth": float(self.scheduler.queue_depth),
                 "completed": float(len(self.completed)),
                 "store_used": float(self.store.used_bytes)}
        self.step_log.append(stats)
        return stats

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> List[ServedRequest]:
        """Step until every admitted request completed, or until
        ``max_steps`` iterations *from this call* — the budget is relative,
        so a second ``run()`` on a long-lived runtime keeps making
        progress instead of returning against the cumulative counter."""
        start = self.steps
        while not self.scheduler.idle and self.steps - start < max_steps:
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    def max_in_flight(self) -> int:
        return int(max((s["in_flight"] for s in self.step_log), default=0))

    def summary(self) -> Dict[str, float]:
        hits = [r for r in self.completed if r.pool_hit]
        cold = [r for r in self.completed if not r.pool_hit]
        out = {
            "completed": len(self.completed),
            "rejected": self.scheduler.admission.rejected,
            "max_in_flight": self.max_in_flight(),
            "pool_hits": len(hits),
            "pool_hit_rate": len(hits) / max(len(self.completed), 1),
            "wire_transfers": float(self.wire.transfers),
            "wire_bytes_moved": float(self.wire.bytes_moved),
        }
        if self.completed:
            out["mean_jct"] = float(np.mean([r.jct for r in self.completed]))
            out["mean_ttft"] = float(np.mean([r.ttft for r in self.completed]))
        if hits:
            out["mean_ttft_hit"] = float(np.mean([r.ttft for r in hits]))
        if cold:
            out["mean_ttft_cold"] = float(np.mean([r.ttft for r in cold]))
        out.update({f"store_{k}": v for k, v in self.store.summary().items()})
        return out
