"""Paper Fig. 14: TTFT in the prefix-caching (KV pool) scenario.

CacheGen-style static falls back to recomputation when its fixed profile
cannot meet the SLO; KVServe pinpoints a feasible profile from the Pareto
set, turning infeasible fetches into valid cache hits.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_profiles, emit
from repro.controller import ServiceAwareController
from repro.data.synthetic import WORKLOADS
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    NoCompressionPolicy,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)


def run(smoke: bool = False) -> None:
    profiles = cached_profiles()
    cachegen = next(p for p in profiles
                    if "cachegen" in p.strategy.short_name())
    # Paper regime (Fig 14): long-context prefill is the expensive path
    # (loaded cluster, ~150 tok/s effective), so a compressed fetch beats
    # recomputation whenever a feasible profile exists.
    cfg = SimConfig(scenario="pool", prefill_tok_s=150.0)
    mk = lambda hit: WorkloadMix(rate=0.5, seed=1, slo=45.0, q_min=0.0,
                                 prefix_hit_rate=hit)
    bandwidths = (0.04, 0.3) if smoke else (0.04, 0.06, 0.08, 0.12, 0.3,
                                            0.6)
    n = 20 if smoke else 40

    for bw in bandwidths:
        trace = BandwidthTrace.constant(bw * GBPS)
        t0 = time.perf_counter()
        # "Default" = no prefix reuse: always recompute
        res_def = Simulator(cfg, NoCompressionPolicy(), trace,
                            mk(0.0).generate(n)).run()
        res_cg = Simulator(cfg, StaticPolicy(cachegen, "cg",
                                             slo_fallback_recompute=True),
                           trace, mk(1.0).generate(n)).run()
        controller = ServiceAwareController({w: profiles for w in WORKLOADS})
        res_kv = Simulator(cfg, KVServePolicy(controller), trace,
                           mk(1.0).generate(n)).run()
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig14_ttft_bw{bw}gbps", us,
             f"recompute={res_def.mean_ttft():.2f}s "
             f"cachegen={res_cg.mean_ttft():.2f}s "
             f"kvserve={res_kv.mean_ttft():.2f}s "
             f"speedup_vs_recompute={res_def.mean_ttft()/res_kv.mean_ttft():.1f}x "
             f"slo_attain_kv={res_kv.slo_attainment():.2f} "
             f"slo_attain_cg={res_cg.slo_attainment():.2f}")


if __name__ == "__main__":
    run()
