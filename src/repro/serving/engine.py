"""Real-execution disaggregated serving engine (CPU, tiny reference model).

A faithful miniature of the paper's vLLM integration: a prefill worker
produces real KV, the KV crosses a (simulated-bandwidth) link as *actual
compressed bytes* chosen by the Service-Aware Controller, and a decode
worker decompresses and generates.  Used by the e2e example and the
integration tests — every byte on the "wire" is real pipeline output.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.controller import Decision, ServiceAwareController, ServiceContext
from repro.core.pipeline import CompressionPipeline
from repro.core.profiles import Profile
from repro.core.quality import (
    _greedy_decode,
    _jitted_steps,
    _prompts_for,
    extract_kv,
    get_reference_model,
    inject_kv,
)
from repro.core.strategy import StrategyConfig, is_identity
from repro.data.tokenizer import ByteTokenizer
from repro.serving.network import BandwidthTrace, GoodputEstimator


@dataclass
class ServedBatch:
    workload: str
    text: List[str]
    tokens: np.ndarray
    profile: str
    kv_bytes: int
    wire_bytes: int
    t_prefill: float
    t_compress: float
    t_comm: float
    t_decompress: float
    t_decode: float
    agreement: float  # vs uncompressed decode

    @property
    def jct(self) -> float:
        return (self.t_prefill + self.t_compress + self.t_comm
                + self.t_decompress + self.t_decode)


class DisaggregatedEngine:
    """PD-separated serving of the tiny reference model with real
    compression on the KV path."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 seq: int = 192, decode_tokens: int = 20, batch: int = 4):
        self.cfg, self.params = get_reference_model()
        self.controller = controller
        self.static_profile = static_profile
        self.seq = seq
        self.decode_tokens = decode_tokens
        self.batch = batch
        self.estimator = GoodputEstimator()
        self._pre, self._dec = _jitted_steps(
            self.cfg.name, seq, batch, seq + decode_tokens + 2)
        self.tok = ByteTokenizer()

    # ------------------------------------------------------------------
    def serve(self, workload: str, trace: BandwidthTrace, now: float = 0.0,
              t_slo: float = 0.0, q_min: float = 0.97, seed: int = 0
              ) -> ServedBatch:
        tokens, _ = _prompts_for(workload, self.batch, self.seq, seed)

        # ---- prefill worker ----
        t0 = time.perf_counter()
        logits, caches = self._pre(self.params, {"tokens": tokens})
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        first = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

        # reference decode for agreement scoring
        ref_toks = _greedy_decode(self._dec, self.params, caches, first,
                                  self.seq, self.decode_tokens)

        # ---- controller decision ----
        kvs = [extract_kv(self.cfg, caches, b, upto=self.seq)
               for b in range(self.batch)]
        v_bytes = sum(kv.nbytes_wire() for kv in kvs)
        ctx = ServiceContext(workload=workload,
                             bandwidth=self.estimator.estimate,
                             t_slo=t_slo, q_min=q_min, t_model=t_prefill,
                             kv_bytes=v_bytes)
        decision = None
        if self.controller is not None:
            decision = self.controller.select(ctx)
            profile = decision.profile
        elif self.static_profile is not None:
            profile = self.static_profile
        else:
            from repro.core.profiles import IDENTITY_PROFILE
            profile = IDENTITY_PROFILE

        # ---- compress -> wire -> decompress (real bytes) ----
        pipe = CompressionPipeline(profile.strategy)
        t0 = time.perf_counter()
        comps = [pipe.compress(kv) for kv in kvs]
        t_compress = time.perf_counter() - t0
        wire_bytes = sum(c.total_bytes() for c in comps)
        t_comm = trace.transfer_time(now + t_prefill + t_compress, wire_bytes)
        self.estimator.observe(wire_bytes, t_comm)
        t0 = time.perf_counter()
        restored = [pipe.decompress(c) for c in comps]
        t_decompress = time.perf_counter() - t0

        # ---- decode worker ----
        comp_caches = caches
        if not is_identity(profile.strategy):
            for b in range(self.batch):
                comp_caches = inject_kv(self.cfg, comp_caches, b, restored[b])
        t0 = time.perf_counter()
        test_toks = _greedy_decode(self._dec, self.params, comp_caches, first,
                                   self.seq, self.decode_tokens)
        t_decode = time.perf_counter() - t0

        agreement = float((ref_toks == test_toks).mean())
        observed = t_compress + t_comm + t_decompress + ctx.t_model
        if self.controller is not None and decision is not None:
            self.controller.observe(ctx, decision, observed)

        texts = [self.tok.decode(row[1:]) for row in test_toks]
        return ServedBatch(
            workload=workload, text=texts, tokens=test_toks,
            profile=profile.strategy.short_name(), kv_bytes=int(v_bytes),
            wire_bytes=int(wire_bytes), t_prefill=t_prefill,
            t_compress=t_compress, t_comm=t_comm,
            t_decompress=t_decompress, t_decode=t_decode,
            agreement=agreement)
