"""Trace-driven serving grid: scenario x routing x bandwidth (ISSUE 6).

Replays production-shaped traces (:mod:`repro.workloads`) through the
event-driven simulator over a 2x2 per-link topology and reports, per
cell, the full tail block from :mod:`repro.serving.metrics`: p50/p95/p99
TTFT and JCT plus per-SLO-class violation rates (explicit zero/None
reporting for empty classes).

Each scenario's trace is built ONCE per seed and replayed under every
(routing, bandwidth) condition — a controlled comparison: the offered
load is byte-identical across cells, only the network differs.  The
decode-node-1 links run at 1/8th of the cell bandwidth, so "load_aware"
vs "round_robin" is a real decision, not a tie.

Determinism contract: the grid is a pure function of (seed, sizes) — no
wall-clock values enter the JSON, floats are rounded to 6 significant
digits.  The smoke grid is committed at ``BENCH_trace_grid.json``; CI
regenerates it and fails when the committed copy is stale
(``python -m benchmarks.trace_grid --check``).  Refresh with
``python -m benchmarks.trace_grid --smoke --write``.
"""
from __future__ import annotations

import argparse
import json
import math
import os
from typing import Dict, List, Optional

from benchmarks.common import emit, write_json
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving.network import GBPS, BandwidthTrace
from repro.serving.simulator import SimConfig, StaticPolicy
from repro.serving.topology import NetworkTopology
from repro.workloads import TenantSpec, build_trace, default_tenants, \
    replay_simulator

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_trace_grid.json")
SEED = 1234
SLOW_LINK_DIV = 8.0          # decode node 1 is behind 1/8th-rate links
N_PREFILL, N_DECODE = 2, 2

SCENARIOS: Dict[str, List[TenantSpec]] = {
    "mixed": default_tenants(rate_scale=1.0),
    "chat": [TenantSpec(name="chat", scenario="chat", rate=4.0,
                        arrival="diurnal")],
    "rag": [TenantSpec(name="rag", scenario="rag", rate=1.5)],
    "agentic": [TenantSpec(name="agents", scenario="agentic", rate=0.8,
                           arrival="mmpp")],
}

SMOKE_GRID = dict(scenarios=("mixed", "chat"), gbps=(40.0, 10.0, 5.0),
                  duration=60.0)
FULL_GRID = dict(scenarios=tuple(SCENARIOS),
                 gbps=(100.0, 40.0, 10.0, 5.0, 2.0), duration=600.0)
ROUTINGS = ("round_robin", "load_aware")


def _policy() -> StaticPolicy:
    profile = Profile(
        strategy=StrategyConfig(quantizer="uniform", key_bits=8,
                                value_bits=8, granularity="per_channel"),
        cr=3.5, s_enc=60.0 * GBPS, s_dec=80.0 * GBPS, quality=0.995)
    return StaticPolicy(profile, "static-u8")


def _topology(gbps: float) -> NetworkTopology:
    fast = BandwidthTrace.constant(gbps * GBPS)
    slow = BandwidthTrace.constant(gbps * GBPS / SLOW_LINK_DIV)
    links = {(i, 1): slow for i in range(N_PREFILL)}
    return NetworkTopology.full_mesh(N_PREFILL, N_DECODE, fast,
                                     links=links)


def _round(x, sig: int = 6):
    """Round every float to ``sig`` significant digits, recursively —
    the committed-JSON canonicalization (robust to FMA/library noise)."""
    if isinstance(x, dict):
        return {k: _round(v, sig) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_round(v, sig) for v in x]
    if isinstance(x, bool) or not isinstance(x, float):
        return x
    if x == 0.0 or not math.isfinite(x):
        return x
    return round(x, sig - 1 - int(math.floor(math.log10(abs(x)))))


def build_grid(smoke: bool = True) -> Dict[str, object]:
    spec = SMOKE_GRID if smoke else FULL_GRID
    cells = []
    for scen in spec["scenarios"]:
        trace = build_trace(SCENARIOS[scen], duration=spec["duration"],
                            seed=SEED)
        for gbps in spec["gbps"]:
            for routing in ROUTINGS:
                res = replay_simulator(
                    trace, _policy(),
                    BandwidthTrace.constant(gbps * GBPS),
                    SimConfig(scenario="pd", n_prefill=N_PREFILL,
                              n_decode=N_DECODE, seed=SEED),
                    topology=_topology(gbps), routing=routing)
                cells.append({
                    "scenario": scen, "routing": routing, "gbps": gbps,
                    "trace_events": len(trace),
                    "trace_digest": trace.digest(),
                    "summary": res.summary(),
                })
    return _round({
        "version": 1,
        "smoke": bool(smoke),
        "seed": SEED,
        "grid_cells": len(cells),
        "grid": cells,
    })


def _diff(a, b, path="") -> Optional[str]:
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            d = _diff(a.get(k), b.get(k), f"{path}.{k}")
            if d:
                return d
        return None
    if isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            return f"{path}: length {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            d = _diff(x, y, f"{path}[{i}]")
            if d:
                return d
        return None
    if a != b:
        return f"{path}: {a!r} != {b!r}"
    return None


def check_against_committed(grid: Dict[str, object]) -> None:
    """Fail loudly when the committed BENCH JSON no longer matches what
    the current code produces (the CI staleness gate)."""
    if not os.path.exists(BENCH_PATH):
        raise AssertionError(
            f"{BENCH_PATH} missing — generate it with "
            f"`python -m benchmarks.trace_grid --smoke --write`")
    with open(BENCH_PATH) as f:
        committed = json.load(f)
    d = _diff(_round(committed), grid)
    assert d is None, (
        f"BENCH_trace_grid.json is stale vs the current code at {d}; "
        f"refresh with `python -m benchmarks.trace_grid --smoke --write`")


def _emit_cells(grid: Dict[str, object]) -> None:
    for cell in grid["grid"]:
        s = cell["summary"]
        emit(f"trace_grid/{cell['scenario']}/{cell['routing']}/"
             f"{cell['gbps']}gbps", 0.0,
             f"n={s.get('completed', 0):.0f} "
             f"jct_p95={s.get('jct_p95', float('nan')):.4g} "
             f"ttft_p95={s.get('ttft_p95', float('nan')):.4g} "
             f"viol={s.get('slo_violation_rate', 0.0):.3f}")


def run(smoke: bool = False, write: bool = False, check: bool = False,
        json_path: str = "") -> None:
    grid = build_grid(smoke=smoke or check)
    _emit_cells(grid)
    if smoke or check:
        # Determinism within the process: a second build must be
        # byte-identical (the replay-determinism contract, end to end).
        again = build_grid(smoke=True)
        d = _diff(grid, again)
        assert d is None, f"trace grid is non-deterministic at {d}"
        # Routing sanity on the heterogeneous mesh: load-aware must not
        # lose to round-robin on p95 JCT in the congested mixed cell.
        by_key = {(c["scenario"], c["routing"], c["gbps"]): c["summary"]
                  for c in grid["grid"]}
        scen = grid["grid"][0]["scenario"]
        low_bw = min(c["gbps"] for c in grid["grid"])
        la = by_key[(scen, "load_aware", low_bw)]["jct_p95"]
        rr = by_key[(scen, "round_robin", low_bw)]["jct_p95"]
        assert la <= rr * 1.05, (
            f"load-aware routing lost to round-robin on the slow mesh: "
            f"p95 JCT {la:.3f}s vs {rr:.3f}s")
    if write:
        with open(BENCH_PATH, "w") as f:
            json.dump(grid, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {BENCH_PATH}")
    elif smoke or check:
        check_against_committed(grid)
    if json_path:
        write_json(json_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + determinism/staleness checks")
    ap.add_argument("--check", action="store_true",
                    help="regenerate the smoke grid and fail if the "
                         "committed BENCH_trace_grid.json is stale")
    ap.add_argument("--write", action="store_true",
                    help="refresh the committed BENCH_trace_grid.json "
                         "(smoke grid only)")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    run(smoke=args.smoke or args.write, write=args.write,
        check=args.check, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
