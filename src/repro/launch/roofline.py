"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e-class, per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI per link      : ~50 GB/s

Terms (seconds, per device — the compiled module is the per-device SPMD
program, so cost_analysis numbers are already per-chip):
  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = collective_bytes / ICI_BW
collective_bytes is parsed from the post-SPMD HLO text (sum of operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops) — it is NOT in cost_analysis.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 0.125,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+)?)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_INSTR_RE = re.compile(r"^(%[\w.\-]+)\s*=\s*(.+)$")
_OPND_RE = re.compile(r"\(([^)]*)\)")
_NAME_RE = re.compile(r"%[\w.\-]+")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes entering each collective kind (operand sizes).

    Post-SPMD HLO references operands by name (``all-reduce(%dot.1)``), so we
    first build a symbol table of every instruction's result bytes, then sum
    operand sizes for each collective (falling back to the collective's own
    result shape when an operand is unknown)."""
    sizes: Dict[str, float] = {}
    coll_lines = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ROOT "):
            stripped = stripped[len("ROOT "):]
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # Result shapes: all dtype[dims] tokens before the op name's paren.
        head = rhs.split("(", 1)[0]
        rbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        sizes[name] = rbytes
        opm = re.match(r"^\s*(?:\([^)]*\)|\S+)\s+([a-z\-]+[a-z0-9.\-]*)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        kind = next((k for k in _COLLECTIVES
                     if op == k or op.startswith(k + ".") or
                     op.startswith(k + "-start")), None)
        if kind is not None:
            operands = _OPND_RE.search(rhs[opm.end() - 1:])
            names = _NAME_RE.findall(operands.group(1)) if operands else []
            coll_lines.append((kind, names, rbytes))

    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    seen_starts = set()
    for kind, names, rbytes in coll_lines:
        opnd = sum(sizes.get(n, 0.0) for n in names)
        out[kind] += opnd if opnd > 0 else rbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float           # per device
    hlo_bytes: float           # per device
    coll_bytes: float          # per device
    coll_breakdown: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float         # global useful FLOPs (6*N*D)
    useful_ratio: float        # model_flops / (hlo_flops * chips)
    mem_per_device: Optional[float] = None  # bytes (args+outputs+temps)
    fits_hbm: Optional[bool] = None
    note: str = ""

    def terms(self) -> Dict[str, float]:
        return {"compute": self.t_compute, "memory": self.t_memory,
                "collective": self.t_collective}

    def to_json(self) -> str:
        return json.dumps(asdict(self))


def _cost_get(cost: Dict, key: str) -> float:
    if key in cost:
        return float(cost[key])
    total = 0.0
    for k, v in cost.items():
        if k.startswith(key):
            total += float(v)
    return total


def analyze(
    compiled,
    lowered_text: str,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float,
    hbm_per_chip: float = 16e9,  # v5e
) -> RooflineReport:
    # Trip-count-aware HLO walk (XLA's cost_analysis counts while bodies
    # once — see launch/hlo_cost.py).  Falls back to cost_analysis if the
    # text walk fails.
    try:
        from repro.launch.hlo_cost import analyze_hlo_text
        walked = analyze_hlo_text(lowered_text)
        flops = walked.flops
        bytes_accessed = walked.bytes
        coll = dict(walked.coll_breakdown)
        for k in _COLLECTIVES:
            coll.setdefault(k, 0.0)
        coll["total"] = walked.coll_bytes
    except Exception:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older API returns [dict]
            cost = cost[0] if cost else {}
        flops = _cost_get(cost, "flops")
        bytes_accessed = _cost_get(cost, "bytes accessed")
        coll = collective_bytes(lowered_text)

    mem = None
    fits = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes)
            fits = mem <= hbm_per_chip
    except Exception:
        pass

    t_c = flops / PEAK_FLOPS
    t_m = bytes_accessed / HBM_BW
    t_x = coll["total"] / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                   key=lambda kv: kv[1])[0]
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_accessed, coll_bytes=coll["total"],
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dominant,
        model_flops=model_flops, useful_ratio=useful,
        mem_per_device=mem, fits_hbm=fits,
    )


def model_flops_for(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch
    new tokens; train adds the backward 2x (6ND already includes fwd+bwd:
    2ND fwd + 4ND bwd)."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        d_tokens = seq * batch
        return 6.0 * n_active * d_tokens
    if shape_kind == "prefill":
        d_tokens = seq * batch
        return 2.0 * n_active * d_tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch
