"""Transform stage: exact (float-exact) invertibility + structure."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.transforms import (
    apply_transform,
    hadamard_matrix,
    invert_transform,
    transform_meta_bytes,
)


@pytest.mark.parametrize("name", ["none", "delta", "hadamard", "affine"])
def test_roundtrip(name):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, 2, 96, 64)).astype(np.float32)
    y, ctx = apply_transform(name, x, delta_group=16)
    x2 = invert_transform(y, ctx)
    np.testing.assert_allclose(x2, x, atol=2e-5, rtol=1e-5)


def test_hadamard_orthonormal():
    for n in (8, 64, 128):
        h = hadamard_matrix(n)
        np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-5)


def test_hadamard_pads_non_pow2():
    x = np.random.default_rng(1).standard_normal((2, 2, 16, 48)).astype(np.float32)
    y, ctx = apply_transform("hadamard", x)
    assert y.shape[-1] == 64 and ctx["pad_dim"] == 64
    np.testing.assert_allclose(invert_transform(y, ctx), x, atol=2e-5)


def test_hadamard_spreads_outliers():
    """The point of the rotation: outlier channel energy spreads out."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((1, 1, 256, 64)).astype(np.float32)
    x[..., 7] *= 50.0  # one outlier channel
    y, _ = apply_transform("hadamard", x)
    ratio_before = np.abs(x).max(axis=(0, 1, 2)).max() / np.abs(x).mean()
    ratio_after = np.abs(y).max(axis=(0, 1, 2)).max() / np.abs(y).mean()
    assert ratio_after < ratio_before / 2


def test_delta_reduces_range_on_smooth_data():
    t = np.linspace(0, 1, 128, dtype=np.float32)
    x = np.broadcast_to(np.sin(t * 4)[None, None, :, None],
                        (2, 2, 128, 32)).copy()
    y, ctx = apply_transform("delta", x, delta_group=16)
    assert np.abs(y).max() < np.abs(x).max()
    assert transform_meta_bytes(ctx) > 0


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    group=st.sampled_from([8, 16, 64]),
    seq=st.integers(4, 80),
    dim=st.sampled_from([8, 32, 64]),
)
def test_delta_roundtrip_property(seed, group, seq, dim):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((2, 1, seq, dim)) * 10).astype(np.float32)
    y, ctx = apply_transform("delta", x, delta_group=group)
    np.testing.assert_allclose(invert_transform(y, ctx), x, atol=1e-5)
