"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig13,...] [--smoke]``
prints ``name,us_per_call,derived`` CSV rows.

``--all --smoke`` executes EVERY registered benchmark's smoke path
(CI-sized settings; each suite's deterministic asserts still run, so a
crash or a violated acceptance bound fails the harness).  ``--json PATH``
archives every emitted row for the CI artifact.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback


SUITES = [
    ("fig5_strategy_space", "benchmarks.strategy_space"),
    ("fig4_kv_latency_thresholds", "benchmarks.kv_latency_thresholds"),
    ("fig8_profiling_stability", "benchmarks.profiling_stability"),
    ("fig9_16l_bo_convergence", "benchmarks.bo_convergence"),
    ("fig10_pareto_frontier", "benchmarks.pareto_frontier"),
    ("tab1_acc_cr", "benchmarks.acc_cr_table"),
    ("fig13_jct_vs_bandwidth", "benchmarks.jct_vs_bandwidth"),
    ("fig14_ttft_prefix_caching", "benchmarks.ttft_prefix_caching"),
    ("fig15_latency_breakdown", "benchmarks.latency_breakdown"),
    ("fig16r_online_adaptivity", "benchmarks.online_adaptivity"),
    ("fig12_hardware_tiers", "benchmarks.hardware_tiers"),
    ("serving_continuous_batching", "benchmarks.continuous_batching"),
    ("serving_tiered_kv", "benchmarks.tiered_kv"),
    ("serving_cluster_scaling", "benchmarks.cluster_scaling"),
    ("serving_sim_speed", "benchmarks.sim_speed"),
    ("serving_trace_grid", "benchmarks.trace_grid"),
    ("serving_paged_arena", "benchmarks.paged_arena"),
    ("serving_speculative_decode", "benchmarks.speculative_decode"),
    ("kernels", "benchmarks.kernel_throughput"),
    ("roofline", "benchmarks.roofline"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list of suite prefixes")
    ap.add_argument("--all", action="store_true",
                    help="run every registered suite (explicit form of the "
                         "default; combine with --smoke for the CI sweep)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings for every suite that supports "
                         "them; a crash or violated assert fails the run")
    ap.add_argument("--skip", default="",
                    help="comma list of suite prefixes to leave out (CI "
                         "uses this to avoid re-running suites already "
                         "executed as dedicated steps)")
    ap.add_argument("--json", default="",
                    help="archive all emitted rows to this JSON path")
    args = ap.parse_args(argv)
    if args.all and args.only:
        ap.error("--all and --only are mutually exclusive")
    only = [s for s in args.only.split(",") if s]
    skip = [s for s in args.skip.split(",") if s]

    print("name,us_per_call,derived")
    failures = 0
    for name, module in SUITES:
        if only and not any(name.startswith(o) or o in name for o in only):
            continue
        if skip and any(name.startswith(s) or s in name for s in skip):
            print(f"# suite {name} skipped (--skip)")
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            kwargs = {}
            if args.smoke and \
                    "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            mod.run(**kwargs)
            print(f"# suite {name} done in {time.time()-t0:.1f}s")
        except Exception as e:
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
