"""Cross-pod KV migration as a *compiled collective* with fused compression.

The paper moves KV prefill->decode over NCCL outside the compiler.  The
TPU-native adaptation expresses PD migration as ``shard_map`` +
``lax.ppermute`` over the ``pod`` mesh axis, with the strategy's quantizer
fused in: quantize+pack on the source pod, permute the int payload + fp16
scales, dequantize on the destination.  The collective term of the roofline
drops by ~16/bits versus shipping BF16 — measured directly in the dry-run
HLO (EXPERIMENTS.md §Perf).

This is the beyond-paper integration of the paper's own insight (DESIGN.md
§7.1): the compiler schedules the quantize->permute->dequant chain and can
overlap it with decode compute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distribution.sharding import cache_pspecs


# ---------------------------------------------------------------------------
# Device-side symmetric group quantization (jnp; also used by the kernels'
# reference path).
# ---------------------------------------------------------------------------
def quantize_sym(x: jnp.ndarray, bits: int, group: int):
    """Per-group symmetric quant along the last axis.  Returns (codes int8,
    scales f16).  Last dim must be divisible by group."""
    d = x.shape[-1]
    assert d % group == 0, (d, group)
    qmax = (1 << (bits - 1)) - 1
    xg = x.reshape(x.shape[:-1] + (d // group, group)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-8)
    q = jnp.clip(jnp.round(xg / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q.reshape(x.shape), scale.squeeze(-1).astype(jnp.float16)


def dequantize_sym(q: jnp.ndarray, scale: jnp.ndarray, group: int,
                   dtype=jnp.bfloat16):
    d = q.shape[-1]
    qg = q.reshape(q.shape[:-1] + (d // group, group)).astype(jnp.float32)
    x = qg * scale[..., None].astype(jnp.float32)
    return x.reshape(q.shape).astype(dtype)


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """int8 codes in [-8, 7] -> packed uint8 (last dim halved)."""
    u = (q.astype(jnp.int32) + 8).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(p: jnp.ndarray) -> jnp.ndarray:
    lo = (p & jnp.uint8(0x0F)).astype(jnp.int32) - 8
    hi = (p >> jnp.uint8(4)).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(p.shape[:-1] + (p.shape[-1] * 2,)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# The transfer step.
# ---------------------------------------------------------------------------
def make_kv_transfer(mesh: Mesh, cache_example, bits: int = 4,
                     group: int = 64):
    """Build a jit'd KV migration: every pod ships its cache shard to the
    next pod (PD pairs are bidirectional for pod=2).

    bits=16 is the uncompressed BF16 baseline; bits in {8, 4} use the fused
    quantizer.  Returns ``fn(cache) -> cache``."""
    assert "pod" in mesh.axis_names, "multi-pod mesh required"
    npod = mesh.shape["pod"]
    perm = [(i, (i + 1) % npod) for i in range(npod)]
    specs = cache_pspecs(cache_example, mesh)

    def xfer_leaf(x):
        if x.ndim < 2 or bits >= 16:
            return jax.lax.ppermute(x, "pod", perm)
        g = min(group, x.shape[-1])
        # bypass tiny/odd trailing dims (e.g. conv states (.., k-1=3)):
        # int4 nibble packing needs even groups, and the payload is noise
        if x.shape[-1] % g or (bits == 4 and g % 2):
            return jax.lax.ppermute(x, "pod", perm)
        q, scale = quantize_sym(x, bits, g)
        if bits == 4:
            q = pack_int4(q)
        q = jax.lax.ppermute(q, "pod", perm)
        scale = jax.lax.ppermute(scale, "pod", perm)
        if bits == 4:
            q = unpack_int4(q)
        return dequantize_sym(q, scale, g, dtype=x.dtype)

    def body(cache):
        return jax.tree_util.tree_map(xfer_leaf, cache)

    # check_vma=False: with batch=1 cells (long_500k) the pod axis doesn't
    # appear in the value specs, and replication can't be statically
    # inferred through ppermute.
    from repro.utils.compat import shard_map_compat
    mapped = shard_map_compat(body, mesh=mesh, in_specs=(specs,),
                              out_specs=specs, check=False)
    return jax.jit(mapped), specs


def transfer_wire_bytes(cache_example, bits: int, group: int = 64) -> int:
    """Bytes that cross the pod boundary per transfer (whole cache)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(cache_example):
        n = int(np.prod(leaf.shape))
        g = min(group, leaf.shape[-1]) if leaf.ndim >= 2 else 0
        if bits >= 16 or leaf.ndim < 2 or leaf.shape[-1] % g \
                or (bits == 4 and g % 2):
            total += n * 2  # bf16
        else:
            total += n * bits // 8 + (n // g) * 2  # codes + f16 scales
    return total
