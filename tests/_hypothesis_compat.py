"""Property-testing shim: real ``hypothesis`` when installed (the ``dev``
packaging extra), otherwise a deterministic random-sampling fallback so the
suite still collects and runs in minimal environments.

The fallback draws ``max_examples`` pseudo-random cases from a fixed seed —
weaker than hypothesis (no shrinking, no edge-case bias) but it exercises the
same assertions over the same input domains.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # fallback sampler
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    def settings(max_examples=25, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def runner():
                n = getattr(runner, "_max_examples", 25)
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(n):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
