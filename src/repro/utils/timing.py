"""Lightweight timing helpers used by profile measurement and benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating wall-clock timer (seconds)."""

    total: float = 0.0
    count: int = 0
    _start: float = field(default=0.0, repr=False)

    def start(self) -> None:
        self._start = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._start
        self.total += dt
        self.count += 1
        return dt

    @property
    def mean(self) -> float:
        return self.total / max(self.count, 1)


@contextmanager
def timed(timer: Timer):
    timer.start()
    try:
        yield timer
    finally:
        timer.stop()
