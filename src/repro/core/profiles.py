"""Profiles: the paper's ``p = (cr_p, s_p, q_p)`` triple + measurement.

``measure_profile`` runs the real pipeline on sample KV caches and returns
measured compression ratio (bytes, metadata included), encode/decode
throughputs (bytes/s of *uncompressed* KV processed, matching the paper's
definition so that enc+dec time == V/s_p), and a quality score per workload
when a quality function is provided.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.kvcache import KVCache
from repro.core.pipeline import CompressionPipeline
from repro.core.strategy import StrategyConfig, is_identity


def harmonic_throughput(s_enc: float, s_dec: float) -> float:
    """s_p = (1/s_enc + 1/s_dec)^-1 so that V/s_enc + V/s_dec = V/s_p."""
    if math.isinf(s_enc) and math.isinf(s_dec):
        return float("inf")
    return 1.0 / (1.0 / s_enc + 1.0 / s_dec)


@dataclass
class Profile:
    """Measured operating point of one strategy."""

    strategy: StrategyConfig
    cr: float  # compression ratio (>= includes metadata)
    s_enc: float  # bytes/s of uncompressed KV through the encoder
    s_dec: float  # bytes/s through the decoder
    quality: Dict[str, float] = field(default_factory=dict)  # per workload
    mse: float = 0.0

    @property
    def s_eff(self) -> float:
        return harmonic_throughput(self.s_enc, self.s_dec)

    def q(self, workload: str) -> float:
        if not self.quality:
            return 1.0
        if workload in self.quality:
            return self.quality[workload]
        return float(np.mean(list(self.quality.values())))

    def to_json(self) -> str:
        d = asdict(self)
        d["strategy"] = self.strategy.to_json()
        return json.dumps(d)

    @staticmethod
    def from_json(s: str) -> "Profile":
        d = json.loads(s)
        d["strategy"] = StrategyConfig.from_json(d["strategy"])
        return Profile(**d)


IDENTITY_PROFILE = Profile(
    strategy=StrategyConfig(key_bits=16, value_bits=16),
    cr=1.0, s_enc=float("inf"), s_dec=float("inf"), quality={}, mse=0.0,
)


def measure_profile(
    strategy: StrategyConfig,
    kv_samples: Sequence[KVCache],
    quality_fn: Optional[Callable[[StrategyConfig], Dict[str, float]]] = None,
    head_scores: Optional[np.ndarray] = None,
    repeats: int = 1,
) -> Profile:
    """Run the pipeline end-to-end on sample caches and measure (cr, s, q)."""
    pipe = CompressionPipeline(strategy, head_scores=head_scores)
    total_orig = 0
    total_comp = 0
    enc_time = 0.0
    dec_time = 0.0
    sq_err = 0.0
    n_elem = 0
    for kv in kv_samples:
        for _ in range(repeats):
            restored, comp, t_enc, t_dec = pipe.roundtrip(kv)
            enc_time += t_enc
            dec_time += t_dec
        total_orig += kv.nbytes_wire()
        total_comp += comp.total_bytes()
        sq_err += float(((restored.k - kv.k) ** 2).sum() + ((restored.v - kv.v) ** 2).sum())
        n_elem += kv.k.size + kv.v.size

    reps = max(repeats * len(kv_samples), 1)
    v_bytes = total_orig * repeats  # uncompressed bytes pushed through
    s_enc = v_bytes / enc_time if enc_time > 0 else float("inf")
    s_dec = v_bytes / dec_time if dec_time > 0 else float("inf")
    if is_identity(strategy):
        s_enc = s_dec = float("inf")

    quality = quality_fn(strategy) if quality_fn is not None else {}
    return Profile(
        strategy=strategy,
        cr=total_orig / max(total_comp, 1),
        s_enc=s_enc,
        s_dec=s_dec,
        quality=quality,
        mse=sq_err / max(n_elem, 1),
    )


def save_profiles(profiles: List[Profile], path: str) -> None:
    with open(path, "w") as f:
        for p in profiles:
            f.write(p.to_json() + "\n")


def load_profiles(path: str) -> List[Profile]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(Profile.from_json(line))
    return out
