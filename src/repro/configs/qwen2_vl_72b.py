"""Config alias for --arch qwen2-vl-72b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("qwen2-vl-72b")
