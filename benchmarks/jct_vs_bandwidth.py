"""Paper Fig. 13 (and Fig. 1): JCT across bandwidths in PD separation.

Compares Default(BF16) / CacheGen / KIVI / KVServe over 5-100 Gbps-scale
effective bandwidths (scaled to the simulator's calibrated throughputs).
Derived column: mean JCT seconds and speedup over default.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached_profiles, emit, time_call
from repro.controller import ServiceAwareController
from repro.data.synthetic import WORKLOADS
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    NoCompressionPolicy,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)

BANDWIDTHS_GBPS = (0.05, 0.1, 0.25, 0.5, 1.0, 5.0, 25.0, 100.0)


def run() -> None:
    profiles = cached_profiles()
    by_name = {p.strategy.short_name(): p for p in profiles}
    cachegen = next(p for n, p in by_name.items() if "cachegen" in n)
    kivi = next(p for n, p in by_name.items() if "kivi" in n)

    reqs = lambda: WorkloadMix(rate=2.0, seed=0, q_min=0.0).generate(40)

    for bw in BANDWIDTHS_GBPS:
        trace = BandwidthTrace.constant(bw * GBPS)
        res = {}
        t0 = __import__("time").perf_counter()
        res["default"] = Simulator(SimConfig(), NoCompressionPolicy(), trace,
                                   reqs()).run().mean_jct()
        res["cachegen"] = Simulator(SimConfig(), StaticPolicy(cachegen, "cg"),
                                    trace, reqs()).run().mean_jct()
        res["kivi"] = Simulator(SimConfig(), StaticPolicy(kivi, "kivi"),
                                trace, reqs()).run().mean_jct()
        controller = ServiceAwareController({w: profiles for w in WORKLOADS})
        res["kvserve"] = Simulator(SimConfig(), KVServePolicy(controller),
                                   trace, reqs()).run().mean_jct()
        elapsed = (__import__("time").perf_counter() - t0) * 1e6
        speedup = res["default"] / res["kvserve"]
        emit(f"fig13_jct_bw{bw}gbps", elapsed,
             f"default={res['default']:.2f}s cachegen={res['cachegen']:.2f}s "
             f"kivi={res['kivi']:.2f}s kvserve={res['kvserve']:.2f}s "
             f"speedup={speedup:.2f}x")


if __name__ == "__main__":
    run()
