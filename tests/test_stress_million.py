"""Million-request trace replay (ISSUE 6 stress path).

Opt-in (`pytest -m stress`; excluded from the default run by
``addopts``): builds a ~1M-event production trace and replays it through
the simulator's fast PD path, checking the properties that matter at
scale — full completion, the breakdown accounting identity on a sample,
and an events/s floor that would catch a hot-path regression the small
suite can't see.
"""
import time

import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import BandwidthTrace, GBPS
from repro.serving.simulator import SimConfig, Simulator, StaticPolicy
from repro.workloads import scaled_trace, trace_requests

N_EVENTS = 1_000_000
MIN_EVENTS_PER_S = 500_000       # optimized path runs ~2.7M+/s on 1 CPU
EVENTS_PER_REQUEST = 5           # arrival/prefill/transfer/decode/complete


@pytest.mark.stress
def test_million_request_replay_completes_fast():
    trace = scaled_trace(N_EVENTS, seed=0)
    assert 0.5 * N_EVENTS <= len(trace) <= 2.0 * N_EVENTS
    policy = StaticPolicy(
        Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                               value_bits=8, granularity="per_channel"),
                cr=3.5, s_enc=60.0 * GBPS, s_dec=80.0 * GBPS), "u8")
    sim = Simulator(SimConfig(scenario="pd", n_prefill=4, n_decode=2,
                              straggler_sigma=0.1, seed=0),
                    policy, BandwidthTrace.constant(10 * GBPS),
                    trace_requests(trace))
    assert sim._fast_pd_eligible()
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    done = res.completed()
    assert len(done) == len(trace)
    eps = len(done) * EVENTS_PER_REQUEST / wall
    assert eps >= MIN_EVENTS_PER_S, \
        f"{eps:,.0f} events/s < {MIN_EVENTS_PER_S:,} floor ({wall:.1f}s)"
    for r in done[:: max(len(done) // 1000, 1)]:     # ~1k sample
        assert abs(sum(r.breakdown.values()) - r.jct) < 1e-6
        assert 0 < r.ttft <= r.jct + 1e-12
