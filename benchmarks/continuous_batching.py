"""Continuous-batching serving runtime (DESIGN.md §9, EXPERIMENTS.md
§Serving): offered load × SLO mix × store capacity.

Part A drives the *real-execution* ServingRuntime (tiny model, real
compressed bytes, modelled loaded-cluster compute) and checks the two
acceptance properties: ≥4 concurrent in-flight requests, and prefix-pool
hits beating cold prefill on TTFT.

Part B sweeps the event-driven simulator through the same shared
scheduler/store code path at scale.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import (
    GBPS,
    BandwidthTrace,
    NoCompressionPolicy,
    PrefixKVStore,
    SchedulerConfig,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)


def _pool_profile() -> Profile:
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel",
                                  codec="zstd3"),
                   cr=3.0, s_enc=5e8, s_dec=5e8)


# ---------------------------------------------------------------------------
def run_runtime() -> None:
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    cfg = RuntimeConfig(seq=96, decode_tokens=8,
                        prefill_tok_s=2000.0, decode_tok_s=500.0)
    rt = ServingRuntime(
        static_profile=_pool_profile(), config=cfg,
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=64))
    # 12 requests over 4 workloads; repeated prompt seeds => pool hits.
    t0 = time.perf_counter()
    for i, w in enumerate(("qalike", "codelike", "mathlike", "summlike") * 3):
        rt.submit(w, slo_class=("interactive", "standard", "batch")[i % 3],
                  prompt_seed=i % 4)
        rt.step()
    rt.run()
    us = (time.perf_counter() - t0) * 1e6
    s = rt.summary()
    assert s["max_in_flight"] >= 4, s
    assert s["mean_ttft_hit"] < s["mean_ttft_cold"], s
    emit("runtime_continuous_batching", us,
         f"completed={s['completed']} max_in_flight={s['max_in_flight']} "
         f"pool_hit_rate={s['pool_hit_rate']:.2f} "
         f"ttft_hit={s['mean_ttft_hit']*1e3:.1f}ms "
         f"ttft_cold={s['mean_ttft_cold']*1e3:.1f}ms "
         f"speedup={s['mean_ttft_cold']/s['mean_ttft_hit']:.1f}x")


# ---------------------------------------------------------------------------
def run_sweep() -> None:
    # 4-bit + zstd pool profile: a fetch moves ~1/6 of the KV bytes.
    prof = Profile(StrategyConfig(quantizer="uniform", key_bits=4,
                                  value_bits=4, granularity="per_channel",
                                  codec="zstd3"),
                   cr=6.0, s_enc=1e9, s_dec=1e9)
    trace = BandwidthTrace.constant(1 * GBPS)
    mixes = {
        "uniform": None,
        "tiered": {"interactive": 0.3, "standard": 0.4, "batch": 0.3},
    }
    # 4 prefill nodes x 2000 tok/s over ~4k-token prompts => capacity
    # ~2 req/s: the rates bracket under-load, saturation, and overload.
    for rate in (0.5, 2.0, 8.0):
        for mix_name, mix in mixes.items():
            for cap_name, cap in (("small", int(5e8)), ("large", 1 << 36)):
                reqs = WorkloadMix(rate=rate, seed=11, q_min=0.0,
                                   ctx_scale=0.25, prefix_hit_rate=0.7,
                                   slo_class_mix=mix).generate(120)
                store = PrefixKVStore(capacity_bytes=cap, block=1)
                t0 = time.perf_counter()
                res = Simulator(
                    SimConfig(scenario="pool", prefill_tok_s=2000.0),
                    StaticPolicy(prof, "pool"), trace, reqs, store=store,
                    scheduler=SchedulerConfig(max_queue=40),
                ).run()
                us = (time.perf_counter() - t0) * 1e6
                done = res.completed()
                # Three-way: full hits (fetch only), partial hits (fetch +
                # top-up prefill for the uncovered suffix), cold recomputes.
                fetched = lambda r: r.breakdown.get("comm", 0) > 0
                refill = lambda r: r.breakdown.get("prefill", 0) > 0
                hits = [r for r in done if fetched(r) and not refill(r)]
                partial = [r for r in done if fetched(r) and refill(r)]
                colds = [r for r in done if refill(r) and not fetched(r)]
                mean = lambda rs: (float(np.mean([r.ttft for r in rs]))
                                   if rs else 0.0)
                emit(f"sweep_rate{rate:g}_{mix_name}_{cap_name}", us,
                     f"hit_rate={store.stats.hit_rate:.2f} "
                     f"evictions={store.stats.evictions} "
                     f"rejected={len(res.rejected())} "
                     f"ttft_hit={mean(hits):.3f}s "
                     f"ttft_partial={mean(partial):.3f}s(n={len(partial)}) "
                     f"ttft_cold={mean(colds):.3f}s "
                     f"p95_ttft={np.percentile(res.ttft(), 95):.3f}s")


def run() -> None:
    run_sweep()
    run_runtime()


if __name__ == "__main__":
    run()
