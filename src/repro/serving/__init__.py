from repro.serving.network import GBPS, BandwidthTrace, GoodputEstimator
from repro.serving.request import Request, WorkloadMix, kv_bytes_for
from repro.serving.simulator import (
    KVServePolicy,
    NoCompressionPolicy,
    Policy,
    SimConfig,
    SimResult,
    Simulator,
    StaticPolicy,
)

__all__ = [
    "GBPS", "BandwidthTrace", "GoodputEstimator", "Request", "WorkloadMix",
    "kv_bytes_for", "KVServePolicy", "NoCompressionPolicy", "Policy",
    "SimConfig", "SimResult", "Simulator", "StaticPolicy",
]
