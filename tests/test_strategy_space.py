"""Strategy space: enumeration, serialization, analytic CR estimates."""
import numpy as np
import pytest

from repro.core import StrategyConfig, enumerate_space, estimate_cr, space_sizes
from repro.core.strategy import BASELINES, IDENTITY_STRATEGY, is_identity


def test_space_growth():
    sizes = space_sizes()
    # Fig. 5-left: pipeline < module < hybrid (~10^4)
    assert sizes["pipeline"] < sizes["module"] < sizes["hybrid"]
    assert sizes["hybrid"] >= 5_000


def test_unique_keys():
    space = enumerate_space("module")
    keys = {c.key() for c in space}
    assert len(keys) == len(space)


def test_json_roundtrip():
    for cfg in list(BASELINES.values()) + [IDENTITY_STRATEGY]:
        assert StrategyConfig.from_json(cfg.to_json()) == cfg


def test_identity_detection():
    assert is_identity(IDENTITY_STRATEGY)
    assert not is_identity(BASELINES["kivi"])


def test_estimate_cr_ordering(kv_sample):
    """Observation 2: analytic estimates order configs like measurements."""
    from repro.core import CompressionPipeline
    cfgs = [
        StrategyConfig(quantizer="uniform", key_bits=b, value_bits=b,
                       granularity="per_head")
        for b in (2, 4, 8)
    ]
    est = [estimate_cr(c) for c in cfgs]
    real = [CompressionPipeline(c).compress(kv_sample).compression_ratio()
            for c in cfgs]
    assert np.argsort(est).tolist() == np.argsort(real).tolist()


def test_estimates_within_factor_two(kv_sample):
    from repro.core import CompressionPipeline
    for name in ("kivi", "mixhq"):
        cfg = BASELINES[name]
        est = estimate_cr(cfg, num_layers=4, kv_heads=4, seq=160, head_dim=64)
        real = CompressionPipeline(cfg).compress(kv_sample).compression_ratio()
        assert 0.5 < est / real < 2.0, (name, est, real)


def test_validate_rejects_bad():
    with pytest.raises(AssertionError):
        StrategyConfig(transform="fft").validate()
    with pytest.raises(AssertionError):
        StrategyConfig(key_bits=0).validate()
