"""The analytical latency model (Sec. 3.2 / 6.1).

  T_p(c)  = T_model(w) + V/s_p + V/(B·cr_p)          (Eq. 1)
  T_0(c)  = T_model(w) + V/B
  B*_p    = (1 - 1/cr_p) · s_p                        (Eq. 5, Theorem 6.1)
  T̃_p(x) = 1/s_p + x/cr_p,  x = 1/B                  (Eq. 6)

Profiles are beneficial iff B < B*_p — independent of V.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.profiles import Profile


@dataclass(frozen=True)
class ServiceContext:
    """c = (w, B, T_SLO, q_min) — Sec. 3.1."""

    workload: str
    bandwidth: float        # effective bytes/s (network or IO goodput)
    t_slo: float            # seconds
    q_min: float            # minimum relative quality
    t_model: float = 0.0    # strategy-independent execution time
    kv_bytes: float = 0.0   # V — uncompressed KV payload of the segment
    # Which latency t_slo bounds ("ttft" | "jct").  The runtime feeds the
    # matching observation through ServiceAwareController.observe, so the
    # bandit's violation cooldown fires on the same metric the serving
    # layer reports as slo_violated.
    slo_metric: str = "jct"
    # Placement route identity ("p0->d1") in a multi-worker cluster: the
    # controller keeps a separate residual bandit per route, so the
    # offline->online drift of EACH link is learned independently (a
    # congested 50 Mbps cross-rack wire and an idle 1 Gbps local link get
    # different residual corrections).  "" = single-link / routeless.
    route: str = ""
    # Decode side runs a paged arena with fused dequant-attention
    # (DESIGN.md §12): paged-eligible profiles skip the materialized
    # decompress, so Eq. 1's s_eff term keeps only its encode half.
    fused_dec: bool = False
    # Strategy-independent serial decode-stream time (out_tokens at the
    # decode worker's per-token rate).  Speculative decoding divides it
    # by the expected committed tokens per verify step (DESIGN.md §15);
    # 0.0 when unknown (the k-selection then ranks on throughput alone).
    decode_time: float = 0.0


def predicted_latency(p: Profile, c: ServiceContext) -> float:
    """T_p(c) per Eq. 1.  Under a fused-dequant decode arena
    (``c.fused_dec``) a paged-eligible profile pays only the encode side
    of the codec: V/s_enc instead of V/s_eff."""
    from repro.core.strategy import paged_eligible

    v = c.kv_bytes
    if c.fused_dec and paged_eligible(p.strategy):
        s_term = 0.0 if p.s_enc == float("inf") else v / p.s_enc
    else:
        s_term = 0.0 if p.s_eff == float("inf") else v / p.s_eff
    return c.t_model + s_term + v / (c.bandwidth * p.cr)


def baseline_latency(c: ServiceContext) -> float:
    return c.t_model + c.kv_bytes / c.bandwidth


def bandwidth_threshold(p: Profile) -> float:
    """B*_p (Theorem 6.1): beneficial iff B < B*_p."""
    if p.cr <= 1.0:
        return 0.0
    if p.s_eff == float("inf"):
        return float("inf")
    return (1.0 - 1.0 / p.cr) * p.s_eff


def is_beneficial(p: Profile, bandwidth: float) -> bool:
    return bandwidth < bandwidth_threshold(p)


def normalized_latency(p: Profile, inv_bandwidth: float) -> float:
    """T̃_p(x) = 1/s_p + x/cr_p (Eq. 6)."""
    s_term = 0.0 if p.s_eff == float("inf") else 1.0 / p.s_eff
    return s_term + inv_bandwidth / p.cr


# ---------------------------------------------------------------------------
# Speculative-decode terms (DESIGN.md §15): the decode-stream analogue of
# Eq. 1's transfer terms.  With draft budget k and per-draft acceptance
# rate r, a greedy verify step commits 1 bonus token plus a geometric
# accepted prefix.
# ---------------------------------------------------------------------------
def expected_tokens_per_step(k: int, accept_rate: float) -> float:
    """E[committed tokens per verify step] = sum_{j=0..k} r^j — one bonus
    token always commits; draft j commits iff all drafts before it did
    (i.i.d. per-draft acceptance r).  k = 0 gives exactly 1.0, the plain
    one-token decode."""
    r = min(max(accept_rate, 0.0), 1.0)
    return sum(r ** j for j in range(max(k, 0) + 1))


def speculative_decode_latency(decode_time: float, k: int,
                               accept_rate: float,
                               verify_overhead: float = 0.0) -> float:
    """Decode-stream time with k-draft speculation: the serial decode
    time shrinks by the expected committed tokens per verify step, while
    each (wider) verify step may carry a relative overhead
    ``verify_overhead`` per draft slot.  Monotone pieces pull against
    each other, so argmin over a candidate set is the k-selection rule
    (the controller breaks latency ties toward smaller k — at
    accept_rate 0 every k collapses to the baseline and k = 0 wins)."""
    tps = expected_tokens_per_step(k, accept_rate)
    return decode_time * (1.0 + verify_overhead * max(k, 0)) / tps


# ---------------------------------------------------------------------------
# Tier-aware fetch term (ISSUE 4): the KV prefix lives in a memory
# hierarchy, so the cost of materializing it depends on WHERE the bytes
# sit and on which encoding crosses that tier's link.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TierFetch:
    """One route for materializing a stored KV prefix.

    ``variant="stored"`` fetches the tier's stored encoding as-is;
    ``variant="reencoded"`` pays a source-side re-encode (``s_enc``) to
    cross the link with fewer bytes — the "refetch smaller" route that
    wins on slow links."""

    tier: str                     # holding tier ("hbm" | "dram" | "remote")
    wire_bytes: float             # bytes that cross the tier's fetch link
    kv_bytes: float               # uncompressed payload V (decode restore)
    bandwidth: float              # tier link effective bytes/s
    overhead: float = 0.0         # per-fetch RPC/setup cost
    s_dec: float = float("inf")   # decode-side decompress throughput
    s_enc: float = float("inf")   # source-side re-encode throughput
    variant: str = "stored"
    # The fetched encoding lands as packed quantized pages consumed by
    # the fused dequant-attention decode (DESIGN.md §12) — no
    # materialized decompress term.
    fused_dequant: bool = False


def tier_fetch_latency(opt: TierFetch) -> float:
    """T_fetch = o + V/s_enc + wire/B_tier + V/s_dec — the tier-aware
    analogue of Eq. 1's transfer term.  ``fused_dequant`` drops the
    V/s_dec term: the pages decode in place."""
    enc = 0.0 if opt.s_enc == float("inf") else opt.kv_bytes / opt.s_enc
    dec = (0.0 if opt.fused_dequant or opt.s_dec == float("inf")
           else opt.kv_bytes / opt.s_dec)
    return (opt.overhead + enc + opt.wire_bytes / max(opt.bandwidth, 1e-9)
            + dec)
