"""Shared infrastructure for the repro static-analysis suite.

The suite is a set of *repo-specific* AST checkers (DESIGN.md §13): each
rule knows this codebase's conventions (virtual-clock accounting, the
`t_*`/`*_bytes` naming scheme, the kernels/ops/ref layout) and flags
violations with a file:line, a rule id, and a fix hint.

Suppression grammar
-------------------
A finding is suppressed by an inline comment on the flagged line or the
line directly above it::

    nxt = np.asarray(nxt)   # lint: sync-ok(single per-iteration token pull)

The general form is ``# lint: <token>(<reason>) [<token>(<reason>) ...]``
where ``<token>`` is the rule's suppression token (``sync-ok``,
``clock-ok``, ``units-ok``, ``kernel-ok``).  The reason is mandatory: an
empty reason or an unknown token is itself a finding (rule
``lint-suppression``) and cannot be suppressed — the tree never goes
green by silencing the linter.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

SUPPRESS_RE = re.compile(r"#\s*lint:\s*(?P<body>.*)$")
ENTRY_RE = re.compile(r"(?P<token>[a-z][a-z0-9-]*)\s*\(\s*(?P<reason>[^()]*?)\s*\)")


@dataclass
class Finding:
    """One checker hit, addressable by file:line."""
    rule: str
    path: str
    line: int
    message: str
    hint: str = ""
    suppressed: bool = False
    reason: str = ""

    def render(self) -> str:
        s = f"{self.path}:{self.line}: [{self.rule}] {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        if self.suppressed:
            s += f"\n    suppressed: {self.reason}"
        return s

    def to_json(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "hint": self.hint,
                "suppressed": self.suppressed, "reason": self.reason}


@dataclass
class SourceFile:
    path: Path                    # absolute
    rel: str                      # display / matching path (posix, relative)
    text: str
    tree: ast.Module
    # line -> {token: reason}; parsed once, applied by the driver
    suppressions: Dict[int, Dict[str, str]] = field(default_factory=dict)

    @property
    def parts(self) -> Sequence[str]:
        return Path(self.rel).parts

    def in_dir(self, name: str) -> bool:
        return name in self.parts


@dataclass
class Project:
    files: List[SourceFile]

    def matching(self, pred: Callable[[SourceFile], bool]) -> List[SourceFile]:
        return [f for f in self.files if pred(f)]


@dataclass(frozen=True)
class Rule:
    id: str
    token: str                    # suppression token, e.g. "sync-ok"
    summary: str
    check: Callable[[Project], List[Finding]]


def parse_suppressions(rel: str, text: str, known_tokens: Iterable[str]
                       ) -> tuple[Dict[int, Dict[str, str]], List[Finding]]:
    """Scan a file's ``# lint:`` comments.  Returns (line -> token ->
    reason, grammar findings).  Malformed entries become findings of the
    un-suppressible ``lint-suppression`` rule."""
    known = set(known_tokens)
    out: Dict[int, Dict[str, str]] = {}
    bad: List[Finding] = []
    comments: List[tuple[int, str]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                comments.append((tok.start[0], tok.string))
    except tokenize.TokenizeError:
        pass  # a parse-error finding is raised by the loader anyway
    for lineno, comment in comments:
        m = SUPPRESS_RE.search(comment)
        if not m:
            continue
        body = m.group("body").strip()
        entries = list(ENTRY_RE.finditer(body))
        leftover = ENTRY_RE.sub("", body).replace(",", "").strip()
        if not entries or leftover:
            bad.append(Finding(
                "lint-suppression", rel, lineno,
                f"malformed suppression comment: {body!r}",
                "use `# lint: <token>(reason)`, e.g. `# lint: sync-ok(...)`"))
            continue
        for e in entries:
            token, reason = e.group("token"), e.group("reason").strip()
            if token not in known:
                bad.append(Finding(
                    "lint-suppression", rel, lineno,
                    f"unknown suppression token {token!r}",
                    f"known tokens: {', '.join(sorted(known))}"))
                continue
            if not reason:
                bad.append(Finding(
                    "lint-suppression", rel, lineno,
                    f"suppression {token}() has no reason",
                    "every suppression must say WHY the pattern is "
                    "intentional"))
                continue
            out.setdefault(lineno, {})[token] = reason
    return out, bad


def iter_python_files(paths: Sequence[str]) -> List[Path]:
    seen: List[Path] = []
    for p in paths:
        root = Path(p)
        if root.is_file() and root.suffix == ".py":
            seen.append(root)
        elif root.is_dir():
            for f in sorted(root.rglob("*.py")):
                if any(part.startswith(".") or part == "__pycache__"
                       for part in f.parts):
                    continue
                seen.append(f)
    return seen


def load_project(paths: Sequence[str], known_tokens: Iterable[str],
                 base: Optional[Path] = None
                 ) -> tuple[Project, List[Finding]]:
    """Parse every .py file under ``paths``.  Unparseable files become
    ``parse-error`` findings (never suppressible)."""
    base = base or Path.cwd()
    files: List[SourceFile] = []
    findings: List[Finding] = []
    tokens = list(known_tokens)
    for path in iter_python_files(paths):
        apath = path.resolve()
        try:
            rel = apath.relative_to(base.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        try:
            text = apath.read_text()
            tree = ast.parse(text, filename=rel)
        except (OSError, SyntaxError) as e:
            findings.append(Finding("parse-error", rel,
                                    getattr(e, "lineno", 1) or 1, str(e)))
            continue
        supp, bad = parse_suppressions(rel, text, tokens)
        findings.extend(bad)
        files.append(SourceFile(apath, rel, text, tree, supp))
    return Project(files), findings


def dotted(node: ast.AST) -> str:
    """'np.asarray' for Attribute/Name chains, '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def func_defs(tree: ast.AST):
    """Yield every (Async)FunctionDef in the tree."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
