"""Paper Fig. 9 + Fig. 16-left: Bayesian engine convergence + ablations
(w/o Enc, w/o Exp, w/o Prune, w/o Stop) vs random search on the hybrid
space with a calibrated synthetic objective (the objective shape is fit to
the measured CR-Acc trade-off so the search dynamics are realistic while
keeping the benchmark CPU-cheap)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.strategy import enumerate_space, estimate_cr
from repro.profiling import BOConfig, run_bo, run_random_search


def _objective(cfg):
    cr = estimate_cr(cfg)
    penalty = 0.0045 * cr**1.4
    if cfg.transform == "hadamard":
        penalty *= 0.8
    if cfg.quantizer == "mixhq":
        penalty *= 0.9
    acc = max(0.0, 1.0 - penalty)
    return acc, cr


def run(smoke: bool = False) -> None:
    # smoke: the module-granularity space and a short budget keep the CI
    # path seconds-cheap while still exercising the full BO loop
    space = enumerate_space("module" if smoke else "hybrid")
    iters = 40 if smoke else 300
    thres = 0.95
    feas = [(c, _objective(c)) for c in space if _objective(c)[0] >= thres]
    true_best = max(v[1] for _, v in feas)

    variants = {
        "full": BOConfig(acc_threshold=thres, max_iters=iters, seed=2),
        "wo_enc": BOConfig(acc_threshold=thres, max_iters=iters, seed=2,
                           use_encoding=False),
        "wo_exp": BOConfig(acc_threshold=thres, max_iters=iters, seed=2,
                           use_exploration=False),
        "wo_prune": BOConfig(acc_threshold=thres, max_iters=iters, seed=2,
                             use_pruning=False),
        "wo_stop": BOConfig(acc_threshold=thres, max_iters=iters, seed=2,
                            use_early_stop=False),
    }
    if smoke:
        variants = {"full": variants["full"]}
    for name, cfg in variants.items():
        t0 = time.perf_counter()
        res = run_bo(space, _objective, cfg)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig16l_bo_{name}", us,
             f"best_cr={res.best_cr():.2f} true={true_best:.2f} "
             f"iters={res.evaluations} "
             f"gap={100*(true_best-res.best_cr())/true_best:.1f}%")

    t0 = time.perf_counter()
    rnd = run_random_search(space, _objective,
                            BOConfig(acc_threshold=thres, max_iters=iters,
                                     seed=2))
    emit("fig16l_random", (time.perf_counter() - t0) * 1e6,
         f"best_cr={rnd.best_cr():.2f} true={true_best:.2f} iters={iters}")

    # Fig 9 headline: search-overhead reduction vs exhaustive profiling.
    full = run_bo(space, _objective, variants["full"])
    emit("fig9_overhead_reduction", 0.0,
         f"exhaustive={len(space)} bo_evals={full.evaluations} "
         f"reduction={len(space)/max(full.evaluations,1):.1f}x")


if __name__ == "__main__":
    run()
