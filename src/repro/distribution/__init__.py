from repro.distribution.optimizer import OptConfig, adamw_update, init_opt_state
from repro.distribution.steps import (
    loss_fn,
    make_decode_step,
    make_eval_step,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "OptConfig", "adamw_update", "init_opt_state", "loss_fn",
    "make_decode_step", "make_eval_step", "make_prefill_step",
    "make_train_step",
]
