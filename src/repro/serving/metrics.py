"""Latency-distribution metrics shared by every serving backend.

Means hide exactly what SLO serving is about: the tail.  This module
computes the p50/p95/p99 TTFT and JCT quantiles plus per-SLO-class
violation rates from any population of finished requests — the
real-execution :class:`~repro.serving.engine.ServingRuntime`, the
multi-worker :class:`~repro.serving.cluster.ClusterRuntime`, and the
event-driven :class:`~repro.serving.simulator.Simulator` all feed their
completions through :func:`latency_summary` so their ``summary()``
outputs are directly comparable.

Requests are duck-typed: anything with ``ttft``, ``jct``, ``slo_class``,
``t_slo`` and ``slo_violated`` attributes works (both
:class:`~repro.serving.request.Request` and the runtime's
``ServedRequest`` qualify).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

import numpy as np

PERCENTILES = (50, 95, 99)


def percentile_row(values: Sequence[float], prefix: str
                   ) -> Dict[str, float]:
    """``{prefix_p50: ..., prefix_p95: ..., prefix_p99: ...}`` (empty when
    there are no values — absent keys beat fabricated zeros)."""
    vals = np.asarray(list(values), dtype=float)
    if vals.size == 0:
        return {}
    return {f"{prefix}_p{p}": float(np.percentile(vals, p))
            for p in PERCENTILES}


def violation_rates(requests: Iterable,
                    classes: Iterable[str] = ()) -> Dict[str, float]:
    """Per-SLO-class violation rates over requests that carry an SLO
    (``t_slo > 0``); ``slo_violation_rate`` is the all-class aggregate.

    ``classes`` forces a rate key for each named class even when no
    completed request of that class carried an SLO — reported as 0.0
    violations rather than silently dropped (a class that was entirely
    shed or starved still shows up in the summary)."""
    with_slo: Dict[str, list] = {cls: [] for cls in classes}
    for r in requests:
        if getattr(r, "t_slo", 0.0) > 0:
            with_slo.setdefault(r.slo_class, []).append(bool(r.slo_violated))
    out: Dict[str, float] = {}
    all_flags = [f for flags in with_slo.values() for f in flags]
    if all_flags:
        out["slo_violation_rate"] = float(np.mean(all_flags))
    for cls, flags in sorted(with_slo.items()):
        out[f"slo_violation_rate_{cls}"] = \
            float(np.mean(flags)) if flags else 0.0
    return out


def route_counts(requests: Iterable) -> Dict[str, float]:
    """``{route_<name>_completed: n}`` over requests that carry a
    placement route — one shared implementation for the cluster runtime
    and the topology-driven simulator."""
    by_route: Dict[str, int] = {}
    for r in requests:
        route = getattr(r, "route", "")
        if route:
            by_route[route] = by_route.get(route, 0) + 1
    return {f"route_{name}_completed": float(n)
            for name, n in sorted(by_route.items())}


def class_latency_blocks(requests: Sequence,
                         classes: Iterable[str] = ()) -> Dict[str, object]:
    """Per-SLO-class tail blocks: completed count plus TTFT/JCT
    p50/p95/p99 for every class observed among ``requests`` or named in
    ``classes``.  Edge cases are explicit, never NaN:

    * 0 completed in a class -> ``completed_<cls>`` is 0.0 and every
      percentile key is present with value ``None`` (the class is
      reported, not dropped);
    * 1 completed -> all three percentiles equal that request's latency.
    """
    by_cls: Dict[str, list] = {}
    for r in requests:
        by_cls.setdefault(getattr(r, "slo_class", "standard"), []).append(r)
    out: Dict[str, object] = {}
    for cls in sorted(set(classes) | set(by_cls)):
        rs = by_cls.get(cls, [])
        out[f"completed_{cls}"] = float(len(rs))
        if rs:
            out.update(percentile_row([r.ttft for r in rs], f"ttft_{cls}"))
            out.update(percentile_row([r.jct for r in rs], f"jct_{cls}"))
        else:
            for p in PERCENTILES:
                out[f"ttft_{cls}_p{p}"] = None
                out[f"jct_{cls}_p{p}"] = None
    return out


def speculation_stats(requests: Iterable,
                      classes: Iterable[str] = ()) -> Dict[str, float]:
    """Speculative-decode acceptance block (DESIGN.md §15), duck-typed on
    ``verify_steps`` / ``spec_committed`` / ``drafts_offered`` /
    ``drafts_accepted`` (requests without them — e.g. simulator records —
    contribute nothing).  Emitted only when at least one request actually
    took a verify step, so non-speculative summaries are unchanged:

    * ``spec_tokens_per_step``       — committed tokens per verify step,
      aggregated over all verify steps (the decode-throughput multiplier);
    * ``spec_tokens_per_step_<cls>`` — the same per SLO class;
    * ``spec_accept_rate``           — accepted / offered drafts.
    """
    steps = committed = offered = accepted = 0
    by_cls: Dict[str, list] = {cls: [0, 0] for cls in classes}
    for r in requests:
        vs = int(getattr(r, "verify_steps", 0) or 0)
        if vs <= 0:
            continue
        sc = int(getattr(r, "spec_committed", 0) or 0)
        steps += vs
        committed += sc
        offered += int(getattr(r, "drafts_offered", 0) or 0)
        accepted += int(getattr(r, "drafts_accepted", 0) or 0)
        cls = by_cls.setdefault(getattr(r, "slo_class", "standard"), [0, 0])
        cls[0] += vs
        cls[1] += sc
    if steps == 0:
        return {}
    out = {"spec_tokens_per_step": committed / steps}
    if offered > 0:
        out["spec_accept_rate"] = accepted / offered
    for cls, (vs, sc) in sorted(by_cls.items()):
        out[f"spec_tokens_per_step_{cls}"] = sc / vs if vs else None
    return out


def latency_summary(requests: Sequence,
                    classes: Optional[Iterable[str]] = None
                    ) -> Dict[str, float]:
    """The shared distribution block: TTFT/JCT p50/p95/p99 plus per-class
    violation rates.  Pass ``classes`` (the SLO classes the run was
    *supposed* to serve) to additionally emit per-class tail blocks with
    explicit zero/None reporting for empty classes — see
    :func:`class_latency_blocks`.  Runs with speculative decoding also
    get the acceptance block of :func:`speculation_stats`."""
    out: Dict[str, float] = {}
    out.update(percentile_row([r.ttft for r in requests], "ttft"))
    out.update(percentile_row([r.jct for r in requests], "jct"))
    out.update(violation_rates(requests, classes or ()))
    if classes is not None:
        out.update(class_latency_blocks(requests, classes))
    out.update(speculation_stats(requests, classes or ()))
    return out
