"""The unified KV-cache compression pipeline: ``BS = C(Q(T(X)))`` (Sec. 5.1).

``compress`` produces a :class:`CompressedKV` whose *payload is real bytes*
(bit-packed, entropy-coded); ``decompress`` round-trips through those bytes.
Structural metadata (scales, zero-points, transform anchors, indices) is kept
native but exactly byte-accounted, so the reported CR equals
``wire_bytes(original) / wire_bytes(compressed)`` including all metadata —
this reproduces e.g. KIVI's metadata-bounded CR ceiling (paper Sec. 7.3).

Stage implementations and the TPU/host split are described in DESIGN.md
§2-§3; :class:`CompressedKV` is also the payload the serving layer's
prefix-KV pool stores (DESIGN.md §9).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import codecs
from repro.core.kvcache import KVCache
from repro.core.quantizers import (
    QuantBucket,
    QuantizedTensor,
    head_importance_scores,
    quantize_tensor,
)
from repro.core.strategy import SOURCE_BYTES, StrategyConfig, is_identity
from repro.core.transforms import apply_transform, invert_transform, transform_meta_bytes

HEADER_BYTES = 64  # fixed per-message framing overhead


@dataclass
class _BucketWire:
    """Wire form of one quant bucket: payload bytes + structural metadata."""

    payload: bytes
    bits: int
    grouping: str
    group_size: int
    symmetric: bool
    codes_shape: Tuple[int, ...]
    lh_index: np.ndarray
    scale: Optional[np.ndarray]
    zp: Optional[np.ndarray]
    token_index: Optional[np.ndarray]

    def meta_bytes(self) -> int:
        b = self.lh_index.size * 2
        if self.scale is not None:
            b += self.scale.size * 2
        if self.zp is not None:
            b += self.zp.size * 2
        if self.token_index is not None:
            b += self.token_index.size * 4
        return int(b)


@dataclass
class CompressedKV:
    strategy: StrategyConfig
    shape: Tuple[int, int, int, int]
    k_buckets: List[_BucketWire]
    v_buckets: List[_BucketWire]
    k_ctx: Dict[str, Any]
    v_ctx: Dict[str, Any]
    identity_payload: Optional[bytes] = None  # bypass path

    # ------------------------------------------------------------------
    def payload_bytes(self) -> int:
        if self.identity_payload is not None:
            return len(self.identity_payload)
        return sum(len(b.payload) for b in self.k_buckets + self.v_buckets)

    def meta_bytes(self) -> int:
        if self.identity_payload is not None:
            return HEADER_BYTES
        m = sum(b.meta_bytes() for b in self.k_buckets + self.v_buckets)
        m += transform_meta_bytes(self.k_ctx) + transform_meta_bytes(self.v_ctx)
        return m + HEADER_BYTES

    def total_bytes(self) -> int:
        return self.payload_bytes() + self.meta_bytes()

    def original_bytes(self) -> int:
        return int(np.prod(self.shape)) * 2 * SOURCE_BYTES

    def compression_ratio(self) -> float:
        return self.original_bytes() / max(self.total_bytes(), 1)


# ---------------------------------------------------------------------------
def _encode_quantized(qt: QuantizedTensor, codec: str) -> List[_BucketWire]:
    out = []
    for b in qt.buckets:
        if b.bits >= 16:
            payload = codecs.encode_f16(b.codes, codec)
        else:
            payload = codecs.encode_codes(b.codes, b.bits, codec)
        out.append(
            _BucketWire(
                payload=payload, bits=b.bits, grouping=b.grouping,
                group_size=b.group_size, symmetric=b.symmetric,
                codes_shape=tuple(b.codes.shape), lh_index=b.lh_index,
                scale=b.scale, zp=b.zp, token_index=b.token_index,
            )
        )
    return out


def _decode_quantized(wires: List[_BucketWire], shape, codec: str) -> QuantizedTensor:
    qt = QuantizedTensor(shape=shape)
    for w in wires:
        count = int(np.prod(w.codes_shape))
        if w.bits >= 16:
            codes = codecs.decode_f16(w.payload, count, codec).reshape(w.codes_shape)
        else:
            codes = codecs.decode_codes(w.payload, w.bits, count, codec).reshape(
                w.codes_shape
            )
        qt.buckets.append(
            QuantBucket(
                lh_index=w.lh_index, bits=w.bits, grouping=w.grouping,
                group_size=w.group_size, symmetric=w.symmetric, codes=codes,
                scale=w.scale, zp=w.zp, token_index=w.token_index,
            )
        )
    return qt


class CompressionPipeline:
    """Stateless compressor for one :class:`StrategyConfig`."""

    def __init__(self, strategy: StrategyConfig,
                 head_scores: Optional[np.ndarray] = None):
        strategy.validate()
        self.strategy = strategy
        self.head_scores = head_scores

    # ------------------------------------------------------------------
    def compress(self, kv: KVCache) -> CompressedKV:
        cfg = self.strategy
        if is_identity(cfg):
            payload = np.concatenate(
                [kv.k.ravel(), kv.v.ravel()]
            ).astype(np.float16).tobytes()
            return CompressedKV(cfg, kv.shape, [], [], {"kind": "none"},
                                {"kind": "none"}, identity_payload=payload)

        k_t, k_ctx = apply_transform(cfg.transform, kv.k, cfg.delta_group)
        v_t, v_ctx = apply_transform(cfg.transform, kv.v, cfg.delta_group)

        scores = self.head_scores
        if scores is None and cfg.quantizer in ("mixhq", "duo"):
            scores = head_importance_scores(kv.k)

        k_q = quantize_tensor(k_t, cfg, is_key=True, head_scores=scores)
        v_q = quantize_tensor(v_t, cfg, is_key=False, head_scores=scores)

        return CompressedKV(
            strategy=cfg, shape=kv.shape,
            k_buckets=_encode_quantized(k_q, cfg.codec),
            v_buckets=_encode_quantized(v_q, cfg.codec),
            k_ctx=k_ctx, v_ctx=v_ctx,
        )

    # ------------------------------------------------------------------
    def decompress(self, comp: CompressedKV) -> KVCache:
        cfg = comp.strategy
        if comp.identity_payload is not None:
            n = int(np.prod(comp.shape))
            flat = np.frombuffer(comp.identity_payload, dtype=np.float16,
                                 count=2 * n).astype(np.float32)
            k = flat[:n].reshape(comp.shape)
            v = flat[n:].reshape(comp.shape)
            return KVCache(k, v)

        # The quantizer operated on *transformed* tensors whose channel dim
        # may have been padded (hadamard); recover that shape.
        k_shape = self._transformed_shape(comp.shape, comp.k_ctx)
        v_shape = self._transformed_shape(comp.shape, comp.v_ctx)
        k_q = _decode_quantized(comp.k_buckets, k_shape, cfg.codec)
        v_q = _decode_quantized(comp.v_buckets, v_shape, cfg.codec)
        k_t = k_q.dequantize()
        v_t = v_q.dequantize()
        k = invert_transform(k_t, comp.k_ctx)
        v = invert_transform(v_t, comp.v_ctx)
        return KVCache(k, v)

    @staticmethod
    def _transformed_shape(shape, ctx) -> Tuple[int, int, int, int]:
        if ctx.get("kind") == "hadamard":
            return shape[:3] + (ctx["pad_dim"],)
        return tuple(shape)

    # ------------------------------------------------------------------
    def roundtrip(self, kv: KVCache) -> Tuple[KVCache, CompressedKV, float, float]:
        """(restored, compressed, enc_seconds, dec_seconds)."""
        t0 = time.perf_counter()
        comp = self.compress(kv)
        t1 = time.perf_counter()
        restored = self.decompress(comp)
        t2 = time.perf_counter()
        return restored, comp, t1 - t0, t2 - t1
