"""Config alias for --arch deepseek-moe-16b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("deepseek-moe-16b")
