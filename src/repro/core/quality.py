"""Quality proxy: a trained tiny byte-LM measures the workload-dependent
accuracy impact of each compression strategy (DESIGN.md §8).

``evaluate_quality(strategy)`` returns per-workload *relative accuracy* —
greedy-decode token agreement against the uncompressed-KV decode, the
laptop-scale analogue of the paper's "97% relative accuracy" metric.  The
four synthetic workloads have genuinely different byte statistics, so KV
compressibility and accuracy rankings differ per workload (Motivation 1).
"""
from __future__ import annotations

import os
from functools import lru_cache
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.kvcache import KVCache
from repro.core.pipeline import CompressionPipeline
from repro.core.strategy import StrategyConfig, is_identity
from repro.data.synthetic import WORKLOADS, make_batch, make_prompt
from repro.data.tokenizer import ByteTokenizer

CACHE_DIR = Path(os.environ.get("REPRO_CACHE_DIR",
                                Path.home() / ".cache" / "repro"))
REF_STEPS = int(os.environ.get("REPRO_REF_STEPS", "400"))


# ---------------------------------------------------------------------------
# Reference model (trained once, cached to disk)
# ---------------------------------------------------------------------------
def _params_path(steps: int) -> Path:
    return CACHE_DIR / f"tiny_lm_s{steps}.npz"


def train_reference_model(steps: int = REF_STEPS, seed: int = 0,
                          batch: int = 16, seq: int = 256,
                          log_every: int = 0):
    """Train tiny-lm on the mixed workload soup; returns (cfg, params)."""
    from repro.distribution.optimizer import OptConfig, init_opt_state
    from repro.distribution.steps import make_train_step
    from repro.models import init_params

    cfg = get_config("tiny-lm")
    params, _ = init_params(cfg, seed=seed)
    oc = OptConfig(lr=3e-3, warmup_steps=max(steps // 10, 10),
                   total_steps=steps, schedule="cosine", weight_decay=0.01)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, oc, remat=False))
    loss = None
    for i in range(steps):
        tokens, mask = make_batch("mixed", batch, seq, seed=seed * 100003 + i)
        b = {"tokens": jnp.asarray(tokens), "mask": jnp.asarray(mask[:, 1:])}
        params, opt_state, metrics = step_fn(params, opt_state, b)
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1}/{steps} loss={float(metrics['loss']):.3f}")
        loss = metrics["loss"]
    return cfg, params, float(loss)


def get_reference_model(steps: int = REF_STEPS, seed: int = 0):
    """Load the cached reference model, training it on first use."""
    from repro.models import init_params

    cfg = get_config("tiny-lm")
    path = _params_path(steps)
    template, _ = init_params(cfg, seed=seed)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if path.exists():
        data = np.load(path)
        loaded = [jnp.asarray(data[f"arr_{i}"]) for i in range(len(leaves))]
        return cfg, jax.tree_util.tree_unflatten(treedef, loaded)
    cfg, params, _ = train_reference_model(steps=steps, seed=seed)
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(path, **{f"arr_{i}": np.asarray(x) for i, x in enumerate(flat)})
    return cfg, params


# ---------------------------------------------------------------------------
# Cache <-> KVCache conversion (attention layers, dense stacks)
# ---------------------------------------------------------------------------
def extract_kv(cfg, caches, batch_idx: int, upto: int) -> KVCache:
    """Pull one batch element's attention KV as (L, H, S, D) numpy."""
    from repro.models.transformer import plan_stack

    plan = plan_stack(cfg)
    ks: List[np.ndarray] = []
    vs: List[np.ndarray] = []
    for i, spec in enumerate(plan.prefix_specs):
        if spec.kind != "attn":
            continue
        c = caches["prefix"][f"layer{i}"]
        ks.append(np.asarray(c["k"][batch_idx, :upto], np.float32).transpose(1, 0, 2))
        vs.append(np.asarray(c["v"][batch_idx, :upto], np.float32).transpose(1, 0, 2))
    for blk in range(plan.n_blocks):
        for j, spec in enumerate(plan.period_specs):
            if spec.kind != "attn":
                continue
            c = caches["blocks"][f"layer{j}"]
            ks.append(np.asarray(c["k"][blk, batch_idx, :upto],
                                 np.float32).transpose(1, 0, 2))
            vs.append(np.asarray(c["v"][blk, batch_idx, :upto],
                                 np.float32).transpose(1, 0, 2))
    return KVCache(np.stack(ks), np.stack(vs))


def copy_cache_slot(cfg, dst, src, slot, src_idx: int = 0):
    """Write one batch row of the ``src`` cache pytree into row ``slot`` of
    the (larger-batch) ``dst`` arena pytree — how a fresh batch-1 prefill
    lands in its slot.  Jitted once; ``slot`` is a traced scalar so slot
    recycling never recompiles."""
    if "self" in dst:
        raise NotImplementedError("slot arena: decoder-only caches")
    return _slot_copy(dst, src, jnp.asarray(slot, jnp.int32),
                      jnp.asarray(src_idx, jnp.int32))


@jax.jit
def _slot_copy(dst, src, slot, src_idx):
    def _write(batch_axis):
        def w(d, s):
            row = jax.lax.dynamic_slice_in_dim(s, src_idx, 1, batch_axis)
            start = [0] * d.ndim
            start[batch_axis] = slot
            return jax.lax.dynamic_update_slice(
                d, row.astype(d.dtype), tuple(start))
        return w

    # prefix leaves carry batch at axis 0, scanned blocks at axis 1
    return {
        "prefix": jax.tree_util.tree_map(_write(0), dst["prefix"],
                                         src["prefix"]),
        "blocks": jax.tree_util.tree_map(_write(1), dst["blocks"],
                                         src["blocks"]),
    }


def inject_kv(cfg, caches, batch_idx: int, kv: KVCache):
    """Write a (possibly lossy) KVCache back into the cache pytree."""
    from repro.models.transformer import plan_stack

    plan = plan_stack(cfg)
    upto = kv.seq
    li = 0

    def _store(buf, arr):
        # arr (H, S, D) -> (S, H, D)
        return buf.at[batch_idx, :upto].set(
            jnp.asarray(arr.transpose(1, 0, 2), buf.dtype))

    new_prefix = {}
    for i, spec in enumerate(plan.prefix_specs):
        name = f"layer{i}"
        c = caches["prefix"][name]
        if spec.kind != "attn":
            new_prefix[name] = c
            continue
        new_prefix[name] = {"k": _store(c["k"], kv.k[li]),
                            "v": _store(c["v"], kv.v[li])}
        li += 1
    new_blocks = dict(caches["blocks"])
    attn_per_period = len([s for s in plan.period_specs if s.kind == "attn"])
    for j, spec in enumerate(plan.period_specs):
        name = f"layer{j}"
        if spec.kind != "attn":
            continue
        c = caches["blocks"][name]
        # layer indices owned by this period slot, across blocks
        idxs = [li + n * attn_per_period for n in range(plan.n_blocks)]
        karr = np.stack([kv.k[i2].transpose(1, 0, 2) for i2 in idxs])
        varr = np.stack([kv.v[i2].transpose(1, 0, 2) for i2 in idxs])
        k_buf = c["k"].at[:, batch_idx, :upto].set(jnp.asarray(karr, c["k"].dtype))
        v_buf = c["v"].at[:, batch_idx, :upto].set(jnp.asarray(varr, c["v"].dtype))
        new_blocks[name] = {"k": k_buf, "v": v_buf}
        li += 1
    return {"prefix": new_prefix, "blocks": new_blocks}


# ---------------------------------------------------------------------------
# Paged decode arena (DESIGN.md §12)
# ---------------------------------------------------------------------------
def init_paged_pools(cfg, num_pages: int, page_size: int, group: int):
    """Build the paged arena's device pools: ``(pool, qcodes, qscales)``.

    ``pool`` mirrors ``init_cache``'s pytree with the (batch, max_len)
    leading axes replaced by (num_pages, page_size) — logical position
    ``t`` of a slot lives at row ``t % page_size`` of the pool page named
    by entry ``t // page_size`` of its block table.  ``qcodes``/
    ``qscales`` are the parallel quantized pools (int8 codes + f32
    scales, one scale per ``group`` channels per token) sharing the SAME
    page ids: a page holds either fp content or quantized content, and
    the per-slot ``quant_len`` decides which pool each position reads
    from.  Page 0 is the reserved scratch page (never allocated)."""
    from repro.models import init_cache

    pool = init_cache(cfg, num_pages, max_len=page_size)
    qcodes = jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, jnp.int8), pool)
    qscales = jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape[:-1] + (a.shape[-1] // group,),
                            jnp.float32), pool)
    return pool, qcodes, qscales


def _paged_view(leaf, bt, prefix: bool):
    """Gather a pool leaf into the dense (·, B, S, H, D) decode view."""
    if prefix:  # (P, ps, H, D) -> (B, PPS*ps, H, D)
        g = jnp.take(leaf, bt, axis=0)
        return g.reshape(g.shape[0], -1, *g.shape[3:])
    g = jnp.take(leaf, bt, axis=1)  # (n, B, PPS, ps, H, D)
    return g.reshape(g.shape[0], g.shape[1], -1, *g.shape[4:])


def _blend_quant(view, qc_view, qs_view, quant_len, prefix: bool):
    """Dequantize the quant-pool view and take it for positions below
    each slot's ``quant_len`` (exactly the ``group_dequantize`` math:
    signed codes x f32 scale, then cast to the cache compute dtype)."""
    d = view.shape[-1]
    g = d // qs_view.shape[-1]
    x = qc_view.astype(jnp.float32).reshape(qc_view.shape[:-1] + (d // g, g))
    x = (x * qs_view[..., None].astype(jnp.float32)
         ).reshape(qc_view.shape).astype(view.dtype)
    s = view.shape[1] if prefix else view.shape[2]
    use_q = jnp.arange(s, dtype=jnp.int32)[None, :] < quant_len[:, None]
    m = use_q[:, :, None, None] if prefix else use_q[None, :, :, None, None]
    return jnp.where(m, x, view)


def _pad_axis(x, target: int, axis: int):
    cur = x.shape[axis]
    if cur >= target:
        return jax.lax.slice_in_dim(x, 0, target, axis=axis)
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - cur)
    return jnp.pad(x, pad)


@lru_cache(maxsize=8)
def _paged_steps(cfg_name: str, page_size: int):
    """Jitted paged-arena kernels for one model config: ``(arena, copy)``.

    ``arena(params, pool, qcodes, qscales, bt, quant_len, tokens, pos,
    mask)`` is the paged analogue of ``_jitted_steps``'s arena decode:
    gather every slot's pages into a contiguous view (dequant-blending
    quantized-resident positions), run one masked ``decode_step``, then
    scatter ONLY the newly written K/V row back to each slot's page.
    Parked rows (mask False) are pinned to the view's last position,
    which maps to the scratch page or the slot's own never-attended tail
    row, so their writes are inert — same contract as the dense arena.
    Block tables and lengths are traced: page churn never recompiles.

    ``copy(pool, src, bt_row, src_idx)`` is ``copy_cache_slot`` as a
    page-map operation: one prefilled source row lands in the slot's
    owned pages (sentinel-0 tail entries spill into scratch).
    """
    from repro.models import decode_step

    cfg = get_config(cfg_name)

    def arena(params, pool, qcodes, qscales, bt, quant_len, tokens, pos,
              mask):
        view_len = bt.shape[1] * page_size
        pos = jnp.where(mask, pos, view_len - 1).astype(jnp.int32)

        def build(prefix):
            def f(p, qc, qs):
                return _blend_quant(_paged_view(p, bt, prefix),
                                    _paged_view(qc, bt, prefix),
                                    _paged_view(qs, bt, prefix),
                                    quant_len, prefix)
            return f

        caches = {
            "prefix": jax.tree_util.tree_map(
                build(True), pool["prefix"], qcodes["prefix"],
                qscales["prefix"]),
            "blocks": jax.tree_util.tree_map(
                build(False), pool["blocks"], qcodes["blocks"],
                qscales["blocks"]),
        }
        logits, new_caches = decode_step(cfg, params, caches, tokens, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

        page_idx = jnp.take_along_axis(
            bt, (pos // page_size)[:, None], axis=1)[:, 0]
        offset = pos % page_size

        def scat(prefix):
            def f(p, nv):
                if prefix:
                    row = jnp.take_along_axis(
                        nv, pos[:, None, None, None], axis=1)[:, 0]
                    return p.at[page_idx, offset].set(row.astype(p.dtype))
                row = jnp.take_along_axis(
                    nv, pos[None, :, None, None, None], axis=2)[:, :, 0]
                return p.at[:, page_idx, offset].set(row.astype(p.dtype))
            return f

        new_pool = {
            "prefix": jax.tree_util.tree_map(
                scat(True), pool["prefix"], new_caches["prefix"]),
            "blocks": jax.tree_util.tree_map(
                scat(False), pool["blocks"], new_caches["blocks"]),
        }
        return jnp.where(mask, nxt, 0), new_pool

    def copy(pool, src, bt_row, src_idx):
        pps = bt_row.shape[0]

        def w_prefix(p, s):
            row = jax.lax.dynamic_slice_in_dim(s, src_idx, 1, 0)[0]
            row = _pad_axis(row, pps * page_size, axis=0)
            pages = row.reshape(pps, page_size, *row.shape[1:])
            return p.at[bt_row].set(pages.astype(p.dtype))

        def w_block(p, s):
            row = jax.lax.dynamic_slice_in_dim(s, src_idx, 1, 1)[:, 0]
            row = _pad_axis(row, pps * page_size, axis=1)
            pages = row.reshape(row.shape[0], pps, page_size,
                                *row.shape[2:])
            return p.at[:, bt_row].set(pages.astype(p.dtype))

        return {
            "prefix": jax.tree_util.tree_map(w_prefix, pool["prefix"],
                                             src["prefix"]),
            "blocks": jax.tree_util.tree_map(w_block, pool["blocks"],
                                             src["blocks"]),
        }

    return jax.jit(arena), jax.jit(copy)


@lru_cache(maxsize=16)
def _paged_verify_steps(cfg_name: str, page_size: int, width: int):
    """Jitted paged multi-token verify step (DESIGN.md §15).

    ``verify(params, pool, qcodes, qscales, bt, quant_len, tokens, pos,
    mask)`` is ``_paged_steps``'s arena decode widened to a ``(B, width)``
    query block: each live slot feeds ``width`` tokens at consecutive
    positions ``pos..pos+width-1`` and gets all ``width`` greedy argmax
    outputs back for host-side accept-prefix matching.  All ``width`` K/V
    rows are scattered to each slot's pages; positions beyond a slot's
    *ensured* page span map to block-table entry 0 — the reserved scratch
    page, which no live query ever reads — so slots verifying fewer than
    ``width-1`` drafts need no masking: their surplus writes are inert by
    construction.  Parked rows pin to ``view_len - width`` (scratch pages
    again).  Rejected suffixes are rolled back by the caller via
    ``PageTable.release_tail``; the pages themselves need no scrubbing
    because reads are capped at each slot's committed ``pos``.
    """
    from repro.models import decode_step

    cfg = get_config(cfg_name)

    def verify(params, pool, qcodes, qscales, bt, quant_len, tokens, pos,
               mask):
        view_len = bt.shape[1] * page_size
        pos = jnp.where(mask, pos, view_len - width).astype(jnp.int32)

        def build(prefix):
            def f(p, qc, qs):
                return _blend_quant(_paged_view(p, bt, prefix),
                                    _paged_view(qc, bt, prefix),
                                    _paged_view(qs, bt, prefix),
                                    quant_len, prefix)
            return f

        caches = {
            "prefix": jax.tree_util.tree_map(
                build(True), pool["prefix"], qcodes["prefix"],
                qscales["prefix"]),
            "blocks": jax.tree_util.tree_map(
                build(False), pool["blocks"], qcodes["blocks"],
                qscales["blocks"]),
        }
        logits, new_caches = decode_step(cfg, params, caches, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, width)

        new_pool = pool
        for j in range(width):
            pj = pos + j
            page_idx = jnp.take_along_axis(
                bt, (pj // page_size)[:, None], axis=1)[:, 0]
            offset = pj % page_size

            def scat(prefix, pj=pj, page_idx=page_idx, offset=offset):
                def f(p, nv):
                    if prefix:
                        row = jnp.take_along_axis(
                            nv, pj[:, None, None, None], axis=1)[:, 0]
                        return p.at[page_idx, offset].set(row.astype(p.dtype))
                    row = jnp.take_along_axis(
                        nv, pj[None, :, None, None, None], axis=2)[:, :, 0]
                    return p.at[:, page_idx, offset].set(row.astype(p.dtype))
                return f

            new_pool = {
                "prefix": jax.tree_util.tree_map(
                    scat(True), new_pool["prefix"], new_caches["prefix"]),
                "blocks": jax.tree_util.tree_map(
                    scat(False), new_pool["blocks"], new_caches["blocks"]),
            }
        return jnp.where(mask[:, None], nxt, 0), new_pool

    return jax.jit(verify)


def copy_cache_slot_paged(cfg, pool, src, bt_row, page_size: int,
                          src_idx: int = 0):
    """Paged ``copy_cache_slot``: land one prefilled source row in the
    pages of ``bt_row`` (a (PPS,) int32 row; 0 entries spill to scratch)."""
    if "self" in pool:
        raise NotImplementedError("paged arena: decoder-only caches")
    _, copy = _paged_steps(cfg.name, page_size)
    return copy(pool, src, jnp.asarray(bt_row, jnp.int32),
                jnp.asarray(src_idx, jnp.int32))


def _paged_scatter(cfg, pool, bt_row, k_arr, v_arr, upto: int,
                   page_size: int):
    """Scatter per-layer (L, H, S, X) k/v arrays into a slot's pages —
    the page-map core of ``inject_kv_paged``/``inject_quant_pages``.
    Only the first ``ceil(upto / page_size)`` owned pages are written
    (partial-page tails are zero-filled; the slot is fresh, so nothing
    real is clobbered)."""
    from repro.models.transformer import plan_stack

    plan = plan_stack(cfg)
    n_used = -(-upto // page_size)
    rows = jnp.asarray(np.asarray(bt_row)[:n_used], jnp.int32)
    li = 0

    def _pages(arr):  # (H, S, X) -> (n_used, ps, H, X)
        a = jnp.asarray(arr).swapaxes(0, 1)  # (S, H, X)
        a = _pad_axis(a, n_used * page_size, axis=0)
        return a.reshape(n_used, page_size, *a.shape[1:])

    new_prefix = {}
    for i, spec in enumerate(plan.prefix_specs):
        name = f"layer{i}"
        c = pool["prefix"][name]
        if spec.kind != "attn":
            new_prefix[name] = c
            continue
        new_prefix[name] = {
            "k": c["k"].at[rows].set(_pages(k_arr[li]).astype(c["k"].dtype)),
            "v": c["v"].at[rows].set(_pages(v_arr[li]).astype(c["v"].dtype)),
        }
        li += 1
    new_blocks = dict(pool["blocks"])
    attn_per_period = len([s for s in plan.period_specs if s.kind == "attn"])
    for j, spec in enumerate(plan.period_specs):
        name = f"layer{j}"
        if spec.kind != "attn":
            continue
        c = pool["blocks"][name]
        idxs = [li + n * attn_per_period for n in range(plan.n_blocks)]
        karr = jnp.stack([_pages(k_arr[i2]) for i2 in idxs])
        varr = jnp.stack([_pages(v_arr[i2]) for i2 in idxs])
        new_blocks[name] = {
            "k": c["k"].at[:, rows].set(karr.astype(c["k"].dtype)),
            "v": c["v"].at[:, rows].set(varr.astype(c["v"].dtype)),
        }
        li += 1
    return {"prefix": new_prefix, "blocks": new_blocks}


def inject_kv_paged(cfg, pool, bt_row, kv: KVCache, page_size: int):
    """Paged ``inject_kv``: write a restored KVCache into a fresh slot's
    pages as a page-map operation."""
    return _paged_scatter(cfg, pool, bt_row, kv.k, kv.v, kv.seq, page_size)


def inject_quant_pages(cfg, qcodes, qscales, bt_row, k_codes, k_scales,
                       v_codes, v_scales, upto: int, page_size: int):
    """Land packed quantized KV straight in the quant page pools — the
    zero-materialization injection path for paged-eligible strategies.
    ``k_codes``/``v_codes`` are (L, H, S, D) signed int8;
    ``k_scales``/``v_scales`` are (L, H, S, D/group) f32 (already
    round-tripped through fp16, so the fused dequant is bit-identical
    to the materialized ``group_dequantize`` + inject path)."""
    new_qc = _paged_scatter(cfg, qcodes, bt_row, k_codes, v_codes, upto,
                            page_size)
    new_qs = _paged_scatter(cfg, qscales, bt_row, k_scales, v_scales, upto,
                            page_size)
    return new_qc, new_qs


# ---------------------------------------------------------------------------
# Quality evaluation
# ---------------------------------------------------------------------------
@lru_cache(maxsize=8)
def _jitted_steps(cfg_name: str, seq: int, batch: int, max_len: int):
    """Returns (prefill, decode, arena_decode), all jitted.

    ``arena_decode(params, caches, tokens, pos, mask)`` is the masked
    batched decode of the slot arena (DESIGN.md §9): ``tokens`` (B, 1),
    ``pos`` (B,) per-slot next cache positions, ``mask`` (B,) live-slot
    flags.  Every slot advances in ONE model call; parked rows (mask
    False — free slots and this iteration's fresh prefills) are pinned to
    the scratch position ``max_len - 1``, which no live query position
    ever attends to, so their cache writes are inert.  The next token per
    slot comes from an on-device argmax; the caller pulls the (B,) token
    vector back once per iteration.
    """
    from repro.models import decode_step, prefill

    cfg = get_config(cfg_name)
    pre = jax.jit(lambda p, b: prefill(cfg, p, b, max_len=max_len))
    dec = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def _arena(p, c, t, pos, mask):
        pos = jnp.where(mask, pos, max_len - 1).astype(jnp.int32)
        logits, c = decode_step(cfg, p, c, t, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return jnp.where(mask, nxt, 0), c

    return pre, dec, jax.jit(_arena)


@lru_cache(maxsize=16)
def _verify_steps(cfg_name: str, max_len: int, width: int):
    """Jitted dense multi-token verify step (DESIGN.md §15).

    ``verify(params, caches, tokens, pos, mask)`` widens ``_jitted_steps``'s
    arena decode to a ``(B, width)`` query block: each live slot feeds its
    last committed token plus ``width-1`` draft tokens at consecutive
    positions ``pos..pos+width-1`` and receives all ``width`` greedy argmax
    outputs for host-side accept-prefix matching.  Parked rows pin to
    ``max_len - width`` so every one of their ``width`` K/V row writes
    stays in-bounds; the writes are inert because rows are per-slot and
    reads are capped at each slot's committed position (``kv_valid``), so
    garbage beyond ``pos`` — including rejected draft KV — is simply
    overwritten by later steps and never attended to.
    """
    from repro.models import decode_step

    cfg = get_config(cfg_name)

    def _verify(p, c, t, pos, mask):
        pos = jnp.where(mask, pos, max_len - width).astype(jnp.int32)
        logits, c = decode_step(cfg, p, c, t, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, width)
        return jnp.where(mask[:, None], nxt, 0), c

    return jax.jit(_verify)


def _prompts_for(workload: str, n: int, seq: int, seed: int
                 ) -> Tuple[jnp.ndarray, List[str]]:
    tok = ByteTokenizer()
    rng = np.random.default_rng(seed)
    rows, answers = [], []
    for _ in range(n):
        prompt, ans = make_prompt(workload, rng, approx_len=seq + 32)
        ids = tok.encode(prompt)
        ids = ids[-seq:] if len(ids) >= seq else tok.pad_to(ids, seq)
        rows.append(ids)
        answers.append(ans)
    return jnp.asarray(np.stack(rows)), answers


def _greedy_decode(dec_fn, params, caches, first_tokens, start_pos: int,
                   steps: int) -> np.ndarray:
    toks = first_tokens  # (B, 1)
    out = [np.asarray(toks)[:, 0]]
    pos = jnp.asarray(start_pos, jnp.int32)
    for t in range(steps):
        logits, caches = dec_fn(params, caches, toks, pos + t)
        toks = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        # lint: sync-ok(offline reference decode for agreement scoring)
        out.append(np.asarray(toks)[:, 0])
    return np.stack(out, axis=1)  # (B, steps+1)


def _teacher_forced_agreement(dec_fn, params, caches, ref_tokens: np.ndarray,
                              start_pos: int) -> float:
    """Relative accuracy without divergence compounding: feed the reference
    continuation, compare each step's argmax against the reference's next
    token (the paper's relative-accuracy analogue)."""
    b, t1 = ref_tokens.shape
    pos = jnp.asarray(start_pos, jnp.int32)
    hits, total = 0, 0
    for t in range(t1 - 1):
        toks = jnp.asarray(ref_tokens[:, t:t + 1], jnp.int32)
        logits, caches = dec_fn(params, caches, toks, pos + t)
        pred = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        hits += int((pred == ref_tokens[:, t + 1]).sum())
        total += b
    return hits / max(total, 1)


def evaluate_quality(
    strategy: StrategyConfig,
    workloads: Sequence[str] = tuple(WORKLOADS),
    n_prompts: int = 6,
    seq: int = 192,
    decode_tokens: int = 20,
    seed: int = 0,
    ref=None,
    head_scores: Optional[np.ndarray] = None,
) -> Dict[str, float]:
    """Per-workload relative accuracy of ``strategy`` on the tiny LM."""
    if is_identity(strategy):
        return {w: 1.0 for w in workloads}
    cfg, params = ref if ref is not None else get_reference_model()
    gen_budget = decode_tokens + 2
    pre, dec, _ = _jitted_steps(cfg.name, seq, n_prompts, seq + gen_budget)
    pipe = CompressionPipeline(strategy, head_scores=head_scores)

    out: Dict[str, float] = {}
    for wi, w in enumerate(workloads):
        tokens, _ = _prompts_for(w, n_prompts, seq, seed * 7919 + wi)
        logits, caches = pre(params, {"tokens": tokens})
        first = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

        # reference decode (uncompressed KV)
        ref_toks = _greedy_decode(dec, params, caches, first, seq,
                                  decode_tokens)

        # compressed-KV decode, teacher-forced on the reference tokens
        comp_caches = caches
        for b in range(n_prompts):
            kv = extract_kv(cfg, caches, b, upto=seq)
            restored = pipe.decompress(pipe.compress(kv))
            comp_caches = inject_kv(cfg, comp_caches, b, restored)
        out[w] = _teacher_forced_agreement(dec, params, comp_caches,
                                           ref_toks, seq)
    return out


def calibrate_head_scores(workload: str = "mixed", n_prompts: int = 4,
                          seq: int = 192, seed: int = 0, ref=None
                          ) -> np.ndarray:
    """Data-driven retrieval-head scores (L, H) from real model KV."""
    cfg, params = ref if ref is not None else get_reference_model()
    pre, _, _ = _jitted_steps(cfg.name, seq, n_prompts, seq + 4)
    ws = list(WORKLOADS) if workload == "mixed" else [workload]
    scores = []
    for wi, w in enumerate(ws):
        tokens, _ = _prompts_for(w, n_prompts, seq, seed + wi)
        _, caches = pre(params, {"tokens": tokens})
        for b in range(min(n_prompts, 2)):
            kv = extract_kv(cfg, caches, b, upto=seq)
            centered = kv.k - kv.k.mean(axis=2, keepdims=True)
            scores.append(np.sqrt((centered**2).mean(axis=(2, 3))))
    return np.mean(scores, axis=0)
