"""Minimal Gaussian Process regressor (RBF + noise) in numpy.

Supports the profiling engine's needs: posterior mean/variance over the
embedded strategy space, feasibility probability under an accuracy
threshold, and incremental refits as observations accumulate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass
class GaussianProcess:
    length_scale: float = 1.0
    signal_var: float = 1.0
    noise_var: float = 1e-4
    normalize_y: bool = True

    _x: Optional[np.ndarray] = field(default=None, repr=False)
    _alpha: Optional[np.ndarray] = field(default=None, repr=False)
    _l_chol: Optional[np.ndarray] = field(default=None, repr=False)
    _y_mean: float = 0.0
    _y_std: float = 1.0

    def _kernel(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return self.signal_var * np.exp(-0.5 * d2 / self.length_scale**2)

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GaussianProcess":
        x = np.atleast_2d(np.asarray(x, dtype=np.float64))
        y = np.asarray(y, dtype=np.float64).ravel()
        if self.normalize_y and len(y) > 1:
            self._y_mean = float(y.mean())
            self._y_std = float(y.std() + 1e-9)
        else:
            self._y_mean, self._y_std = 0.0, 1.0
        yn = (y - self._y_mean) / self._y_std
        k = self._kernel(x, x) + self.noise_var * np.eye(len(x))
        self._l_chol = np.linalg.cholesky(k)
        self._alpha = np.linalg.solve(
            self._l_chol.T, np.linalg.solve(self._l_chol, yn))
        self._x = x
        return self

    def predict(self, xq: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Posterior (mean, std) at query points."""
        xq = np.atleast_2d(np.asarray(xq, dtype=np.float64))
        if self._x is None:
            return (np.zeros(len(xq)) + self._y_mean,
                    np.full(len(xq), np.sqrt(self.signal_var)) * self._y_std)
        ks = self._kernel(xq, self._x)
        mean = ks @ self._alpha
        v = np.linalg.solve(self._l_chol, ks.T)
        var = np.clip(self.signal_var - (v**2).sum(0), 1e-12, None)
        return mean * self._y_std + self._y_mean, np.sqrt(var) * self._y_std

    def prob_greater(self, xq: np.ndarray, threshold: float) -> np.ndarray:
        """P(f(x) >= threshold) under the Gaussian posterior."""
        mean, std = self.predict(xq)
        z = (mean - threshold) / np.maximum(std, 1e-9)
        return _norm_cdf(z)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(z / np.sqrt(2.0)))


def _erf(x: np.ndarray) -> np.ndarray:
    # Abramowitz & Stegun 7.1.26 (max abs error 1.5e-7)
    sign = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736) * t + 0.254829592) * t * np.exp(-x * x)
    return sign * y
