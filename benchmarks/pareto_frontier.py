"""Paper Fig. 10: the 3D Pareto frontier (Acc × CR × Latency)."""
from __future__ import annotations

import time

from benchmarks.common import cached_profiles, emit
from repro.data.synthetic import WORKLOADS
from repro.profiling import frontier_from_profiles


def run(smoke: bool = False) -> None:
    # frontier extraction over the cached profile set is already cheap:
    # the smoke path IS the full path
    profiles = cached_profiles()
    for w in WORKLOADS:
        t0 = time.perf_counter()
        frontier = frontier_from_profiles(profiles, w, ref_bandwidth=1e9)
        us = (time.perf_counter() - t0) * 1e6
        tops = sorted(frontier, key=lambda p: -p.cr)[:3]
        emit(f"fig10_frontier_{w}", us,
             f"candidates={len(profiles)} frontier={len(frontier)} "
             + " ".join(f"[acc={p.acc:.2f},cr={p.cr:.1f}]" for p in tops))


if __name__ == "__main__":
    run()
