"""Canonical host-side KV cache container used on the network path, and
the page-table bookkeeping for the paged decode arena (DESIGN.md §12).

Layout: ``k, v : (num_layers, kv_heads, seq, head_dim)`` float32 arrays that
*logically* represent bf16 wire data (2 bytes/elem), matching the paper's
BF16 baseline accounting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.core.strategy import SCALE_BYTES, SOURCE_BYTES


@dataclass
class KVCache:
    k: np.ndarray  # (L, H, S, D)
    v: np.ndarray  # (L, H, S, D)

    def __post_init__(self):
        assert self.k.shape == self.v.shape, (self.k.shape, self.v.shape)
        assert self.k.ndim == 4

    @property
    def shape(self):
        return self.k.shape

    @property
    def num_layers(self) -> int:
        return self.k.shape[0]

    @property
    def kv_heads(self) -> int:
        return self.k.shape[1]

    @property
    def seq(self) -> int:
        return self.k.shape[2]

    @property
    def head_dim(self) -> int:
        return self.k.shape[3]

    def nbytes_wire(self) -> int:
        """Bytes of the uncompressed payload on the wire (logical bf16)."""
        return int(self.k.size + self.v.size) * SOURCE_BYTES

    @staticmethod
    def random(num_layers=4, kv_heads=4, seq=128, head_dim=64, seed=0,
               scale: float = 1.0) -> "KVCache":
        rng = np.random.default_rng(seed)
        shape = (num_layers, kv_heads, seq, head_dim)
        # Heavy-tailed, channel-structured data resembling real KV statistics:
        # per-channel means + a few outlier channels (motivating Hadamard).
        base_k = rng.standard_normal(shape).astype(np.float32)
        base_v = rng.standard_normal(shape).astype(np.float32)
        chan_scale = np.exp(rng.standard_normal((1, 1, 1, head_dim)) * 0.5)
        outliers = rng.random((1, 1, 1, head_dim)) < 0.03
        chan_scale = chan_scale * np.where(outliers, 8.0, 1.0)
        k = (base_k * chan_scale + rng.standard_normal((1, 1, 1, head_dim))) * scale
        v = base_v * scale
        return KVCache(k.astype(np.float32), v.astype(np.float32))

    def allclose(self, other: "KVCache", atol=1e-5, rtol=1e-5) -> bool:
        return bool(
            np.allclose(self.k, other.k, atol=atol, rtol=rtol)
            and np.allclose(self.v, other.v, atol=atol, rtol=rtol)
        )


# ---------------------------------------------------------------------------
# Paged-arena page table (DESIGN.md §12)
# ---------------------------------------------------------------------------
class ArenaOutOfPages(RuntimeError):
    """The shared page pool is exhausted — a slot asked for more pages
    than the free list holds.  Admission control should have prevented
    this; raising (rather than silently corrupting a stolen page) keeps
    page ownership single-writer by construction."""


@dataclass
class PageTable:
    """Host-side bookkeeping for a paged KV arena.

    The device pools are ``(num_pages, page_size, ...)`` arrays; this
    table tracks which pool pages each slot owns.  Page 0 is reserved as
    the scratch page — it is never allocated, every unmapped block-table
    entry points at it, and parked/inert cache writes land in it, so
    real pages are single-writer: exactly one slot owns any page > 0.

    Invariants (checked by :meth:`check`):
      * ``len(free) + sum(len(pages[s]))  ==  num_pages - 1``
      * no page id appears twice (across the free list + all slots)
      * page 0 is never owned and never free-listed
    """

    num_pages: int
    page_size: int
    free: List[int] = field(default_factory=list)
    pages: Dict[int, List[int]] = field(default_factory=dict)

    def __post_init__(self):
        assert self.num_pages >= 2 and self.page_size >= 1
        if not self.free and not self.pages:
            # LIFO free list: recently released pages are re-used first
            # (they are the ones most likely still warm in cache).
            self.free = list(range(self.num_pages - 1, 0, -1))

    # -- capacity ------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self.free)

    def ensure(self, slot: int, n_tokens: int) -> List[int]:
        """Grow ``slot`` to cover ``n_tokens`` positions, allocating pages
        on demand.  Returns the newly allocated page ids (often empty).
        Raises :class:`ArenaOutOfPages` when the pool cannot cover it —
        the slot keeps whatever it already owned (no partial grant)."""
        owned = self.pages.setdefault(slot, [])
        need = self.pages_for(n_tokens) - len(owned)
        if need <= 0:
            return []
        if need > len(self.free):
            raise ArenaOutOfPages(
                f"slot {slot} needs {need} more page(s) of {self.page_size} "
                f"tokens but only {len(self.free)} free of {self.num_pages}")
        new = [self.free.pop() for _ in range(need)]
        owned.extend(new)
        return new

    def release(self, slot: int) -> int:
        """Return all of ``slot``'s pages to the free pool."""
        owned = self.pages.pop(slot, [])
        self.free.extend(owned)
        return len(owned)

    def release_tail(self, slot: int, n_tokens: int) -> List[int]:
        """Shrink ``slot`` to the pages covering ``n_tokens`` positions,
        returning the freed page ids (often empty).  This is the
        speculative-decode rollback: a verify step may have ensured pages
        for ``k`` draft positions that were then rejected; the slot stays
        live and keeps its committed prefix, only the rejected tail pages
        go back to the free pool.  The freed pages need no scrubbing —
        reads are capped at the committed position, so whatever draft KV
        they hold is never attended to and is overwritten on reuse."""
        owned = self.pages.get(slot, [])
        keep = self.pages_for(n_tokens)
        freed = owned[keep:]
        del owned[keep:]
        self.free.extend(freed)
        return freed

    def block_row(self, slot: int, row_len: int) -> np.ndarray:
        """The slot's block-table row, padded with the scratch sentinel 0
        to ``row_len`` entries (row_len = ceil(max_len / page_size))."""
        owned = self.pages.get(slot, [])
        assert len(owned) <= row_len, (slot, len(owned), row_len)
        row = np.zeros(row_len, np.int32)
        row[:len(owned)] = owned
        return row

    def check(self) -> None:
        """Assert the conservation + single-ownership invariants."""
        seen = set(self.free)
        assert len(seen) == len(self.free), "free list holds duplicates"
        total = len(self.free)
        for slot, owned in self.pages.items():
            for p in owned:
                assert 0 < p < self.num_pages, (slot, p)
                assert p not in seen, f"page {p} double-owned"
                seen.add(p)
            total += len(owned)
        assert 0 not in seen, "scratch page 0 was allocated"
        assert total == self.num_pages - 1, (total, self.num_pages - 1)

    # -- byte accounting (capacity experiments) ------------------------
    @staticmethod
    def page_bytes_fp16(page_size: int, kv_heads: int, head_dim: int,
                        num_layers: int) -> int:
        """Logical HBM bytes of one fp16/bf16 K+V page across layers."""
        return 2 * num_layers * page_size * kv_heads * head_dim * SOURCE_BYTES

    @staticmethod
    def page_bytes_quant(page_size: int, kv_heads: int, head_dim: int,
                         num_layers: int, bits: int, group: int) -> int:
        """Logical HBM bytes of one quantized K+V page (codes + fp16
        scales at one scale per ``group`` channels per token)."""
        elems = num_layers * page_size * kv_heads * head_dim
        per_tensor = elems * bits // 8 + (elems // group) * SCALE_BYTES
        return 2 * per_tensor
