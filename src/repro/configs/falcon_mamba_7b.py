"""Config alias for --arch falcon-mamba-7b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("falcon-mamba-7b")
