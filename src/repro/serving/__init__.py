from repro.serving.kvstore import (
    SLO_CLASSES,
    KVTier,
    PrefixKVStore,
    StoreEntry,
    TierHit,
    TierSpec,
    TieredKVStore,
    default_tier_specs,
    slo_rank,
)
from repro.serving.network import (
    GBPS,
    BandwidthTrace,
    GoodputEstimator,
    KVWire,
    WireTransfer,
)
from repro.serving.request import LIFECYCLE, Request, WorkloadMix, kv_bytes_for
from repro.serving.scheduler import (
    AdmissionController,
    ContinuousScheduler,
    SchedulerConfig,
    priority_key,
)
from repro.serving.simulator import (
    KVServePolicy,
    NoCompressionPolicy,
    Policy,
    SimConfig,
    SimResult,
    Simulator,
    StaticPolicy,
)

# NOTE: the real-execution runtime (ServingRuntime / DisaggregatedEngine)
# lives in repro.serving.engine and is imported directly by its users — it
# pulls in the jax model stack, which the simulator-only path doesn't need.

__all__ = [
    "GBPS", "BandwidthTrace", "GoodputEstimator", "KVWire", "WireTransfer",
    "LIFECYCLE", "Request", "WorkloadMix",
    "kv_bytes_for", "KVServePolicy", "NoCompressionPolicy", "Policy",
    "SimConfig", "SimResult", "Simulator", "StaticPolicy",
    "PrefixKVStore", "StoreEntry", "SLO_CLASSES", "slo_rank",
    "KVTier", "TierHit", "TierSpec", "TieredKVStore", "default_tier_specs",
    "ContinuousScheduler", "SchedulerConfig", "AdmissionController",
    "priority_key",
]
