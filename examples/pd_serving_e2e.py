"""End-to-end driver: serve the (trained) tiny reference model through the
REAL PD-disaggregated *continuous* runtime — a prefill stream and a decode
stream joined by a serialized compressed-KV wire, with the full KVServe
stack (offline profiles -> service-aware controller -> bandit feedback).

Each cold request's critical path is prefill -> controller-selected
compress -> wire transfer -> decompress -> decode arena; repeated prompts
hit the decode-side prefix pool instead.  A mid-run bandwidth drop shows
the controller switching profiles on the live goodput estimate.

    PYTHONPATH=src python examples/pd_serving_e2e.py
"""
import numpy as np

from repro.controller import ServiceAwareController
from repro.core.strategy import BASELINES, StrategyConfig
from repro.data.synthetic import WORKLOADS
from repro.launch.profile_offline import build_profiles
from repro.serving import GBPS, BandwidthTrace, SchedulerConfig
from repro.serving.engine import RuntimeConfig, ServingRuntime


def main():
    print("== offline profiling (measured CR/throughput/quality) ==")
    profiles = build_profiles(
        [BASELINES["kivi"], BASELINES["cachegen"], BASELINES["mixhq"],
         StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                        granularity="per_channel"),
         StrategyConfig(quantizer="uniform", key_bits=4, value_bits=4,
                        granularity="per_channel", codec="zstd3")],
        quality_kwargs={"n_prompts": 4, "decode_tokens": 12}, verbose=True)

    controller = ServiceAwareController({w: profiles for w in WORKLOADS})
    # bandwidth drops mid-run (virtual-clock seconds): watch the
    # controller switch profiles once the goodput estimate catches up
    trace = BandwidthTrace.steps(
        [(0.0, 0.2 * GBPS), (0.15, 0.002 * GBPS), (1.4, 0.2 * GBPS)],
        jitter=0.1, seed=0)
    rt = ServingRuntime(
        controller=controller,
        config=RuntimeConfig(seq=96, decode_tokens=16,
                             prefill_tok_s=2000.0, decode_tok_s=500.0,
                             mode="pd"),
        trace=trace,
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=64))

    print("\n== continuous PD serving across the bandwidth drop ==")
    rng = np.random.default_rng(0)
    for i in range(20):
        w = list(WORKLOADS)[int(rng.integers(0, 4))]
        # a few repeated prompt seeds => decode-side prefix-pool hits
        rt.submit(w, q_min=0.3, prompt_seed=int(rng.integers(0, 12)))
        rt.step()
    done = rt.run()

    print(f"{'arr':>5s} {'workload':10s} {'chosen profile':42s} {'hit':>3s} "
          f"{'jct':>7s} {'comm':>7s} {'ttft':>7s}")
    for r in sorted(done, key=lambda r: r.arrival):
        print(f"{r.arrival:5.1f} {r.workload:10s} {r.profile:42s} "
              f"{'y' if r.pool_hit else 'n':>3s} {r.jct:7.3f} "
              f"{r.breakdown.get('comm', 0.0):7.3f} {r.ttft:7.3f}")

    s = rt.summary()
    print(f"\nsummary: completed={s['completed']:.0f} "
          f"pool_hit_rate={s['pool_hit_rate']:.2f} "
          f"mean_jct={s['mean_jct']:.3f}s "
          f"wire={s['wire_bytes_moved']/1e6:.2f}MB over "
          f"{s['wire_transfers']:.0f} transfers")
    print("\ngenerated sample (decode-stream output):")
    print(" ", repr(done[-1].text[:60]))


if __name__ == "__main__":
    main()
