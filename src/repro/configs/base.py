"""Model + shape configuration system."""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention features
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0          # window size for local layers
    local_global_period: int = 0     # >0: alternate local/global with period 2
    rope_theta: float = 10000.0
    mrope: bool = False              # qwen2-vl 3-section M-RoPE

    # MLP / MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_period: int = 1              # MoE layer every `moe_period` layers
    moe_dense_prefix: int = 0        # first k layers use dense MLP (deepseek)
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"         # einsum (GShard dispatch) | sort

    # SSM (mamba-1)
    ssm: bool = False
    attn_period: int = 0             # hybrid: one attn layer per period (jamba)
    attn_offset: int = 0             # position of the attn layer in the period
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    enc_layers: int = 0
    dec_seq: int = 448               # decoder length for enc-dec shapes
    frontend: str = "none"           # audio | vision stub

    # vlm stub
    vision_prefix_frac: float = 0.0  # fraction of seq filled by patch embeds

    # misc
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    lr_schedule: str = "cosine"      # cosine | wsd (minicpm)
    sub_quadratic: bool = False      # eligible for long_500k

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        layers = self.num_layers + (self.enc_layers if self.encoder_decoder else 0)
        attn_params = d * (self.num_heads * hd) + 2 * d * (self.kv_heads * hd) \
            + (self.num_heads * hd) * d
        for i in range(layers):
            if self._layer_kind(i) == "ssm":
                di, s, r = self.d_inner, self.ssm_state, self.dt_rank
                n += d * 2 * di + di * self.ssm_conv + di * (r + 2 * s)
                n += r * di + di * s + di + di * d
            else:
                n += attn_params
        if self.encoder_decoder:  # decoder cross-attention blocks
            n += self.num_layers * attn_params
        if self.d_ff > 0:
            for i in range(layers):
                if self._layer_is_moe(i):
                    n += d * self.num_experts  # router
                    n += self.num_experts * 3 * d * self.d_ff
                    n += self.num_shared_experts * 3 * d * self.d_ff
                else:
                    n += 3 * d * self.d_ff
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts that fire)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        # subtract inactive expert params
        n_moe_layers = sum(1 for i in range(self.num_layers) if self._layer_is_moe(i))
        inactive = (self.num_experts - self.experts_per_token)
        total -= n_moe_layers * inactive * 3 * d * self.d_ff
        return int(total)

    # which layers are what
    def _layer_kind(self, i: int) -> str:
        if self.encoder_decoder:
            return "attn"
        if self.ssm and self.attn_period == 0:
            return "ssm"
        if self.ssm and self.attn_period > 0:
            return "attn" if (i % self.attn_period) == self.attn_offset else "ssm"
        return "attn"

    def _layer_is_moe(self, i: int) -> bool:
        if not self.moe:
            return False
        if i < self.moe_dense_prefix:
            return False
        return ((i - self.moe_dense_prefix) % self.moe_period) == 0

    def _layer_is_local(self, i: int) -> bool:
        if self.local_global_period <= 0:
            return False
        return (i % 2) == 0  # even layers local, odd global (gemma2)

    def layer_specs(self) -> List["LayerSpec"]:
        return [
            LayerSpec(
                kind=self._layer_kind(i),
                moe=self._layer_is_moe(i),
                local=self._layer_is_local(i),
            )
            for i in range(self.num_layers)
        ]

    def scan_period(self) -> int:
        """Length of the repeating layer pattern (for scan-over-layers)."""
        specs = self.layer_specs()
        for period in (1, 2, 4, 8, 16):
            if len(specs) % period:
                continue
            blocks = [tuple(specs[i : i + period]) for i in range(0, len(specs), period)]
            if all(b == blocks[0] for b in blocks):
                return period
        return 0  # irregular — no scan


@dataclass(frozen=True)
class LayerSpec:
    kind: str  # attn | ssm
    moe: bool
    local: bool


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", 4096, 256),
    ShapeSpec("prefill_32k", "prefill", 32768, 32),
    ShapeSpec("decode_32k", "decode", 32768, 128),
    ShapeSpec("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def supported_shapes(cfg: ModelConfig) -> List[str]:
    """Shape cells this arch runs; long_500k only for sub-quadratic archs."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    period = max(cfg.scan_period(), 1)
    n_layers = max(2 * period, period)
    if cfg.encoder_decoder:
        n_layers = 2
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.kv_heads, heads if cfg.kv_heads >= cfg.num_heads else 2))
    return replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=n_layers,
        enc_layers=2 if cfg.encoder_decoder else 0,
        d_model=64,
        num_heads=heads,
        kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8) if cfg.moe else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.moe else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        moe_dense_prefix=min(cfg.moe_dense_prefix, 1),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        ssm_state=min(cfg.ssm_state, 8),
        dec_seq=16 if cfg.encoder_decoder else cfg.dec_seq,
        attn_period=cfg.attn_period,
        attn_offset=min(cfg.attn_offset, max(cfg.attn_period - 1, 0)),
    )
