"""Checkpointing: roundtrip, crash safety, pruning, background writes."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32)},
        "opt": {"mu": jnp.zeros((8, 16)), "step": jnp.asarray(3, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree(1)
    mgr.save(10, t, metadata={"loss": 1.5})
    out = mgr.restore(_tree(99))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.metadata() == {"loss": 1.5}


def test_latest_step_and_pruning(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (5, 10, 15, 20):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 20
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(kept) == 2 and kept == ["step_00000015", "step_00000020"]


def test_incomplete_checkpoint_ignored(tmp_path):
    """A crash mid-write (no manifest) must not break restore."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(10, _tree(2))
    # simulate a crashed writer: directory without manifest
    bad = Path(tmp_path) / "step_00000020"
    bad.mkdir()
    np.save(bad / "leaf_00000.npy", np.zeros(3))
    assert mgr.latest_step() == 10
    out = mgr.restore(_tree(0))
    assert out is not None


def test_background_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, _tree(3), background=True)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_restore_resumes_training(tmp_path):
    """Crash/restart: restored state continues bit-identically."""
    from repro.configs import get_config
    from repro.configs.base import reduce_config
    from repro.data.synthetic import make_batch
    from repro.distribution.optimizer import OptConfig, init_opt_state
    from repro.distribution.steps import make_train_step
    from repro.models import init_params

    cfg = reduce_config(get_config("qwen3-4b"))
    params, _ = init_params(cfg, seed=0)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, oc, remat=False))

    def batch(i):
        tokens, mask = make_batch("mixed", 2, 16, seed=i)
        tokens = np.minimum(tokens, cfg.vocab_size - 1)
        return {"tokens": jnp.asarray(tokens), "mask": jnp.asarray(mask[:, 1:])}

    # run 4 steps, checkpoint at 2
    mgr = CheckpointManager(tmp_path)
    p, o = params, opt
    for i in range(4):
        p, o, m = step(p, o, batch(i))
        if i == 1:
            mgr.save(2, {"params": p, "opt": o})
    loss_direct = float(m["loss"])

    # crash -> restore at 2 -> replay steps 2,3
    st = mgr.restore({"params": params, "opt": opt})
    p2, o2 = st["params"], st["opt"]
    for i in (2, 3):
        p2, o2, m2 = step(p2, o2, batch(i))
    assert abs(float(m2["loss"]) - loss_direct) < 1e-5
