"""Paper Fig. 4: KV latency across effective bandwidths per method, and the
bandwidth thresholds B* where compression stops being beneficial
(Theorem 6.1) — the two-intersection structure.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_profiles, emit
from repro.controller import bandwidth_threshold, normalized_latency
from repro.serving.network import GBPS


def run(smoke: bool = False) -> None:
    # analytic once the profile set is cached: the smoke path IS the
    # full path
    profiles = cached_profiles()
    named = {}
    for p in profiles:
        n = p.strategy.short_name()
        if "cachegen" in n:
            named["cachegen"] = p
        elif "kivi" in n:
            named["kivi"] = p
        elif "mixhq" in n:
            named["mixhq"] = p

    t0 = time.perf_counter()
    for name, p in named.items():
        bstar = bandwidth_threshold(p)
        emit(f"fig4_threshold_{name}", (time.perf_counter() - t0) * 1e6,
             f"cr={p.cr:.2f} s_eff={p.s_eff/1e6:.1f}MB/s "
             f"Bstar={bstar/GBPS:.2f}Gbps")
        t0 = time.perf_counter()

    # lower-envelope switching structure: which method is optimal per B
    for bw_gbps in (0.05, 0.2, 0.5, 1.0, 2.0, 8.0, 32.0):
        x = 1.0 / (bw_gbps * GBPS)
        lat = {n: normalized_latency(p, x) for n, p in named.items()}
        lat["default"] = x
        best = min(lat, key=lat.get)
        emit(f"fig4_best_at_{bw_gbps}gbps", 0.0,
             f"best={best} " + " ".join(f"{n}={v:.3e}" for n, v in lat.items()))


if __name__ == "__main__":
    run()
