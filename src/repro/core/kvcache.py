"""Canonical host-side KV cache container used on the network path.

Layout: ``k, v : (num_layers, kv_heads, seq, head_dim)`` float32 arrays that
*logically* represent bf16 wire data (2 bytes/elem), matching the paper's
BF16 baseline accounting.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.strategy import SOURCE_BYTES


@dataclass
class KVCache:
    k: np.ndarray  # (L, H, S, D)
    v: np.ndarray  # (L, H, S, D)

    def __post_init__(self):
        assert self.k.shape == self.v.shape, (self.k.shape, self.v.shape)
        assert self.k.ndim == 4

    @property
    def shape(self):
        return self.k.shape

    @property
    def num_layers(self) -> int:
        return self.k.shape[0]

    @property
    def kv_heads(self) -> int:
        return self.k.shape[1]

    @property
    def seq(self) -> int:
        return self.k.shape[2]

    @property
    def head_dim(self) -> int:
        return self.k.shape[3]

    def nbytes_wire(self) -> int:
        """Bytes of the uncompressed payload on the wire (logical bf16)."""
        return int(self.k.size + self.v.size) * SOURCE_BYTES

    @staticmethod
    def random(num_layers=4, kv_heads=4, seq=128, head_dim=64, seed=0,
               scale: float = 1.0) -> "KVCache":
        rng = np.random.default_rng(seed)
        shape = (num_layers, kv_heads, seq, head_dim)
        # Heavy-tailed, channel-structured data resembling real KV statistics:
        # per-channel means + a few outlier channels (motivating Hadamard).
        base_k = rng.standard_normal(shape).astype(np.float32)
        base_v = rng.standard_normal(shape).astype(np.float32)
        chan_scale = np.exp(rng.standard_normal((1, 1, 1, head_dim)) * 0.5)
        outliers = rng.random((1, 1, 1, head_dim)) < 0.03
        chan_scale = chan_scale * np.where(outliers, 8.0, 1.0)
        k = (base_k * chan_scale + rng.standard_normal((1, 1, 1, head_dim))) * scale
        v = base_v * scale
        return KVCache(k.astype(np.float32), v.astype(np.float32))

    def allclose(self, other: "KVCache", atol=1e-5, rtol=1e-5) -> bool:
        return bool(
            np.allclose(self.k, other.k, atol=atol, rtol=rtol)
            and np.allclose(self.v, other.v, atol=atol, rtol=rtol)
        )
