"""Paper Fig. 16-right: controller behaviour under bandwidth fluctuation
(0-60s trace with a mid-run drop), comparing full KVServe vs w/o Bandit vs
w/o Controller (max-CR static pick)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_profiles, emit
from repro.controller import ServiceAwareController
from repro.data.synthetic import WORKLOADS
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    SimConfig,
    Simulator,
    WorkloadMix,
)


def _trace():
    # bandwidth drop in the 20-40s window (the paper's shaded region)
    return BandwidthTrace.steps(
        [(0.0, 1.0 * GBPS), (20.0, 0.05 * GBPS), (40.0, 1.0 * GBPS)],
        jitter=0.25, seed=5)


def run(smoke: bool = False) -> None:
    profiles = cached_profiles()
    n = 30 if smoke else 70
    reqs = lambda: WorkloadMix(rate=1.2, seed=3, q_min=0.0).generate(n)

    variants = {
        "kvserve": dict(use_bandit=True, use_envelope=True),
        "wo_bandit": dict(use_bandit=False, use_envelope=True),
        "wo_controller": dict(use_bandit=False, use_envelope=False),
    }
    results = {}
    for name, kw in variants.items():
        t0 = time.perf_counter()
        controller = ServiceAwareController(
            {w: profiles for w in WORKLOADS}, **kw)
        res = Simulator(SimConfig(estimator_alpha=0.5),
                        KVServePolicy(controller), _trace(), reqs()).run()
        us = (time.perf_counter() - t0) * 1e6
        # KV-path latency during the drop window (the paper's spike plot)
        drop = [r for r in res.requests if 20.0 <= r.arrival <= 40.0]
        kv_lat = np.mean([r.breakdown.get("compress", 0)
                          + r.breakdown.get("comm", 0)
                          + r.breakdown.get("decompress", 0) for r in drop])
        results[name] = kv_lat
        emit(f"fig16r_{name}", us,
             f"mean_jct={res.mean_jct():.2f}s drop_window_kvlat={kv_lat:.2f}s "
             f"p95={res.p95_jct():.2f}s")

    emit("fig16r_summary", 0.0,
         f"kvserve_vs_wo_controller="
         f"{results['wo_controller']/max(results['kvserve'],1e-9):.2f}x_better")


if __name__ == "__main__":
    run()
