"""Serving launcher: drives the real-execution disaggregated engine with
the Service-Aware Controller over a bandwidth trace.

``python -m repro.launch.serve --requests 12 --bandwidth-gbps 1``
"""
from __future__ import annotations

import argparse
import sys
from typing import List

import numpy as np

from repro.controller import ServiceAwareController
from repro.core.profiles import load_profiles
from repro.data.synthetic import WORKLOADS
from repro.serving.engine import DisaggregatedEngine
from repro.serving.network import GBPS, BandwidthTrace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profiles", default="",
                    help="profiles.jsonl from profile_offline (else built-in)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--bandwidth-gbps", type=float, default=1.0)
    ap.add_argument("--slo", type=float, default=0.0)
    ap.add_argument("--q-min", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.profiles:
        profiles = load_profiles(args.profiles)
    else:
        from repro.launch.profile_offline import build_profiles
        from repro.core.strategy import BASELINES
        profiles = build_profiles(list(BASELINES.values()),
                                  quality_kwargs={"n_prompts": 4,
                                                  "decode_tokens": 12})

    controller = ServiceAwareController(
        {w: profiles for w in WORKLOADS})
    engine = DisaggregatedEngine(controller=controller)
    trace = BandwidthTrace.constant(args.bandwidth_gbps * GBPS)

    rng = np.random.default_rng(args.seed)
    names = list(WORKLOADS)
    print(f"{'workload':10s} {'profile':40s} {'jct':>8s} {'comm':>8s} "
          f"{'agree':>6s} {'wire':>10s}")
    for i in range(args.requests):
        w = names[int(rng.integers(0, len(names)))]
        res = engine.serve(w, trace, t_slo=args.slo, q_min=args.q_min,
                           seed=args.seed * 1000 + i)
        print(f"{w:10s} {res.profile:40s} {res.jct:8.3f} {res.t_comm:8.3f} "
              f"{res.agreement:6.3f} {res.wire_bytes:10d}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
