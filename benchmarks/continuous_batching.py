"""Continuous-batching serving runtime (DESIGN.md §9, EXPERIMENTS.md
§Serving): offered load × SLO mix × store capacity, plus the slot-arena
decode scaling sweep.

Part A drives the *real-execution* ServingRuntime (tiny model, real
compressed bytes, modelled loaded-cluster compute) and checks the two
acceptance properties: ≥4 concurrent in-flight requests, and prefix-pool
hits beating cold prefill on TTFT.

Part B is the slots-vs-step-time sweep: per-iteration decode wall-clock
of the batched slot arena (ONE jitted call for all slots) against the
PR-1 per-slot loop (one batch-1 call + host round-trip per slot), with a
token-exact parity check between the two paths.  The arena must stay
within 2× of its 1-slot step time at 8 slots; the loop degrades ~N×.

Part C sweeps the event-driven simulator through the same shared
scheduler/store code path at scale.

CLI: ``--smoke`` shrinks everything to CI-sized settings (and skips the
hard scaling assertion — timing on shared CI runners is advisory);
``--json PATH`` archives the emitted rows as JSON.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import emit, write_json
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import (
    GBPS,
    BandwidthTrace,
    NoCompressionPolicy,
    PrefixKVStore,
    SchedulerConfig,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)

WORKLOAD_CYCLE = ("qalike", "codelike", "mathlike", "summlike")


def _pool_profile() -> Profile:
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel",
                                  codec="zstd3"),
                   cr=3.0, s_enc=5e8, s_dec=5e8)


# ---------------------------------------------------------------------------
def run_runtime(smoke: bool = False) -> None:
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    cfg = RuntimeConfig(seq=32 if smoke else 96, decode_tokens=4 if smoke else 8,
                        prefill_tok_s=2000.0, decode_tok_s=500.0)
    rt = ServingRuntime(
        static_profile=_pool_profile(), config=cfg,
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=64))
    # 12 requests over 4 workloads; repeated prompt seeds => pool hits.
    t0 = time.perf_counter()
    for i, w in enumerate(WORKLOAD_CYCLE * 3):
        rt.submit(w, slo_class=("interactive", "standard", "batch")[i % 3],
                  prompt_seed=i % 4)
        rt.step()
    rt.run()
    us = (time.perf_counter() - t0) * 1e6
    s = rt.summary()
    assert s["max_in_flight"] >= 4, s
    assert s["mean_ttft_hit"] < s["mean_ttft_cold"], s
    emit("runtime_continuous_batching", us,
         f"completed={s['completed']} max_in_flight={s['max_in_flight']} "
         f"pool_hit_rate={s['pool_hit_rate']:.2f} "
         f"ttft_hit={s['mean_ttft_hit']*1e3:.1f}ms "
         f"ttft_cold={s['mean_ttft_cold']*1e3:.1f}ms "
         f"speedup={s['mean_ttft_cold']/s['mean_ttft_hit']:.1f}x")


# ---------------------------------------------------------------------------
def run_slots_sweep(smoke: bool = False,
                    slot_counts: Sequence[int] = (1, 2, 4, 8)) -> Dict[int, Dict[str, float]]:
    """Per-iteration decode wall-clock vs active slot count, arena vs the
    PR-1 per-slot loop, with a token-exact parity check."""
    import jax.numpy as jnp
    from repro.core.quality import (_jitted_steps, _prompts_for,
                                    copy_cache_slot, get_reference_model)
    from repro.models.transformer import init_cache

    seq = 24 if smoke else 64
    steps = 6 if smoke else 16
    cfg, params = get_reference_model()
    max_len = seq + steps + 2
    pre1, dec1, _ = _jitted_steps(cfg.name, seq, 1, max_len)

    # One batch-1 prefill per slot, shared by both decode paths.
    caches1, firsts = [], []
    for i in range(max(slot_counts)):
        tokens, _ = _prompts_for(WORKLOAD_CYCLE[i % 4], 1, seq, seed=i)
        logits, c = pre1(params, {"tokens": tokens})
        caches1.append(c)
        firsts.append(int(np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))[0]))

    results: Dict[int, Dict[str, float]] = {}
    for n in slot_counts:
        # ---- batched arena: ONE masked jitted call per iteration ----
        _, _, arena_dec = _jitted_steps(cfg.name, seq, n, max_len)
        arena = init_cache(cfg, n, max_len)
        for i in range(n):
            arena = copy_cache_slot(cfg, arena, caches1[i], i)
        pos = np.full(n, seq, np.int32)
        last = np.asarray(firsts[:n], np.int32)
        mask = jnp.ones(n, bool)
        arena_toks: List[List[int]] = [[int(f)] for f in last]
        arena_times = []
        for _ in range(steps):
            t0 = time.perf_counter()
            nxt, arena = arena_dec(params, arena, jnp.asarray(last[:, None]),
                                   jnp.asarray(pos), mask)
            nxt = np.asarray(nxt)       # the iteration's single host pull
            arena_times.append(time.perf_counter() - t0)
            for i in range(n):
                arena_toks[i].append(int(nxt[i]))
                last[i] = nxt[i]
                pos[i] += 1

        # ---- PR-1 loop: batch-1 call + host argmax per slot ----
        loop_caches = list(caches1[:n])
        loop_toks: List[List[int]] = [[int(f)] for f in firsts[:n]]
        loop_times = []
        for t in range(steps):
            t0 = time.perf_counter()
            for i in range(n):
                logits, loop_caches[i] = dec1(
                    params, loop_caches[i],
                    jnp.asarray([[loop_toks[i][-1]]], jnp.int32),
                    jnp.asarray(seq + t, jnp.int32))
                loop_toks[i].append(int(np.asarray(
                    jnp.argmax(logits[:, -1, :], axis=-1))[0]))
            loop_times.append(time.perf_counter() - t0)

        # token-exact parity vs the pre-refactor decode path
        assert arena_toks == loop_toks, f"token mismatch at n={n}"

        # medians: first iterations absorb jit compilation
        arena_ms = float(np.median(arena_times) * 1e3)
        loop_ms = float(np.median(loop_times) * 1e3)
        results[n] = {"arena_ms": arena_ms, "loop_ms": loop_ms}
        emit(f"slots_sweep_n{n}", arena_ms * 1e3,
             f"arena_ms_per_step={arena_ms:.3f} "
             f"per_slot_loop_ms_per_step={loop_ms:.3f} "
             f"token_parity=exact")

    lo, hi = min(slot_counts), max(slot_counts)
    arena_ratio = results[hi]["arena_ms"] / results[lo]["arena_ms"]
    loop_ratio = results[hi]["loop_ms"] / results[lo]["loop_ms"]
    emit("slots_sweep_scaling", 0.0,
         f"arena_{hi}v{lo}_ratio={arena_ratio:.2f} "
         f"loop_{hi}v{lo}_ratio={loop_ratio:.2f}")
    if not smoke:
        # Acceptance: batched decode amortizes across slots (≤2× at 8
        # slots), where the per-slot loop degraded ~linearly.
        assert arena_ratio <= 2.0, results
    return results


# ---------------------------------------------------------------------------
def run_sweep(smoke: bool = False) -> None:
    # 4-bit + zstd pool profile: a fetch moves ~1/6 of the KV bytes.
    prof = Profile(StrategyConfig(quantizer="uniform", key_bits=4,
                                  value_bits=4, granularity="per_channel",
                                  codec="zstd3"),
                   cr=6.0, s_enc=1e9, s_dec=1e9)
    trace = BandwidthTrace.constant(1 * GBPS)
    mixes = {
        "uniform": None,
        "tiered": {"interactive": 0.3, "standard": 0.4, "batch": 0.3},
    }
    n_requests = 30 if smoke else 120
    rates = (2.0,) if smoke else (0.5, 2.0, 8.0)
    # 4 prefill nodes x 2000 tok/s over ~4k-token prompts => capacity
    # ~2 req/s: the rates bracket under-load, saturation, and overload.
    for rate in rates:
        for mix_name, mix in mixes.items():
            for cap_name, cap in (("small", int(5e8)), ("large", 1 << 36)):
                reqs = WorkloadMix(rate=rate, seed=11, q_min=0.0,
                                   ctx_scale=0.25, prefix_hit_rate=0.7,
                                   slo_class_mix=mix).generate(n_requests)
                store = PrefixKVStore(capacity_bytes=cap, block=1)
                t0 = time.perf_counter()
                res = Simulator(
                    SimConfig(scenario="pool", prefill_tok_s=2000.0),
                    StaticPolicy(prof, "pool"), trace, reqs, store=store,
                    scheduler=SchedulerConfig(max_queue=40),
                ).run()
                us = (time.perf_counter() - t0) * 1e6
                done = res.completed()
                # Three-way: full hits (fetch only), partial hits (fetch +
                # top-up prefill for the uncovered suffix), cold recomputes.
                fetched = lambda r: r.breakdown.get("comm", 0) > 0
                refill = lambda r: r.breakdown.get("prefill", 0) > 0
                hits = [r for r in done if fetched(r) and not refill(r)]
                partial = [r for r in done if fetched(r) and refill(r)]
                colds = [r for r in done if refill(r) and not fetched(r)]
                mean = lambda rs: (float(np.mean([r.ttft for r in rs]))
                                   if rs else 0.0)
                emit(f"sweep_rate{rate:g}_{mix_name}_{cap_name}", us,
                     f"hit_rate={store.stats.hit_rate:.2f} "
                     f"evictions={store.stats.evictions} "
                     f"rejected={len(res.rejected())} "
                     f"ttft_hit={mean(hits):.3f}s "
                     f"ttft_partial={mean(partial):.3f}s(n={len(partial)}) "
                     f"ttft_cold={mean(colds):.3f}s "
                     f"p95_ttft={np.percentile(res.ttft(), 95):.3f}s")


def run(smoke: bool = False) -> None:
    run_sweep(smoke)
    run_runtime(smoke)
    run_slots_sweep(smoke)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings; crash = fail, timing advisory")
    ap.add_argument("--json", default="",
                    help="archive emitted rows to this JSON path")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
