"""Multi-worker disaggregated cluster runtime (DESIGN.md §10).

:class:`ClusterRuntime` composes N :class:`~repro.serving.workers.PrefillWorker`
x M :class:`~repro.serving.workers.DecodeWorker` under ONE shared
:class:`~repro.serving.scheduler.ContinuousScheduler` (admission control +
SLO-class priority queue) and a
:class:`~repro.serving.topology.NetworkTopology` of per-(src, dst)
serialized KV links.  Each ``step()`` is one iteration of the whole
cluster:

  1. **Admission + routing** — waiting requests are popped in priority
     order while an eligible route exists (prefill worker under its
     per-iteration admission cap, decode worker with a free arena slot);
     the :class:`Router` places each request on a (prefill -> decode)
     route.  Requests on the same prefill worker serialize within the
     iteration; distinct workers — and distinct links — overlap.
  2. **Decode streams** — every decode worker advances all of its
     previously-running slots one token with a single masked jitted arena
     decode.
  3. **Clocking** — the iteration costs ``max`` over every started
     request's start-of-life path and every decode worker's stream; the
     difference is charged per slot as ``stall`` so per-request breakdowns
     still sum exactly to JCT.

Routing policies:

* :class:`RoundRobinRouter` — the placement baseline: cycle the (src,
  dst) pairs in mesh order, skipping ineligible routes.
* :class:`LoadAwareRouter` — predicted-latency argmin over eligible
  routes, combining the controller's latency model (Eq. 1, evaluated at
  the route's own per-link goodput estimate), live queue depths (in-step
  prefill backlog, link reservations, decode occupancy) and decode-side
  prefix affinity (a worker already holding the request's prefix serves
  it without prefill or cold transfer).  FlowKV-style load awareness and
  compression become one placement decision.

A 1x1 ``ClusterRuntime`` IS the single-engine runtime: the
:class:`~repro.serving.engine.ServingRuntime` facade subclasses it, and
the pinned PR-1 token fixture holds bit-for-bit in both ``pool`` and
``pd`` modes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.controller import ServiceAwareController, ServiceContext, TierFetch
from repro.controller.latency_model import (
    baseline_latency,
    predicted_latency,
)
from repro.core.profiles import Profile
from repro.core.quality import _prompts_for, get_reference_model
from repro.data.tokenizer import ByteTokenizer
from repro.serving.kvstore import (
    KVTier,
    TierHit,
    TierSpec,
    TieredKVStore,
    default_tier_specs,
)
from repro.serving.metrics import latency_summary, route_counts
from repro.serving.network import (
    BandwidthTrace,
    GoodputEstimator,
    KVWire,
    seed_bandwidth,
)
from repro.serving.request import Request, kv_bytes_for
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig
from repro.serving.topology import NetworkTopology, route_name
from repro.serving.workers import (
    DecodeWorker,
    ModelHandle,
    PrefillWorker,
    RuntimeConfig,
    ServedRequest,
    Slot,
    codec_cost,
    decompress_kvs,
    recompress_entry,
)


@dataclass
class Route:
    """One (prefill worker -> decode worker) placement option."""

    index: int                    # position in the mesh-order route list
    prefill: PrefillWorker
    decode: DecodeWorker
    link: KVWire                  # the pair's serialized transfer wire
    estimator: GoodputEstimator   # the link's goodput view (controller B)
    name: str                     # "p0->d1"


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------
class Router:
    """Placement policy: pick one of the iteration's eligible routes."""

    name = "base"

    def choose(self, req: Request, eligible: List[Route], now: float,
               cluster: "ClusterRuntime") -> Route:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """The baseline: cycle the mesh-order route list, skipping routes that
    are ineligible this iteration (admission cap hit / no free slot)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, req, eligible, now, cluster):
        n = max(len(cluster.routes), 1)
        choice = min(eligible, key=lambda r: (r.index - self._next) % n)
        self._next = (choice.index + 1) % n
        return choice


class LoadAwareRouter(Router):
    """Predicted-latency argmin over the eligible routes (ties broken by
    mesh order, so placement stays deterministic)."""

    name = "load_aware"

    def choose(self, req, eligible, now, cluster):
        return min(eligible,
                   key=lambda r: (cluster.route_cost(req, r, now), r.index))


ROUTERS = {"round_robin": RoundRobinRouter, "load_aware": LoadAwareRouter}


# ---------------------------------------------------------------------------
# The cluster runtime
# ---------------------------------------------------------------------------
class ClusterRuntime:
    """Iteration-level serving of the tiny reference model across N
    prefill x M decode workers joined by per-pair serialized KV links."""

    def __init__(self, controller: Optional[ServiceAwareController] = None,
                 static_profile: Optional[Profile] = None,
                 config: Optional[RuntimeConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 store: Optional[Any] = None,
                 trace: Optional[BandwidthTrace] = None,
                 topology: Optional[NetworkTopology] = None,
                 n_prefill: Optional[int] = None,
                 n_decode: Optional[int] = None,
                 router: Union[str, Router] = "load_aware",
                 slots_per_worker: Optional[int] = None):
        self.cfg = config or RuntimeConfig()
        self.controller = controller
        self.static_profile = static_profile
        self.scheduler = ContinuousScheduler(scheduler or SchedulerConfig(),
                                             manage_slots=False)
        self.trace = trace or BandwidthTrace.constant(1e9)
        if topology is None:
            topology = NetworkTopology(n_prefill or 1, n_decode or 1,
                                       default_trace=self.trace)
        elif ((n_prefill is not None and n_prefill != topology.n_prefill)
              or (n_decode is not None and n_decode != topology.n_decode)):
            # Same contract as the Simulator: a topology's dimensions ARE
            # the cluster's — a conflicting explicit worker count is a
            # configuration error, not something to silently override.
            raise ValueError(
                f"topology is {topology.n_prefill}x{topology.n_decode} "
                f"but n_prefill={n_prefill}, n_decode={n_decode} were "
                f"requested")
        self.topology = topology
        self.n_prefill = self.topology.n_prefill
        self.n_decode = self.topology.n_decode
        self.router: Router = (ROUTERS[router]() if isinstance(router, str)
                               else router)
        self._model = ModelHandle(*get_reference_model())
        # Cluster-level estimator: the shared remote pool's goodput view
        # (pool mode feeds it through the store's observe_goodput tier).
        # PD contexts use each route's PER-LINK estimator instead; the
        # cluster-level one then aliases the primary link's so the 1x1
        # facade exposes the estimator its wire actually feeds.
        self.estimator = GoodputEstimator(initial=seed_bandwidth(self.trace))
        if self.cfg.mode == "pd":
            self.estimator = self.topology.estimator(0, 0)

        # ---- workers ----
        n_slots = (slots_per_worker if slots_per_worker is not None
                   else self.scheduler.cfg.max_slots)
        self.prefill_workers = [
            PrefillWorker(i, self._model, self.cfg, controller,
                          static_profile)
            for i in range(self.n_prefill)]
        self.decode_workers = [
            DecodeWorker(j, self._model, self.cfg, n_slots,
                         self._build_store(store, j))
            for j in range(self.n_decode)]
        if self.n_decode == 1 and n_slots == self.scheduler.cfg.max_slots:
            # Legacy introspection parity: with a single decode worker the
            # scheduler's free-slot list IS the worker's (same object), so
            # existing tooling that inspects scheduler._free_slots keeps
            # seeing the live pool.
            self.scheduler._free_slots = self.decode_workers[0].free_slots

        # ---- mesh-order route table ----
        self.routes: List[Route] = []
        for idx, (i, j) in enumerate(self.topology.pairs()):
            self.routes.append(Route(
                index=idx, prefill=self.prefill_workers[i],
                decode=self.decode_workers[j],
                link=self.topology.link(i, j),
                estimator=self.topology.estimator(i, j),
                name=route_name(i, j)))

        self.tok = ByteTokenizer()
        self.clock = 0.0
        self.steps = 0
        self.completed: List[ServedRequest] = []
        self.step_log: List[Dict[str, float]] = []
        self._prompts: Dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._step_busy: List[float] = [0.0] * self.n_prefill

    # ------------------------------------------------------------------
    # Store construction (per decode worker)
    # ------------------------------------------------------------------
    def _ingress(self, j: int) -> Tuple[int, int]:
        """Decode worker ``j``'s primary ingress link (its PD pool tier
        sits across this wire): the same-index prefill worker, wrapped."""
        return (j % self.n_prefill, j)

    def _build_store(self, store: Optional[Any], j: int) -> Any:
        cfg = self.cfg
        if store is not None:
            if self.n_decode != 1:
                raise ValueError("an explicit store requires a single "
                                 "decode worker (per-worker hierarchies "
                                 "are built from config.tiers)")
            if isinstance(store, TieredKVStore):
                if store.estimator is None:
                    store.estimator = self.estimator
                if store.recompress is None:
                    store.recompress = recompress_entry
                return store
            st = TieredKVStore.wrap_flat(
                store, self.trace,
                fetch_overhead=cfg.pool_fetch_overhead,
                estimator=self.estimator)
            st.recompress = recompress_entry
            return st

        if cfg.tiers is not None:
            specs = list(cfg.tiers)
        elif cfg.mode == "pd":
            src, dst = self._ingress(j)
            specs = [TierSpec(
                "remote", cfg.store_capacity,
                bandwidth=self.topology.trace(src, dst),
                fetch_overhead=cfg.pool_fetch_overhead,
                observe_goodput=True)]
        else:
            specs = default_tier_specs(
                cfg.store_capacity, self.trace,
                remote_overhead=cfg.pool_fetch_overhead,
                hot_bytes=cfg.hot_tier_bytes,
                dram_bytes=cfg.dram_tier_bytes)
            # HBM/DRAM are worker-local; the remote pool tier is ONE
            # cluster-wide disaggregated store (shared KVTier: shared
            # capacity, entries, and serialized link).
            if self.n_decode > 1:
                if not hasattr(self, "_shared_remote"):
                    self._shared_remote = KVTier(specs[-1], cfg.store_block)
                    # promotion out of the shared pool COPIES (the entry
                    # must stay visible to every other worker's hierarchy)
                    self._shared_remote.shared = True
                specs = list(specs[:-1]) + [self._shared_remote]
        st = TieredKVStore(specs, block=cfg.store_block,
                           estimator=self.estimator,
                           recompress=recompress_entry)
        if cfg.mode == "pd" and not isinstance(specs[-1], KVTier):
            # PD transfers and pool fetches/writes share ONE physical
            # link — the pool sits across the same wire the compressed
            # KV crosses into this worker.  This applies to explicit
            # cfg.tiers TierSpec lists too (same rule as the old
            # single-engine runtime); only a pre-built KVTier passed in
            # keeps its own wire (it may be shared across workers).
            st.tiers[-1].wire = self.topology.link(*self._ingress(j))
        return st

    # ------------------------------------------------------------------
    # Legacy 1x1 surface (the ServingRuntime facade, tests, benchmarks)
    # ------------------------------------------------------------------
    @property
    def model_cfg(self):
        return self._model.cfg

    @model_cfg.setter
    def model_cfg(self, value):
        # lint: own-ok(facade model swap is cluster-wide BY DESIGN - the shared handle is how it reaches every worker)
        self._model.cfg = value

    @property
    def params(self):
        return self._model.params

    @params.setter
    def params(self, value):
        # lint: own-ok(facade param swap is cluster-wide BY DESIGN - tests pin the reference model through it)
        self._model.params = value

    @property
    def store(self):
        """The decode-side store (single-decode-worker deployments)."""
        if self.n_decode == 1:
            return self.decode_workers[0].store
        raise AttributeError("a multi-worker cluster has per-worker "
                             "stores; use .decode_workers[j].store")

    @property
    def wire(self) -> KVWire:
        """The primary (p0 -> d0) transfer link — THE wire of a 1x1
        deployment."""
        return self.topology.link(0, 0)

    @property
    def n_slots(self) -> int:
        """Arena slots per decode worker."""
        return self.decode_workers[0].n_slots

    @property
    def _slots(self) -> Dict[int, Slot]:
        """Merged in-flight slot view across decode workers (read-only)."""
        out: Dict[int, Slot] = {}
        for dw in self.decode_workers:
            out.update(dw.slots)
        return out

    def _distinct_tiers(self) -> List[KVTier]:
        seen, out = set(), []
        for dw in self.decode_workers:
            for t in dw.store.tiers:
                if id(t) not in seen:
                    seen.add(id(t))
                    out.append(t)
        return out

    # ------------------------------------------------------------------
    @property
    def slo_metric_default(self) -> str:
        """Scenario default for requests that don't pin one: the pool
        scenario's SLO is time-to-first-token, PD separation's is JCT."""
        return "jct" if self.cfg.mode == "pd" else "ttft"

    def submit(self, workload: str, t_slo: float = 0.0, q_min: float = 0.97,
               slo_class: str = "standard", out_tokens: Optional[int] = None,
               prompt_seed: int = 0,
               slo_metric: Optional[str] = None) -> Optional[int]:
        """Admit one request at the current virtual time.  Two submissions
        with the same (workload, prompt_seed) share a prompt, so the second
        can be served from the prefix pool.  Returns the request id, or
        None if admission control shed it."""
        if slo_metric not in (None, "ttft", "jct"):
            raise ValueError(f"slo_metric must be 'ttft' or 'jct', "
                             f"got {slo_metric!r}")
        rid = self._next_rid
        self._next_rid += 1
        tokens, _ = _prompts_for(workload, 1, self.cfg.seq, prompt_seed)
        tokens = np.asarray(tokens)[0]
        m = self.model_cfg
        req = Request(
            rid=rid, workload=workload, arrival=self.clock,
            ctx_tokens=self.cfg.seq,
            out_tokens=(self.cfg.decode_tokens if out_tokens is None
                        else min(out_tokens, self.cfg.decode_tokens)),
            kv_bytes=kv_bytes_for(self.cfg.seq, m.num_layers, m.kv_heads,
                                  m.resolved_head_dim),
            t_slo=t_slo, q_min=q_min, slo_class=slo_class,
            slo_metric=slo_metric,
            prefix_key=tuple(int(t) for t in tokens))
        if not self.scheduler.submit(req, self.clock):
            return None
        self._prompts[rid] = tokens
        return rid

    # ------------------------------------------------------------------
    # Load-aware route scoring
    # ------------------------------------------------------------------
    def route_cost(self, req: Request, route: Route, now: float) -> float:
        """Predicted completion-relevant latency of placing ``req`` on
        ``route``: the controller's latency model at the route's own
        bandwidth estimate, plus live queue depths (in-iteration prefill
        backlog, the link's outstanding reservation, decode occupancy) and
        decode-side prefix affinity."""
        cfg = self.cfg
        pw, dw = route.prefill, route.decode
        decode_est = (1.0 / cfg.decode_tok_s) if cfg.decode_tok_s else 0.0
        queue_term = dw.occupancy * decode_est
        key = req.prefix_key
        hit = (dw.store.peek(key, now=now) if key is not None else None)
        if hit is not None:
            # This worker already holds the prefix: no prefill, no cold
            # transfer — but the hit still pays the holding tier's
            # serialized fetch (overhead + outstanding reservation +
            # stored bytes over the tier link), so a prefix stuck behind
            # a slow wire does NOT blindly pin its repeats there.
            tier = hit.tier
            if tier.wire.estimator is not None:      # PD: the ingress link
                bw = tier.wire.estimator.estimate
            elif tier.spec.observe_goodput:          # pool: the remote tier
                bw = self.estimator.estimate
            else:                                    # local HBM/DRAM tier
                bw = tier.trace.at(now)
            return (tier.fetch_overhead
                    + max(tier.wire.free_at - now, 0.0)
                    + hit.entry.wire_bytes / max(bw, 1e-9)
                    + queue_term)
        t_model = (self._step_busy[pw.wid]
                   + pw.expected_prefill_s(req.ctx_tokens))
        if cfg.mode == "pd":
            bandwidth = route.estimator.estimate
            link_wait = max(route.link.free_at - now, 0.0)
            route_id = route.name
        else:
            bandwidth = self.estimator.estimate
            link_wait = 0.0
            route_id = ""
        ctx = ServiceContext(
            workload=req.workload, bandwidth=bandwidth, t_slo=req.t_slo,
            q_min=req.q_min, t_model=t_model, kv_bytes=req.kv_bytes,
            slo_metric=req.resolved_slo_metric(self.slo_metric_default),
            route=route_id, fused_dec=self.cfg.paged)
        predict = getattr(self.controller, "predict", None)
        if predict is not None:
            t = predict(ctx)
        elif self.static_profile is not None:
            t = predicted_latency(self.static_profile, ctx)
        else:
            t = baseline_latency(ctx)
        return t + link_wait + queue_term

    # ------------------------------------------------------------------
    # Start-of-life stages (per route)
    # ------------------------------------------------------------------
    def _spec_k_for(self, decision) -> int:
        """The draft budget a starting request decodes with: 0 when
        speculation is off; the controller's per-request pick (capped at
        cfg.spec_k) under spec_adaptive when a decision carries one —
        pool hits skip the controller and fall back to the uniform
        cfg.spec_k, as does non-adaptive operation."""
        cfg = self.cfg
        if cfg.spec_k <= 0:
            return 0
        if cfg.spec_adaptive and decision is not None:
            return min(max(int(getattr(decision, "spec_k", 0)), 0),
                       cfg.spec_k)
        return cfg.spec_k

    def _maybe_refetch_smaller(self, req: Request, dw: DecodeWorker,
                               hit: TierHit, now: float) -> float:
        """Tier-aware fetch routing: ask the controller to trade fetching
        the stored encoding over the holding tier's link against
        re-encoding it with the pool tier's (most aggressive) demotion
        profile before the transfer — the "refetch smaller" route that
        pays encode time to cross a slow link with fewer bytes.  Returns
        the source-side re-encode time spent ON the request's critical
        path (0.0 when the stored route wins)."""
        import time as _time
        select_fetch = getattr(self.controller, "select_fetch", None)
        if select_fetch is None:
            return 0.0
        tier, e = hit.tier, hit.entry
        small = dw.store.tiers[-1].spec.profile
        if small is None or small.q(req.workload) < req.q_min:
            return 0.0
        bandwidth = (self.estimator.estimate if tier.spec.observe_goodput
                     else tier.trace.at(now))
        common = dict(tier=tier.name, kv_bytes=e.kv_bytes,
                      bandwidth=bandwidth, overhead=tier.fetch_overhead)

        # Under a paged decode arena, paged-eligible encodings land as
        # quantized pages and decode in the fused attention kernel — the
        # fetch option drops its V/s_dec term (DESIGN.md §12).
        def _fused(strategy) -> bool:
            if not self.cfg.paged:
                return False
            from repro.core.strategy import paged_eligible
            comp = e.payload[0]
            head_dim = comp.shape[3] if hasattr(comp, "shape") else None
            return paged_eligible(strategy, head_dim=head_dim)

        stored = TierFetch(variant="stored", wire_bytes=e.wire_bytes,
                           s_dec=e.payload[2],
                           fused_dequant=_fused(e.payload[0].strategy),
                           **common)
        small_bytes = e.kv_bytes / max(small.cr, 1.0)
        if small_bytes >= e.wire_bytes:
            return 0.0
        reenc = TierFetch(variant="reencoded", wire_bytes=small_bytes,
                          s_enc=small.s_enc, s_dec=small.s_dec,
                          fused_dequant=_fused(small.strategy), **common)
        ctx = ServiceContext(
            workload=req.workload, bandwidth=bandwidth, t_slo=req.t_slo,
            q_min=req.q_min, kv_bytes=e.kv_bytes,
            slo_metric=req.resolved_slo_metric(self.slo_metric_default))
        decision = select_fetch(ctx, [stored, reenc])
        if decision is None or decision.option.variant != "reencoded":
            return 0.0
        t0 = _time.perf_counter()
        if not dw.store.reencode(hit, small):
            return 0.0
        # The re-encode happens before the bytes can cross the link: its
        # cost (the enc term of the fetch decision) is on the critical
        # path — measured wall-clock, or V/s_enc under the virtual clock.
        return codec_cost(self.cfg, _time.perf_counter() - t0, e.kv_bytes,
                          small.s_enc)

    def _start_request(self, req: Request, route: Route, now: float,
                       busy: float) -> Tuple[float, float]:
        """Pool-mode start: prefill-or-fetch one admitted request into its
        arena slot (``req.slot``, local to the route's decode worker).  A
        hit never touches the prefill worker — its fetch starts at ``now``
        and contends on the holding tier's serialized link; a miss
        serializes on the route's prefill worker (``busy``) and writes the
        compressed prefix back through the hot tier's link off the
        critical path.  Returns ``(end_offset, new_busy)`` relative to
        ``now``."""
        pw, dw = route.prefill, route.decode
        tokens = self._prompts[req.rid]
        key = req.prefix_key
        idx = req.slot
        dw.ensure_arena()
        # full=True: a partial (block-aligned) prefix hit would leave the
        # uncovered prompt suffix without KV — the runtime has no top-up
        # prefill, so only a full-coverage entry counts as a pool hit.
        hit = dw.store.lookup(key, now=now, full=True)
        bd: Dict[str, float] = {"queue": now - req.arrival}

        if hit is not None:
            # ---- pool hit: fetch real compressed bytes over the holding
            # tier's serialized link, decompress, inject into the slot
            entry = hit.entry
            req.state = "transferring"
            t_reencode = self._maybe_refetch_smaller(req, dw, hit, now)
            tr = dw.store.fetch(hit, ready=now + t_reencode)
            first, t_decompress = dw.fetch_entry(entry, idx)
            cost = (t_reencode + hit.tier.fetch_overhead + tr.t_wait
                    + tr.t_comm + t_decompress)
            bd.update(wire_wait=tr.t_wait,
                      comm=hit.tier.fetch_overhead + tr.t_comm,
                      decompress=t_decompress)
            if t_reencode > 0:
                bd["compress"] = t_reencode
            req.state = "decoding"
            slot = Slot(req=req, idx=idx, toks=[first],
                        pool_hit=True,
                        profile=entry.payload[0].strategy.short_name(),
                        wire_bytes=int(entry.wire_bytes), breakdown=bd,
                        ttft=(now + cost) - req.arrival, route=route.name,
                        spec_k=self._spec_k_for(None))
            dw.occupy(slot, first, prompt=tokens)
            return cost, busy

        # ---- miss: real prefill into the slot (serialized on the route's
        # prefill worker), then write the compressed prefix back
        bd["queue"] += busy
        caches, first, t_prefill = pw.prefill(req, tokens)
        bd.update(prefill=t_prefill)
        dw.copy_from_caches(caches, idx)

        comp, ctx, decision, profile, t_compress = pw.select_and_compress(
            req, caches, t_prefill, bandwidth=self.estimator.estimate,
            slo_default=self.slo_metric_default)
        wire = comp.total_bytes()
        # The pool write crosses the hot tier's link off the request's
        # critical path (it still contends with fetches there); its cost
        # is booked to pool_write, and the controller observes the
        # request's critical-path latency at _finish instead.
        wr = dw.store.write(
            key, (comp, first, profile.s_dec), wire, kv_bytes=ctx.kv_bytes,
            workload=req.workload, slo_class=req.slo_class,
            ready=now + busy + t_prefill + t_compress, tier=0)
        req.state = "decoding"
        end = busy + t_prefill
        slot = Slot(req=req, idx=idx, toks=[first], pool_hit=False,
                    profile=profile.strategy.short_name(),
                    wire_bytes=int(wire), breakdown=bd,
                    ttft=(now + end) - req.arrival, route=route.name,
                    pool_write=t_compress + wr.t_wait + wr.t_comm,
                    ctx=ctx, decision=decision,
                    spec_k=self._spec_k_for(decision))
        dw.occupy(slot, first, prompt=tokens)
        return end, end

    def _start_request_pd(self, req: Request, route: Route, now: float,
                          busy: float) -> Tuple[float, float]:
        """PD-mode start: run one admitted request through its critical
        path — prefill (on the route's prefill worker, serialized at
        ``busy``) -> controller-selected compress (at the ROUTE's link
        bandwidth estimate) -> serialized transfer on the route's link ->
        decompress -> inject into the route's decode arena.  A decode-side
        pool hit skips the whole cold path (the prefix's bytes crossed
        that worker's ingress wire earlier).  Returns ``(end_offset,
        new_busy)`` relative to ``now``."""
        pw, dw = route.prefill, route.decode
        tokens = self._prompts[req.rid]
        key = req.prefix_key
        idx = req.slot
        bd: Dict[str, float] = {"queue": now - req.arrival}

        hit = dw.store.lookup(key, now=now, full=True)
        if hit is not None:
            # ---- decode-side prefix hit: the compressed prefix already
            # crossed the wire for an earlier request; fetch it from the
            # pool tier (contending for the same wire) instead of
            # re-prefilling.
            entry = hit.entry
            req.state = "transferring"
            tr = dw.store.fetch(hit, ready=now)
            first, t_decompress = dw.fetch_entry(entry, idx)
            end = (hit.tier.fetch_overhead + tr.t_wait + tr.t_comm
                   + t_decompress)
            bd.update(wire_wait=tr.t_wait,
                      comm=hit.tier.fetch_overhead + tr.t_comm,
                      decompress=t_decompress)
            req.state = "decoding"
            slot = Slot(req=req, idx=idx, toks=[first], pool_hit=True,
                        profile=entry.payload[0].strategy.short_name(),
                        wire_bytes=int(entry.wire_bytes), breakdown=bd,
                        ttft=(now + end) - req.arrival, route=route.name,
                        spec_k=self._spec_k_for(None))
            dw.occupy(slot, first, prompt=tokens)
            return end, busy

        # ---- cold request: the full PD critical path.  The prefill
        # worker serializes within the iteration (``busy``); the route's
        # link serializes across ALL of its transfers.
        bd["queue"] += busy
        caches, first, t_prefill = pw.prefill(req, tokens)
        comp, ctx, decision, profile, t_compress = pw.select_and_compress(
            req, caches, t_prefill, bandwidth=route.estimator.estimate,
            slo_default=self.slo_metric_default, route=route.name)
        busy = busy + t_prefill + t_compress
        wire_bytes = comp.total_bytes()
        req.state = "transferring"
        tr = route.link.send(now + busy, wire_bytes)
        # The arena row comes from the restored bytes or (default) from
        # the prefill cache — see RuntimeConfig.pd_inject_restored.  The
        # real decompress only runs when its output or its measured time
        # is actually consumed (virtual-clock default models the cost from
        # profile.s_dec, so running it would be pure benchmark tax).
        if self.cfg.pd_inject_restored or self.cfg.prefill_tok_s is None:
            restored, t_wall = decompress_kvs([comp])
        else:
            restored, t_wall = None, 0.0
        t_decompress = codec_cost(self.cfg, t_wall, ctx.kv_bytes,
                                  profile.s_dec)
        if self.cfg.pd_inject_restored:
            dw.inject_restored(restored[0], idx)
        else:
            dw.copy_from_caches(caches, idx)
        # The bytes that just crossed the wire seed THIS decode worker's
        # pool tier (no extra transfer): later identical prompts routed
        # here hit it.
        dw.store.put(key, (comp, first, profile.s_dec), wire_bytes,
                     kv_bytes=ctx.kv_bytes, workload=req.workload,
                     slo_class=req.slo_class, now=tr.end,
                     tier=len(dw.store.tiers) - 1)
        end = busy + tr.t_wait + tr.t_comm + t_decompress
        bd.update(prefill=t_prefill, compress=t_compress,
                  wire_wait=tr.t_wait, comm=tr.t_comm,
                  decompress=t_decompress)
        req.state = "decoding"
        slot = Slot(req=req, idx=idx, toks=[first], pool_hit=False,
                    profile=profile.strategy.short_name(),
                    wire_bytes=int(wire_bytes), breakdown=bd,
                    ttft=(now + end) - req.arrival, route=route.name,
                    ctx=ctx, decision=decision,
                    spec_k=self._spec_k_for(decision))
        dw.occupy(slot, first, prompt=tokens)
        return end, busy

    # ------------------------------------------------------------------
    def _finish(self, dw: DecodeWorker, slot: Slot, now: float) -> None:
        req = slot.req
        toks = np.asarray(slot.toks, dtype=np.int32)
        req.ttft = slot.ttft
        req.done = now
        req.chosen = slot.profile
        req.breakdown = slot.breakdown
        # One SLO metric end to end: the same latency (ttft or jct,
        # request-pinned or scenario default) is compared to t_slo here
        # AND fed to the bandit, so its violation cooldown fires on the
        # metric the runtime reports — not a different one.
        metric = req.resolved_slo_metric(self.slo_metric_default)
        observed = (slot.ttft if metric == "ttft"
                    else sum(slot.breakdown.values()))
        req.slo_violated = req.t_slo > 0 and observed > req.t_slo
        if self.controller is not None and slot.decision is not None:
            # Residual-bandit feedback: the realized critical-path latency
            # of the SLO metric, landing on the slot's ROUTE bandit (the
            # Slot.ctx carries the route), so each link's drift is learned
            # separately.
            self.controller.observe(slot.ctx, slot.decision, observed)
        if self.controller is not None and slot.drafts_offered > 0:
            # Accept-rate feedback for controller-adaptive speculation:
            # the realized per-draft acceptance on this (workload, route),
            # feeding the EWMA behind Decision.spec_k (DESIGN.md §15).
            observe_accept = getattr(self.controller, "observe_accept",
                                     None)
            if observe_accept is not None:
                observe_accept(req.workload, slot.route,
                               slot.drafts_accepted / slot.drafts_offered)
        self.completed.append(ServedRequest(
            rid=req.rid, workload=req.workload, slo_class=req.slo_class,
            text=self.tok.decode(toks), tokens=toks, profile=slot.profile,
            pool_hit=slot.pool_hit, kv_bytes=int(req.kv_bytes),
            wire_bytes=slot.wire_bytes, arrival=req.arrival, done=now,
            ttft=slot.ttft, slot=slot.idx, route=slot.route,
            breakdown=slot.breakdown, t_pool_write=slot.pool_write,
            slo_metric=metric, t_slo=req.t_slo,
            slo_violated=req.slo_violated, spec_k=slot.spec_k,
            verify_steps=slot.verify_steps,
            spec_committed=slot.spec_committed,
            drafts_offered=slot.drafts_offered,
            drafts_accepted=slot.drafts_accepted))
        self.scheduler.finish(req.rid)
        dw.release(slot)             # returns the local arena slot id
        self._prompts.pop(req.rid, None)

    # ------------------------------------------------------------------
    def _admit_and_start(self, now: float) -> List[Tuple[Slot, float]]:
        """The iteration's admission + routing: pop waiting requests in
        priority order while an eligible route exists (prefill worker
        under its per-iteration cap of ``max_prefills_per_step``, decode
        worker with a free slot) and run each through its start-of-life
        stages on the routed pair.  Returns ``(slot, end_offset)`` pairs;
        the stream's cost is the max end offset."""
        started: List[Tuple[Slot, float]] = []
        cap = self.scheduler.cfg.max_prefills_per_step
        admitted = [0] * self.n_prefill
        self._step_busy = [0.0] * self.n_prefill
        while self.scheduler.queue_depth > 0:
            eligible = [r for r in self.routes
                        if admitted[r.prefill.wid] < cap
                        and r.decode.free_slots]
            if not eligible:
                break
            req = self.scheduler.admit(now)
            route = self.router.choose(req, eligible, now, self)
            admitted[route.prefill.wid] += 1
            req.route = route.name
            req.slot = route.decode.free_slots.pop()
            pwid = route.prefill.wid
            if self.cfg.mode == "pd":
                end, self._step_busy[pwid] = self._start_request_pd(
                    req, route, now, self._step_busy[pwid])
            else:
                end, self._step_busy[pwid] = self._start_request(
                    req, route, now, self._step_busy[pwid])
            started.append((route.decode.slots[req.rid], end))
        return started

    def step(self) -> Dict[str, float]:
        """One iteration of the whole cluster: the admission/routing
        stream starts new requests across the mesh, and every decode
        worker advances its previously-running slots one token (one
        masked batched decode per worker).  The iteration costs ``max``
        over all streams; the difference is charged as stall."""
        now = self.clock
        started = self._admit_and_start(now)
        prefill_cost = max((end for _, end in started), default=0.0)
        new_rids = {s.req.rid for s, _ in started}

        # Decode streams: each worker one masked jitted arena call.
        decode_streams: List[Tuple[float, List[Slot]]] = []
        active_total = 0
        for dw in self.decode_workers:
            active = [s for rid, s in dw.slots.items()
                      if rid not in new_rids]
            if not active:
                continue
            wall = dw.decode_iteration(active)
            cost = (1.0 / self.cfg.decode_tok_s
                    if self.cfg.decode_tok_s else wall)
            decode_streams.append((cost, active))
            active_total += len(active)

        # The iteration costs the slowest stream (PD-separated workers run
        # concurrently); the difference is charged to each slot as
        # "stall" so breakdowns sum exactly to jct.
        iter_cost = max([prefill_cost]
                        + [cost for cost, _ in decode_streams])
        for cost, active in decode_streams:
            for slot in active:
                slot.breakdown["decode"] = \
                    slot.breakdown.get("decode", 0.0) + cost
                slot.breakdown["stall"] = \
                    slot.breakdown.get("stall", 0.0) + iter_cost - cost
        for slot, end_offset in started:
            slot.breakdown["stall"] = \
                slot.breakdown.get("stall", 0.0) + iter_cost - end_offset
        self.clock = now + iter_cost
        self.steps += 1
        for dw in self.decode_workers:
            for slot in list(dw.slots.values()):
                if len(slot.toks) > slot.req.out_tokens:
                    self._finish(dw, slot, self.clock)

        stats = {"step": float(self.steps), "clock": self.clock,
                 "in_flight": float(active_total + len(started)),
                 "queue_depth": float(self.scheduler.queue_depth),
                 "completed": float(len(self.completed)),
                 "store_used": float(sum(t.store.used_bytes
                                         for t in self._distinct_tiers()))}
        self.step_log.append(stats)
        return stats

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> List[ServedRequest]:
        """Step until every admitted request completed, or until
        ``max_steps`` iterations *from this call* — the budget is relative,
        so a second ``run()`` on a long-lived runtime keeps making
        progress instead of returning against the cumulative counter."""
        start = self.steps
        while not self.scheduler.idle and self.steps - start < max_steps:
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    def max_in_flight(self) -> int:
        return int(max((s["in_flight"] for s in self.step_log), default=0))

    def _store_summary(self) -> Dict[str, float]:
        stores = [dw.store for dw in self.decode_workers]
        if len(stores) == 1:
            return stores[0].summary()
        tiers = self._distinct_tiers()
        out: Dict[str, float] = {
            "entries": sum(len(t.store) for t in tiers),
            "used_bytes": sum(t.store.used_bytes for t in tiers),
            "capacity_bytes": sum(t.store.capacity_bytes for t in tiers),
        }
        for k in ("hits", "misses", "partial_misses", "evictions",
                  "rejected_puts", "promotions", "demotions",
                  "slo_protected"):
            out[k] = sum(getattr(s.stats, k, 0) for s in stores)
        n = out["hits"] + out["misses"] + out["partial_misses"]
        out["hit_rate"] = out["hits"] / n if n else 0.0
        return out

    def summary(self) -> Dict[str, float]:
        hits = [r for r in self.completed if r.pool_hit]
        cold = [r for r in self.completed if not r.pool_hit]
        out = {
            "completed": len(self.completed),
            "rejected": self.scheduler.admission.rejected,
            "max_in_flight": self.max_in_flight(),
            "pool_hits": len(hits),
            "pool_hit_rate": len(hits) / max(len(self.completed), 1),
            "wire_transfers": float(self.topology.transfers),
            "wire_bytes_moved": float(self.topology.bytes_moved),
            "n_prefill_workers": float(self.n_prefill),
            "n_decode_workers": float(self.n_decode),
            "router": self.router.name,
        }
        if self.completed:
            out["mean_jct"] = float(np.mean([r.jct for r in self.completed]))
            out["mean_ttft"] = float(np.mean([r.ttft
                                              for r in self.completed]))
            out["throughput_rps"] = (len(self.completed) / self.clock
                                     if self.clock > 0 else 0.0)
        if hits:
            out["mean_ttft_hit"] = float(np.mean([r.ttft for r in hits]))
        if cold:
            out["mean_ttft_cold"] = float(np.mean([r.ttft for r in cold]))
        # Tail latencies + per-SLO-class violation rates (shared metric
        # block — directly comparable with the simulator's summary()).
        out.update(latency_summary(self.completed))
        if self.n_prefill * self.n_decode > 1:
            out.update(route_counts(self.completed))
        out.update({f"store_{k}": v
                    for k, v in self._store_summary().items()})
        return out
