"""Sharding rules: divisibility fallback + cache spec selection.

Uses a subprocess-free trick: rules logic is pure (mesh only supplies axis
sizes), so we fabricate Mesh-like objects."""
from dataclasses import dataclass

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distribution import sharding as sh


@dataclass
class FakeMesh:
    axis_names: tuple
    shape_tuple: tuple

    @property
    def devices(self):
        class _D:
            def __init__(self, s):
                self.shape = s
                self.size = int(np.prod(s))
        return _D(self.shape_tuple)


MESH1 = FakeMesh(("data", "model"), (16, 16))
MESH2 = FakeMesh(("pod", "data", "model"), (2, 16, 16))


def test_divisible_heads_shard():
    spec = sh.resolve_axes(("embed", "heads", "head_dim"), (2560, 32, 128),
                           MESH1)
    assert spec == P(None, "model", None)


def test_indivisible_heads_replicate():
    # minicpm: 36 heads on a 16-way model axis -> fallback to replication
    spec = sh.resolve_axes(("embed", "heads", "head_dim"), (2304, 36, 64),
                           MESH1)
    assert spec == P(None, None, None)


def test_batch_pod_data():
    spec = sh.resolve_axes(("batch", "seq"), (256, 4096), MESH2)
    assert spec == P(("pod", "data"), None)


def test_batch_indivisible():
    spec = sh.resolve_axes(("batch", "seq"), (1, 4096), MESH2)
    assert spec == P(None, None)


def test_vocab_odd_fallback():
    # minicpm vocab 122753 is odd -> replicated
    spec = sh.resolve_axes(("vocab", "embed"), (122753, 2304), MESH1)
    assert spec == P(None, None)
    spec2 = sh.resolve_axes(("vocab", "embed"), (151936, 2560), MESH1)
    assert spec2 == P("model", None)


def test_mesh_axis_never_reused():
    spec = sh.resolve_axes(("heads", "mlp"), (32, 9728), MESH1)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used)) == 1  # model used once


def test_kv_cache_pspec_heads_vs_seq():
    # kv=16 divisible -> heads sharded
    assert sh.kv_cache_pspec(MESH1, (128, 32768, 16, 128)) == \
        P("data", None, "model", None)
    # kv=8 not divisible by 16 -> sequence sharding (flash-decoding style)
    assert sh.kv_cache_pspec(MESH1, (128, 32768, 8, 128)) == \
        P("data", "model", None, None)
    # batch=1 (long_500k): no batch sharding
    assert sh.kv_cache_pspec(MESH1, (1, 524288, 8, 128)) == \
        P(None, "model", None, None)


def test_mamba_state_pspec():
    assert sh.mamba_state_pspec(MESH1, (128, 8192, 16)) == \
        P("data", "model", None)
    assert sh.mamba_state_pspec(MESH1, (1, 8190, 16)) == P(None, None, None)
