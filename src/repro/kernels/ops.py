"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests (the kernel body runs in the Pallas interpreter) and compile to real
Mosaic kernels on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_attention
from repro.kernels.hadamard import hadamard_transform as _hadamard
from repro.kernels.paged_attention import paged_attention as _paged_attention
from repro.kernels.paged_verify_attention import (
    paged_verify_attention as _paged_verify_attention,
)
from repro.kernels.quant_pack import dequant_unpack as _dequant
from repro.kernels.quant_pack import quant_pack as _quant


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_tokens",
                                             "interpret"))
def quant_pack_op(x, bits: int = 8, group: int = 64, block_tokens: int = 256,
                  interpret: Optional[bool] = None):
    """Fused group-quantize + pack.  x (T, D) -> (codes, scales)."""
    itp = _default_interpret() if interpret is None else interpret
    return _quant(x, bits=bits, group=group, block_tokens=block_tokens,
                  interpret=itp)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_tokens",
                                             "out_dtype", "interpret"))
def dequant_unpack_op(codes, scales, bits: int = 8, group: int = 64,
                      block_tokens: int = 256, out_dtype=jnp.bfloat16,
                      interpret: Optional[bool] = None):
    itp = _default_interpret() if interpret is None else interpret
    return _dequant(codes, scales, bits=bits, group=group,
                    block_tokens=block_tokens, out_dtype=out_dtype,
                    interpret=itp)


@functools.partial(jax.jit, static_argnames=("block_tokens", "interpret"))
def hadamard_op(x, block_tokens: int = 256, interpret: Optional[bool] = None):
    itp = _default_interpret() if interpret is None else interpret
    return _hadamard(x, block_tokens=block_tokens, interpret=itp)


@functools.partial(jax.jit, static_argnames=("bits", "group", "kv_len",
                                             "block_s", "interpret"))
def _decode_attention_static(q, k_codes, k_scale, v_codes, v_scale, bits,
                             group, kv_len, block_s, interpret):
    return _decode_attention(q, k_codes, k_scale, v_codes, v_scale, bits=bits,
                             group=group, kv_len=kv_len, block_s=block_s,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_s",
                                             "interpret"))
def _decode_attention_multi_slot(q, k_codes, k_scale, v_codes, v_scale,
                                 kv_lens, bits, group, block_s, interpret):
    return _decode_attention(q, k_codes, k_scale, v_codes, v_scale, bits=bits,
                             group=group, kv_len=kv_lens, block_s=block_s,
                             interpret=interpret)


def decode_attention_op(q, k_codes, k_scale, v_codes, v_scale, bits: int = 8,
                        group: int = 64, kv_len=None,
                        block_s: int = 256, interpret: Optional[bool] = None):
    """Quantized flash-decode attention (see decode_attention.py).

    ``kv_len``: None | int | (B,) int32 — the vector form is the masked
    multi-slot (slot-arena) decode with per-row ragged lengths, traced
    (not static) so slot churn never recompiles."""
    itp = _default_interpret() if interpret is None else interpret
    if kv_len is not None and jnp.ndim(kv_len) == 1:
        return _decode_attention_multi_slot(
            q, k_codes, k_scale, v_codes, v_scale,
            jnp.asarray(kv_len, jnp.int32), bits=bits, group=group,
            block_s=block_s, interpret=itp)
    return _decode_attention_static(q, k_codes, k_scale, v_codes, v_scale,
                                    bits=bits, group=group, kv_len=kv_len,
                                    block_s=block_s, interpret=itp)


@functools.partial(jax.jit, static_argnames=("bits", "group", "interpret"))
def _paged_attention_jit(q, k_codes, k_scale, v_codes, v_scale, block_tables,
                         kv_lens, bits, group, interpret):
    return _paged_attention(q, k_codes, k_scale, v_codes, v_scale,
                            block_tables, kv_lens, bits=bits, group=group,
                            interpret=interpret)


def paged_attention_op(q, k_codes, k_scale, v_codes, v_scale, block_tables,
                       kv_lens, bits: int = 8, group: int = 64,
                       interpret: Optional[bool] = None):
    """Paged quantized decode attention (see paged_attention.py).

    The block table and per-slot lengths are traced (scalar-prefetched
    into SMEM), so page churn across serving steps never recompiles."""
    itp = _default_interpret() if interpret is None else interpret
    return _paged_attention_jit(q, k_codes, k_scale, v_codes, v_scale,
                                jnp.asarray(block_tables, jnp.int32),
                                jnp.asarray(kv_lens, jnp.int32),
                                bits=bits, group=group, interpret=itp)


@functools.partial(jax.jit, static_argnames=("bits", "group", "interpret"))
def _paged_verify_attention_jit(q, k_codes, k_scale, v_codes, v_scale,
                                block_tables, kv_lens, bits, group,
                                interpret):
    return _paged_verify_attention(q, k_codes, k_scale, v_codes, v_scale,
                                   block_tables, kv_lens, bits=bits,
                                   group=group, interpret=interpret)


def paged_verify_attention_op(q, k_codes, k_scale, v_codes, v_scale,
                              block_tables, kv_lens, bits: int = 8,
                              group: int = 64,
                              interpret: Optional[bool] = None):
    """Paged multi-token verify attention (see paged_verify_attention.py).

    ``q`` is (B, Hkv, W, Gq, D): W consecutive verify tokens per slot,
    query ``j`` masked at ``kv_lens[b] + j`` — the speculative-decode
    staircase.  Block table and lengths are traced, so page churn and
    per-step accept lengths never recompile; only W itself is shape-
    static (one compile per speculation width)."""
    itp = _default_interpret() if interpret is None else interpret
    return _paged_verify_attention_jit(q, k_codes, k_scale, v_codes, v_scale,
                                       jnp.asarray(block_tables, jnp.int32),
                                       jnp.asarray(kv_lens, jnp.int32),
                                       bits=bits, group=group, interpret=itp)


# Re-export oracles for test convenience.
quant_pack_ref = ref.quant_pack_ref
dequant_unpack_ref = ref.dequant_unpack_ref
quantize_ref = ref.quantize_ref
dequantize_ref = ref.dequantize_ref
hadamard_ref = ref.hadamard_ref
decode_attention_ref = ref.decode_attention_ref
paged_attention_ref = ref.paged_attention_ref
paged_verify_attention_ref = ref.paged_verify_attention_ref
pack_int4_ref = ref.pack_int4_ref
unpack_int4_ref = ref.unpack_int4_ref
