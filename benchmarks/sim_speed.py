"""Simulator hot-path micro-benchmark: events/sec, new vs pre-PR baseline.

ISSUE 6 tentpole acceptance: replaying a production-shaped trace through
the event-driven :class:`~repro.serving.simulator.Simulator` must be
**>= 5x faster in events/sec** than the pre-optimization hot path, with
IDENTICAL results (same per-request JCT population up to float rounding
— the constant-trace fast path computes ``nbytes/rate`` directly instead
of ``(start + nbytes/rate) - start``).

The baseline is a frozen, faithful reproduction of the pre-PR per-request
costs, kept here so the comparison survives future simulator changes:

* ``LegacyNodePool`` — ndarray speed factors (every downstream duration
  became an ``np.float64``) and the O(n) scan + full ``heapify`` on every
  routed ``acquire_node``;
* ``legacy_transfer_time`` — the segment-scan loop with no constant-trace
  fast path (one ``bisect`` + loop iteration per transfer);
* ``legacy_observe`` — ``np.isfinite`` on a scalar per observation;
* per-request ``ServiceContext`` construction and uncached
  ``StrategyConfig.short_name()`` string building (``BaselineSimulator``
  forces ``needs_ctx`` and overrides the name cache away).

Events/sec counts EVENTS_PER_REQUEST = 5 simulated events per request
(arrival, prefill done, transfer done, decode done, completion); the
speedup ratio is independent of that constant.

CLI: ``--smoke`` (CI size) | ``--full`` (1M-request trace) | ``--json``.
"""
from __future__ import annotations

import argparse
import heapq
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import emit, write_json
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving.network import GBPS, BandwidthTrace
from repro.serving.simulator import (
    NodePool,
    SimConfig,
    Simulator,
    StaticPolicy,
)
from repro.workloads import scaled_trace, trace_requests

EVENTS_PER_REQUEST = 5
MIN_SPEEDUP = 5.0


# ---------------------------------------------------------------------------
# Frozen pre-PR hot path (do not "fix" this — it IS the baseline).
# ---------------------------------------------------------------------------
@dataclass
class LegacyNodePool:
    n: int
    speed: np.ndarray
    free_at: List[Tuple[float, int]] = field(default_factory=list)

    @staticmethod
    def make(n: int, straggler_sigma: float, rng: np.random.Generator
             ) -> "LegacyNodePool":
        speed = np.exp(rng.normal(0.0, straggler_sigma, size=n))
        speed = np.minimum(speed, 1.0)
        pool = LegacyNodePool(n=n, speed=speed)
        pool.free_at = [(0.0, i) for i in range(n)]
        heapq.heapify(pool.free_at)
        return pool

    def acquire(self, now: float) -> Tuple[float, int]:
        free, nid = heapq.heappop(self.free_at)
        return max(free, now), nid

    def acquire_node(self, nid: int, now: float) -> float:
        for k, (free, n) in enumerate(self.free_at):
            if n == nid:
                self.free_at[k] = self.free_at[-1]
                self.free_at.pop()
                heapq.heapify(self.free_at)
                return max(free, now)
        raise KeyError(f"node {nid} is not idle-tracked")

    def free_times(self) -> Dict[int, float]:
        return {nid: free for free, nid in self.free_at}

    def next_free(self):
        return self.free_at[0][0] if self.free_at else None

    def release(self, nid: int, until: float) -> None:
        heapq.heappush(self.free_at, (until, nid))


def legacy_transfer_time(trace: BandwidthTrace, start: float,
                         nbytes: float) -> float:
    from bisect import bisect_right
    if nbytes <= 0:
        return 0.0
    mult = trace._jitter_mult(start, nbytes)
    remaining = nbytes
    t = start
    i = bisect_right(trace.times, t) - 1
    while True:
        rate = trace.values[max(i, 0)] * mult
        seg_end = trace.times[i + 1] if i + 1 < len(trace.times) \
            else float("inf")
        if rate <= 0.0:
            if seg_end == float("inf"):
                return float("inf")
            t = seg_end
            i += 1
            continue
        can = rate * (seg_end - t)
        if can >= remaining or seg_end == float("inf"):
            return (t + remaining / rate) - start
        remaining -= can
        t = seg_end
        i += 1


def legacy_observe(estimator, nbytes: float, seconds: float) -> None:
    if seconds <= 0 or nbytes <= 0 or not np.isfinite(seconds):
        return
    goodput = nbytes / seconds
    estimator._est = goodput if estimator._est is None else \
        (1 - estimator.alpha) * estimator._est + estimator.alpha * goodput


class BaselineSimulator(Simulator):
    """Pre-PR cost model: legacy pools/transfer/observe, no name cache,
    unconditional ServiceContext construction."""

    def __init__(self, config, policy, trace, requests, **kw):
        super().__init__(config, policy, trace, requests, **kw)
        # Undo the hot-path shortcuts the optimized simulator added.
        policy.needs_ctx = True
        self._static_fallback = (isinstance(policy, StaticPolicy)
                                 and policy.slo_fallback_recompute)
        # Rebuild the pools through the legacy implementation with the
        # same rng stream, so straggler draws (and everything after them)
        # match the optimized run bit-for-bit.
        self.rng = np.random.default_rng(config.seed)
        self.prefill = LegacyNodePool.make(config.n_prefill,
                                           config.straggler_sigma, self.rng)
        self.decode = LegacyNodePool.make(config.n_decode,
                                          config.straggler_sigma, self.rng)

    def _profile_name(self, profile):
        return profile.strategy.short_name()   # rebuilt per request

    def _transfer(self, start: float, nbytes: float) -> float:
        dt = legacy_transfer_time(self.trace, start, nbytes)
        legacy_observe(self.estimator, nbytes, dt)
        return dt


# ---------------------------------------------------------------------------
def _policy() -> StaticPolicy:
    profile = Profile(
        strategy=StrategyConfig(quantizer="uniform", key_bits=8,
                                value_bits=8, granularity="per_channel"),
        cr=3.5, s_enc=60.0 * GBPS, s_dec=80.0 * GBPS, quality=0.995)
    return StaticPolicy(profile, "static-u8")


def _events_per_sec(sim_cls, source_trace, trace, repeats: int = 2,
                    seed: int = 0):
    """Best-of-``repeats`` replay rate (each repeat gets fresh Request
    objects — a run mutates them), so a cold first pass or a scheduler
    hiccup cannot fake a regression either way."""
    best_wall, res = float("inf"), None
    for _ in range(repeats):
        # Free the previous repeat's requests BEFORE materializing the
        # next batch: keeping both alive forces every repeat onto
        # first-touch pages (kernel fault time swamps the replay itself).
        # Replays are deterministic, so any repeat's result will do.
        res = None
        requests = trace_requests(source_trace)
        sim = sim_cls(SimConfig(scenario="pd", n_prefill=4, n_decode=2,
                                straggler_sigma=0.1, seed=seed),
                      _policy(), trace, requests)
        t0 = time.perf_counter()
        res = sim.run()
        wall = time.perf_counter() - t0
        best_wall = min(best_wall, wall)
    n = len(source_trace)
    return n * EVENTS_PER_REQUEST / best_wall, res, best_wall


def run(smoke: bool = False, full: bool = False, json_path: str = "") -> None:
    n_new = 1_000_000 if full else (30_000 if smoke else 120_000)
    n_base = min(n_new, 200_000)
    trace = scaled_trace(n_events=n_new, seed=42)
    bw = BandwidthTrace.constant(1.0 * GBPS)

    # Baseline on a prefix-sized trace (at pre-PR speed a full million
    # would dominate the harness); events/sec is per-event, so rates are
    # comparable across sizes.
    base_trace = scaled_trace(n_events=n_base, seed=42)
    eps_base, res_base, wall_base = _events_per_sec(
        BaselineSimulator, base_trace, bw)
    eps_new, res_new, wall_new = _events_per_sec(Simulator, trace, bw)

    # Result equality on the common prefix: same trace + same seed must
    # yield the same per-request latencies up to float rounding (the
    # constant-trace fast path rounds transfer times differently than the
    # legacy segment loop).
    _, check, _ = _events_per_sec(Simulator, base_trace, bw, repeats=1)
    jct_base = res_base.jct()
    jct_new = check.jct()
    assert len(jct_base) == len(jct_new), \
        f"completion count drifted: {len(jct_base)} vs {len(jct_new)}"
    rel = np.max(np.abs(jct_base - jct_new)
                 / np.maximum(np.abs(jct_base), 1e-12))
    assert rel < 1e-9, f"per-request JCT drifted: max rel err {rel:.3e}"

    speedup = eps_new / eps_base
    emit("sim_speed/baseline_events_per_s", 1e6 / eps_base,
         f"eps={eps_base:,.0f} n={n_base} wall={wall_base:.2f}s")
    emit("sim_speed/optimized_events_per_s", 1e6 / eps_new,
         f"eps={eps_new:,.0f} n={n_new} wall={wall_new:.2f}s")
    emit("sim_speed/speedup", 0.0,
         f"{speedup:.1f}x (floor {MIN_SPEEDUP:.0f}x) "
         f"max_rel_jct_err={rel:.1e}")
    assert speedup >= MIN_SPEEDUP, (
        f"simulator hot path regressed: {speedup:.2f}x < "
        f"{MIN_SPEEDUP:.0f}x events/sec over the pre-PR baseline")

    if json_path:
        write_json(json_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="1M-request trace through the optimized path")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, full=args.full, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
