"""kernel-contract: Pallas kernels, oracles, and parity tests in lockstep.

Every public kernel export (`X_op` in ``kernels/__init__.py.__all__``)
must ship with:

* a pure-jnp oracle ``X_ref`` in ``kernels/ref.py`` (the ground truth),
* an ``interpret`` fallback parameter on the ``X_op`` wrapper (so the
  kernel body runs under the Pallas interpreter off-TPU),
* a parity test referencing BOTH names in one test file under
  ``tests/``.

And the inverse drift guard: an ``X_ref`` oracle in ``ref.py`` with no
matching export must at least be a building block referenced by another
oracle — a fully orphaned oracle means the kernel and its ground truth
have drifted apart.

Suppression token: ``kernel-ok``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis.core import Finding, Project, SourceFile, func_defs

RULE_ID = "kernel-contract"
TOKEN = "kernel-ok"


def _find_tests_dir(kernels_dir: Path) -> Optional[Path]:
    for parent in kernels_dir.parents:
        cand = parent / "tests"
        if cand.is_dir():
            return cand
    return None


def _exports(init: SourceFile) -> Dict[str, int]:
    """{export_name: lineno} from __all__ (falls back to import names)."""
    out: Dict[str, int] = {}
    for node in ast.walk(init.tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__" and \
                        isinstance(node.value, (ast.List, ast.Tuple)):
                    for el in node.value.elts:
                        if isinstance(el, ast.Constant) and \
                                isinstance(el.value, str):
                            out[el.value] = node.lineno
    if not out:
        for node in ast.walk(init.tree):
            if isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    out[alias.asname or alias.name] = node.lineno
    return out


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    # A "kernels package" is any scanned dir named `kernels` with both
    # an __init__.py and a ref.py.
    by_dir: Dict[Path, Dict[str, SourceFile]] = {}
    for f in project.files:
        if f.path.parent.name == "kernels":
            by_dir.setdefault(f.path.parent, {})[f.path.name] = f

    for kdir, members in sorted(by_dir.items()):
        init, ref = members.get("__init__.py"), members.get("ref.py")
        if init is None or ref is None:
            continue
        ref_defs: Dict[str, ast.FunctionDef] = {
            fn.name: fn for fn in func_defs(ref.tree)}
        # names referenced inside ref.py outside their own def
        ref_uses: Set[str] = set()
        for fn in ref_defs.values():
            for n in ast.walk(fn):
                if isinstance(n, ast.Call):
                    tgt = n.func
                    name = tgt.attr if isinstance(tgt, ast.Attribute) else (
                        tgt.id if isinstance(tgt, ast.Name) else "")
                    if name != fn.name:
                        ref_uses.add(name)

        # wrapper defs across the package (ops.py et al.)
        wrappers: Dict[str, tuple[SourceFile, ast.FunctionDef]] = {}
        for m in members.values():
            for fn in func_defs(m.tree):
                wrappers.setdefault(fn.name, (m, fn))

        tests_dir = _find_tests_dir(kdir)
        test_texts = {}
        if tests_dir is not None:
            for t in sorted(tests_dir.glob("*.py")):
                try:
                    test_texts[t.name] = t.read_text()
                except OSError:
                    pass

        exports = _exports(init)
        op_bases = {name[:-3] for name in exports if name.endswith("_op")}
        for name, lineno in sorted(exports.items()):
            if not name.endswith("_op"):
                continue
            base = name[:-3]
            # 1) oracle
            if f"{base}_ref" not in ref_defs:
                findings.append(Finding(
                    RULE_ID, init.rel, lineno,
                    f"public kernel `{name}` has no `{base}_ref` oracle "
                    f"in {ref.rel}",
                    f"add a pure-jnp `{base}_ref` (compose existing "
                    f"building-block oracles if the kernel is fused)"))
            # 2) interpret fallback on the wrapper
            w = wrappers.get(name)
            if w is None:
                findings.append(Finding(
                    RULE_ID, init.rel, lineno,
                    f"exported kernel `{name}` has no wrapper def in the "
                    f"kernels package"))
            else:
                wf, wfn = w
                argnames = {a.arg for a in (
                    wfn.args.args + wfn.args.kwonlyargs)}
                if "interpret" not in argnames:
                    findings.append(Finding(
                        RULE_ID, wf.rel, wfn.lineno,
                        f"kernel wrapper `{name}` has no `interpret` "
                        f"fallback parameter",
                        "add `interpret: Optional[bool] = None` routed "
                        "through `_default_interpret()` so CPU tests run "
                        "the Pallas interpreter"))
            # 3) parity test referencing both names
            if tests_dir is not None and not any(
                    name in txt and f"{base}_ref" in txt
                    for txt in test_texts.values()):
                findings.append(Finding(
                    RULE_ID, init.rel, lineno,
                    f"no parity test under {tests_dir.name}/ references "
                    f"both `{name}` and `{base}_ref`",
                    f"add a test asserting {name}(...) matches "
                    f"{base}_ref(...)"))

        # 4) orphaned oracles
        for rname, fn in sorted(ref_defs.items()):
            if not rname.endswith("_ref"):
                continue
            base = rname[:-4]
            if base in op_bases or rname in ref_uses:
                continue
            findings.append(Finding(
                RULE_ID, ref.rel, fn.lineno,
                f"oracle `{rname}` corresponds to no public kernel export "
                f"and no other oracle uses it",
                "export a matching `{}_op`, fold it into the oracle that "
                "needs it, or delete it".format(base)))
    return findings
