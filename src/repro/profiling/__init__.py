from repro.profiling.bo import BOConfig, BOResult, Observation, run_bo, run_random_search
from repro.profiling.gp import GaussianProcess
from repro.profiling.pareto import (
    ParetoPoint,
    dominates,
    frontier_from_profiles,
    pareto_frontier,
    profile_latency,
)

__all__ = [
    "BOConfig", "BOResult", "Observation", "run_bo", "run_random_search",
    "GaussianProcess", "ParetoPoint", "dominates", "frontier_from_profiles",
    "pareto_frontier", "profile_latency",
]
