from repro.serving.kvstore import (
    SLO_CLASSES,
    KVTier,
    PrefixKVStore,
    StoreEntry,
    TierHit,
    TierSpec,
    TieredKVStore,
    default_tier_specs,
    slo_rank,
)
from repro.serving.network import (
    GBPS,
    BandwidthTrace,
    GoodputEstimator,
    KVWire,
    WireTransfer,
)
from repro.serving.metrics import (
    latency_summary,
    percentile_row,
    violation_rates,
)
from repro.serving.request import LIFECYCLE, Request, WorkloadMix, kv_bytes_for
from repro.serving.topology import LinkSpec, NetworkTopology, route_name
from repro.serving.scheduler import (
    AdmissionController,
    ContinuousScheduler,
    SchedulerConfig,
    priority_key,
)
from repro.serving.simulator import (
    KVServePolicy,
    NoCompressionPolicy,
    Policy,
    SimConfig,
    SimResult,
    Simulator,
    StaticPolicy,
)

# NOTE: the real-execution runtimes (ServingRuntime / ClusterRuntime /
# DisaggregatedEngine and the worker classes) live in repro.serving.engine,
# repro.serving.cluster and repro.serving.workers and are imported directly
# by their users — they pull in the jax model stack, which the
# simulator-only path doesn't need.  NetworkTopology is pure network model
# and safe to export here (the simulator drives it too).

__all__ = [
    "GBPS", "BandwidthTrace", "GoodputEstimator", "KVWire", "WireTransfer",
    "LIFECYCLE", "Request", "WorkloadMix",
    "kv_bytes_for", "KVServePolicy", "NoCompressionPolicy", "Policy",
    "SimConfig", "SimResult", "Simulator", "StaticPolicy",
    "PrefixKVStore", "StoreEntry", "SLO_CLASSES", "slo_rank",
    "KVTier", "TierHit", "TierSpec", "TieredKVStore", "default_tier_specs",
    "ContinuousScheduler", "SchedulerConfig", "AdmissionController",
    "priority_key",
    "LinkSpec", "NetworkTopology", "route_name",
    "latency_summary", "percentile_row", "violation_rates",
]
