"""Tiered KV hierarchy driven end-to-end: the real-execution runtime and
the event-driven simulator share one placement/eviction code path, and all
pool traffic contends on the per-tier serialized links (ISSUE 4)."""
import numpy as np
import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import (
    BandwidthTrace,
    GBPS,
    SchedulerConfig,
    TierSpec,
    TieredKVStore,
)


def _profile(cr=2.0, bits=8, codec=None):
    kw = {"codec": codec} if codec else {}
    return Profile(StrategyConfig(quantizer="uniform", key_bits=bits,
                                  value_bits=bits, granularity="per_channel",
                                  **kw),
                   cr=cr, s_enc=5e8, s_dec=5e8)


def _pool_runtime(reference_model, *, tiers=None, max_prefills=2, **kw):
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    # decode_tok_s=20: the decode stream advances the virtual clock well
    # past each off-path pool write's completion, so repeat prompts find
    # the entry visible even over the slowest links used here.
    defaults = dict(
        static_profile=_profile(),
        config=RuntimeConfig(seq=48, decode_tokens=4, prefill_tok_s=150.0,
                             decode_tok_s=20.0, tiers=tiers),
        trace=BandwidthTrace.constant(0.05 * GBPS),   # 50 Mbps remote
        scheduler=SchedulerConfig(max_slots=6,
                                  max_prefills_per_step=max_prefills,
                                  max_queue=64))
    defaults.update(kw)
    rt = ServingRuntime(**defaults)
    rt.model_cfg, rt.params = reference_model
    return rt


def _remote_only(bandwidth, capacity=64 << 20, overhead=0.002, profile=None):
    return [TierSpec("remote", capacity, bandwidth=bandwidth,
                     fetch_overhead=overhead, profile=profile,
                     observe_goodput=True)]


@pytest.mark.slow
def test_concurrent_pool_fetches_contend_on_wire(reference_model):
    """Bugfix (ISSUE 4): pool-mode fetches used to bill straight from the
    trace, so simultaneous fetches never queued.  Two hits admitted in the
    same iteration now contend on the tier's serialized link: the second
    books nonzero wire_wait."""
    rt = _pool_runtime(
        reference_model,
        tiers=_remote_only(0.002 * GBPS))   # slow pool link
    # warm two distinct prefixes
    rt.submit("qalike", prompt_seed=0)
    rt.run()
    rt.submit("codelike", prompt_seed=1)
    rt.run()
    n_cold = len(rt.completed)
    # both hit prompts admitted in ONE iteration (max_prefills=2)
    rt.submit("qalike", prompt_seed=0)
    rt.submit("codelike", prompt_seed=1)
    rt.step()
    rt.run()
    hits = [r for r in rt.completed[n_cold:]]
    assert len(hits) == 2 and all(r.pool_hit for r in hits)
    waits = sorted(r.breakdown.get("wire_wait", 0.0) for r in hits)
    assert waits[0] == 0.0 and waits[1] > 0.0
    # the queued fetch waited out the first transfer's on-wire time
    first = min(hits, key=lambda r: r.breakdown.get("wire_wait", 0.0))
    assert waits[1] == pytest.approx(first.breakdown["comm"] - 0.002,
                                     rel=1e-6)
    for r in rt.completed:
        assert sum(r.breakdown.values()) == pytest.approx(r.jct, abs=1e-9)


@pytest.mark.slow
def test_hot_tier_hit_beats_remote_refetch(reference_model):
    """The tentpole crossover: with an ample hot tier a repeat prompt is
    served from HBM; with the hot tiers disabled it degrades gracefully to
    the remote path (still a pool hit, no crash) at a much larger TTFT —
    which itself still beats cold recomputation."""
    def hit_ttft(tiers):
        rt = _pool_runtime(reference_model, tiers=tiers)
        rt.submit("qalike", prompt_seed=7)
        rt.run()
        rt.submit("qalike", prompt_seed=7)
        rt.run()
        cold, hit = rt.completed
        assert not cold.pool_hit and hit.pool_hit
        return hit.ttft, cold.ttft, rt

    ttft_hot, cold_hot, rt_hot = hit_ttft(None)     # default HBM/DRAM/remote
    ttft_rem, cold_rem, rt_rem = hit_ttft(
        [TierSpec("hbm", 0, bandwidth=64e9),
         TierSpec("dram", 0, bandwidth=8e9, fetch_overhead=5e-4),
         TierSpec("remote", 64 << 20, bandwidth=0.05 * GBPS,
                  fetch_overhead=0.002, observe_goodput=True)])
    assert rt_hot.store.stats.tier_hits.get("hbm") == 1
    assert rt_rem.store.stats.tier_hits.get("remote") == 1
    assert ttft_hot < ttft_rem          # hot-tier hit beats remote refetch
    assert ttft_rem < cold_rem          # remote hit still beats recompute


@pytest.mark.slow
def test_controller_refetches_smaller_over_slow_link(reference_model):
    """Tier-aware fetch routing in the engine: on a slow pool link the
    controller's select_fetch trades the stored encoding for a smaller
    re-encode (the pool tier's demotion profile), and the hit really
    fetches fewer bytes."""
    from repro.controller import ServiceAwareController
    from repro.data.synthetic import WORKLOADS

    q8 = _profile(cr=2.0, bits=8)
    q4z = _profile(cr=6.0, bits=4, codec="zstd3")
    controller = ServiceAwareController({w: [q8] for w in WORKLOADS})
    rt = _pool_runtime(
        reference_model, static_profile=None, controller=controller,
        tiers=_remote_only(0.002 * GBPS, profile=q4z))
    rt.submit("qalike", prompt_seed=3, q_min=0.5)
    rt.run()
    rt.submit("qalike", prompt_seed=3, q_min=0.5)
    rt.run()
    cold, hit = rt.completed
    assert hit.pool_hit
    assert hit.wire_bytes < cold.wire_bytes        # re-encoded smaller
    assert hit.profile == q4z.strategy.short_name()
    # the store now holds the smaller encoding, capacity-accounted
    assert rt.store.used_bytes == hit.wire_bytes
    # the source-side re-encode is billed ON the critical path (the enc
    # term the fetch decision traded against), and accounting still sums
    assert hit.breakdown.get("compress", 0.0) > 0.0
    assert sum(hit.breakdown.values()) == pytest.approx(hit.jct, abs=1e-9)


@pytest.mark.slow
def test_pd_mode_uses_single_pool_tier_sharing_the_wire(reference_model):
    """PD default hierarchy: one remote tier whose link IS the PD transfer
    wire, so pool fetches and cold transfers contend on the same queue."""
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    rt = ServingRuntime(
        static_profile=_profile(),
        config=RuntimeConfig(seq=48, decode_tokens=4, prefill_tok_s=2000.0,
                             decode_tok_s=500.0, mode="pd"),
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=4, max_prefills_per_step=2,
                                  max_queue=32))
    rt.model_cfg, rt.params = reference_model
    assert len(rt.store.tiers) == 1
    assert rt.store.tiers[0].wire is rt.wire
    rt.submit("qalike", prompt_seed=5)
    rt.run()
    rt.submit("qalike", prompt_seed=5)
    rt.run()
    cold, hit = rt.completed
    assert not cold.pool_hit and hit.pool_hit
    assert rt.wire.transfers == 2       # cold transfer + pool fetch


def test_simulator_shares_tiered_store_code_path():
    """The event-driven simulator drives the SAME TieredKVStore: writes
    land hot, capacity pressure demotes with byte-accounting
    re-compression, hits fetch through tier links, and a disabled hot
    tier degrades to remote-path TTFT instead of crashing."""
    from repro.serving import Request, SimConfig, Simulator, StaticPolicy

    prof = Profile(StrategyConfig(key_bits=8, value_bits=8), cr=2.0,
                   s_enc=1e9, s_dec=1e9)

    def run_sim(tiers):
        store = TieredKVStore(tiers, block=8)
        reqs = []
        # 3 writers then 3 re-users of the same prefixes, well spaced so
        # writes are visible
        for i in range(3):
            reqs.append(Request(rid=i, workload="qalike", arrival=10.0 * i,
                                ctx_tokens=1000, out_tokens=4,
                                kv_bytes=1e6, q_min=0.0,
                                prefix_key=(i,)))
        for i in range(3):
            reqs.append(Request(rid=3 + i, workload="qalike",
                                arrival=60.0 + 10.0 * i, ctx_tokens=1000,
                                out_tokens=4, kv_bytes=1e6, q_min=0.0,
                                prefix_key=(i,)))
        res = Simulator(SimConfig(scenario="pool", prefill_tok_s=500.0),
                        StaticPolicy(prof, "s"),
                        BandwidthTrace.constant(1e6), reqs,
                        store=store).run()
        hits = [r for r in res.requests
                if r.breakdown.get("comm", 0) > 0
                and r.breakdown.get("prefill", 0) == 0]
        colds = [r for r in res.requests if r.breakdown.get("prefill", 0) > 0]
        return store, hits, colds

    hot = [TierSpec("hbm", 4 << 20, bandwidth=64e9),
           TierSpec("remote", 64 << 20, bandwidth=1e6, fetch_overhead=2e-3,
                    observe_goodput=True)]
    store_h, hits_h, colds_h = run_sim(hot)
    assert len(hits_h) == 3 and len(colds_h) == 3
    assert store_h.stats.tier_hits.get("hbm") == 3

    cold_tiers = [TierSpec("hbm", 0, bandwidth=64e9),
                  TierSpec("remote", 64 << 20, bandwidth=1e6,
                           fetch_overhead=2e-3, observe_goodput=True)]
    store_r, hits_r, colds_r = run_sim(cold_tiers)
    assert len(hits_r) == 3                      # graceful: still pool hits
    assert store_r.stats.tier_hits.get("remote") == 3
    # hot-tier hits are (much) faster than remote-path hits
    assert np.mean([r.ttft for r in hits_h]) \
        < np.mean([r.ttft for r in hits_r])
    # ... and remote hits still beat cold recompute
    assert np.mean([r.ttft for r in hits_r]) \
        < np.mean([r.ttft for r in colds_r])


def test_simulator_tiered_fetches_contend():
    """Two pool hits arriving together on a slow tier link: the second
    books wire_wait (pre-fix, simulator fetches never queued)."""
    from repro.serving import Request, SimConfig, Simulator, StaticPolicy

    prof = Profile(StrategyConfig(key_bits=8, value_bits=8), cr=2.0,
                   s_enc=1e9, s_dec=1e9)
    store = TieredKVStore(
        [TierSpec("remote", 64 << 20, bandwidth=1e5, fetch_overhead=1e-3,
                  observe_goodput=True)], block=8)
    store.put((0,), prof, 100_000, kv_bytes=2e5, now=0.0)
    store.put((1,), prof, 100_000, kv_bytes=2e5, now=0.0)
    reqs = [Request(rid=i, workload="qalike", arrival=10.0, ctx_tokens=100,
                    out_tokens=2, kv_bytes=2e5, q_min=0.0, prefix_key=(i,))
            for i in range(2)]
    res = Simulator(SimConfig(scenario="pool", prefill_tok_s=1e4),
                    StaticPolicy(prof, "s"), BandwidthTrace.constant(1e5),
                    reqs, store=store).run()
    waits = sorted(r.breakdown.get("wire_wait", 0.0) for r in res.requests)
    assert waits[0] == 0.0
    assert waits[1] == pytest.approx(1.0)   # 100 KB over 100 KB/s ahead


@pytest.mark.slow
def test_pd_explicit_tiers_still_share_the_transfer_wire(reference_model):
    """Review regression (ISSUE 5): an EXPLICIT RuntimeConfig.tiers list
    in PD mode must keep the old engine's rule — the pool tier's link IS
    the PD transfer wire (fetches contend with cold transfers) — not a
    fresh private wire."""
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    rt = ServingRuntime(
        static_profile=_profile(),
        config=RuntimeConfig(seq=48, decode_tokens=4, prefill_tok_s=2000.0,
                             decode_tok_s=500.0, mode="pd",
                             tiers=_remote_only(0.05 * GBPS)),
        trace=BandwidthTrace.constant(0.05 * GBPS),
        scheduler=SchedulerConfig(max_slots=4, max_prefills_per_step=2,
                                  max_queue=32))
    rt.model_cfg, rt.params = reference_model
    assert rt.store.tiers[-1].wire is rt.wire
    rt.submit("qalike", prompt_seed=5)
    rt.run()
    rt.submit("qalike", prompt_seed=5)
    rt.run()
    cold, hit = rt.completed
    assert not cold.pool_hit and hit.pool_hit
    assert rt.wire.transfers == 2       # cold transfer + pool fetch
