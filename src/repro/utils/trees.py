"""Pytree helpers."""
from __future__ import annotations

import jax
import numpy as np


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (respects dtype itemsize)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        itemsize = np.dtype(x.dtype).itemsize
        total += int(np.prod(x.shape)) * itemsize
    return total
