"""Trace schema for production-shaped serving workloads.

A :class:`Trace` is an ordered sequence of :class:`TraceEvent` rows — one
per request — carrying everything either serving backend needs to replay
it: arrival time, tenant, scenario archetype, the ``data.synthetic``
workload family, prompt/output lengths, the prefix-sharing group, and the
SLO contract (class + metric + deadline + quality floor).

Determinism contract (DESIGN.md §11): a trace is a pure function of its
build inputs — same seed ⇒ byte-identical ``to_jsonl()`` serialization.
Every numeric field is a plain Python ``int``/``float`` (never a numpy
scalar), so serialization is canonical and the replay hot path stays on
fast native floats.

Both backends replay the SAME trace:

* the event-driven :class:`~repro.serving.simulator.Simulator` consumes
  :meth:`Trace.to_requests` (see :mod:`repro.workloads.replay`);
* the real-execution :class:`~repro.serving.cluster.ClusterRuntime` /
  :class:`~repro.serving.engine.ServingRuntime` replays through
  :func:`repro.workloads.replay.replay_runtime`, which maps
  ``prefix_group`` onto ``prompt_seed`` so shared-prefix groups share
  real prompts (and therefore real pool entries).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, fields
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.serving.request import Request

# The SLO metrics the serving stack can report violations on — every
# event's slo_metric must be one of these (property-tested).
SLO_METRICS = ("ttft", "jct")


@dataclass(frozen=True)
class TraceEvent:
    """One request of a workload trace."""

    rid: int                 # unique within the trace, == position
    t: float                 # arrival time (s from trace start)
    tenant: str              # originating tenant (superposition source)
    scenario: str            # archetype name (repro.workloads.scenarios)
    workload: str            # data.synthetic family (router label w)
    ctx_tokens: int          # prompt length
    out_tokens: int          # decode budget (1 = prefill-only classify)
    prefix_group: int        # sharing group: equal ids reuse one prefix
    slo_class: str = "standard"   # scheduler class (kvstore.SLO_CLASSES)
    slo_metric: str = "ttft"      # which latency the SLO targets
    t_slo: float = 0.0            # deadline (s); 0 = no SLO
    q_min: float = 0.97           # quality floor for profile selection


@dataclass
class Trace:
    """An arrival-ordered request trace plus its provenance."""

    events: List[TraceEvent]
    seed: Optional[int] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @property
    def duration(self) -> float:
        return self.events[-1].t if self.events else 0.0

    def tenants(self) -> List[str]:
        return sorted({e.tenant for e in self.events})

    def counts_by_tenant(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.tenant] = out.get(e.tenant, 0) + 1
        return out

    def counts_by_scenario(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.scenario] = out.get(e.scenario, 0) + 1
        return out

    # ------------------------------------------------------------------
    # Canonical serialization (the byte-identity surface of the
    # determinism contract) — one compact JSON object per line.
    # ------------------------------------------------------------------
    def to_jsonl(self) -> str:
        head = json.dumps({"seed": self.seed, "meta": self.meta},
                          sort_keys=True, separators=(",", ":"))
        rows = [json.dumps(asdict(e), sort_keys=True,
                           separators=(",", ":")) for e in self.events]
        return "\n".join([head] + rows)

    def digest(self) -> str:
        """SHA-1 of the canonical serialization — two traces with equal
        digests are byte-identical."""
        return hashlib.sha1(self.to_jsonl().encode()).hexdigest()

    @staticmethod
    def from_jsonl(text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln]
        head = json.loads(lines[0])
        names = {f.name for f in fields(TraceEvent)}
        events = []
        for ln in lines[1:]:
            d = json.loads(ln)
            events.append(TraceEvent(**{k: v for k, v in d.items()
                                        if k in names}))
        return Trace(events, seed=head.get("seed"),
                     meta=head.get("meta") or {})

    # ------------------------------------------------------------------
    # Superposition: merge per-tenant (or per-scenario) traces into one
    # arrival-ordered trace.  The merge is stable — ties broken by
    # (tenant, original rid) — and conserves every source's event count
    # (property-tested in tests/test_workloads.py).
    # ------------------------------------------------------------------
    @staticmethod
    def merge(traces: Sequence["Trace"], seed: Optional[int] = None
              ) -> "Trace":
        rows = [e for tr in traces for e in tr.events]
        rows.sort(key=lambda e: (e.t, e.tenant, e.rid))
        events = [TraceEvent(rid=i, t=e.t, tenant=e.tenant,
                             scenario=e.scenario, workload=e.workload,
                             ctx_tokens=e.ctx_tokens,
                             out_tokens=e.out_tokens,
                             prefix_group=e.prefix_group,
                             slo_class=e.slo_class,
                             slo_metric=e.slo_metric, t_slo=e.t_slo,
                             q_min=e.q_min)
                  for i, e in enumerate(rows)]
        meta = {"merged": [tr.meta for tr in traces]}
        return Trace(events, seed=seed, meta=meta)

    # ------------------------------------------------------------------
    # Simulator adapter
    # ------------------------------------------------------------------
    def to_requests(self, num_layers: int = 32, kv_heads: int = 8,
                    head_dim: int = 128, bytes_per_el: int = 2
                    ) -> List[Request]:
        """Materialize :class:`~repro.serving.request.Request` objects for
        the event-driven simulator.  ``prefix_group`` becomes the opaque
        ``prefix_key`` (store-resolved pool hits); ``prefix_hit`` is set
        for repeats of an already-seen group so storeless simulations see
        the same hit population."""
        import gc
        seen: set = set()
        out: List[Request] = []
        per_tok = 2.0 * num_layers * kv_heads * head_dim * bytes_per_el
        # Materializing a million acyclic Request objects under
        # generational GC rescans the growing heap for nothing; defer
        # collection for the duration (same rationale as Simulator.run).
        was_enabled = gc.isenabled()
        if was_enabled:
            gc.disable()
        try:
            for e in self.events:
                group = e.prefix_group
                hit = group in seen
                seen.add(group)
                out.append(Request(
                    rid=e.rid, workload=e.workload, arrival=e.t,
                    ctx_tokens=e.ctx_tokens, out_tokens=e.out_tokens,
                    kv_bytes=per_tok * e.ctx_tokens,
                    t_slo=e.t_slo, slo_metric=e.slo_metric, q_min=e.q_min,
                    prefix_hit=hit, slo_class=e.slo_class,
                    prefix_key=(group,)))
        finally:
            if was_enabled:
                gc.enable()
        return out


def validate(trace: Trace) -> None:
    """Structural invariants every generated trace must satisfy (the same
    ones the property tests check): arrivals non-decreasing, rids dense,
    every SLO class/metric registered, lengths positive."""
    from repro.serving.kvstore import SLO_CLASSES
    last = 0.0
    for i, e in enumerate(trace.events):
        if e.rid != i:
            raise ValueError(f"rid {e.rid} at position {i} (must be dense)")
        if e.t < last:
            raise ValueError(f"arrivals decrease at rid {e.rid}")
        last = e.t
        if e.slo_class not in SLO_CLASSES:
            raise ValueError(f"unregistered slo_class {e.slo_class!r}")
        if e.slo_metric not in SLO_METRICS:
            raise ValueError(f"unregistered slo_metric {e.slo_metric!r}")
        if e.ctx_tokens <= 0 or e.out_tokens <= 0:
            raise ValueError(f"non-positive lengths on rid {e.rid}")


def iter_chunks(events: Iterable[TraceEvent], size: int):
    """Yield fixed-size chunks of an event stream (windowed replay)."""
    chunk: List[TraceEvent] = []
    for e in events:
        chunk.append(e)
        if len(chunk) >= size:
            yield chunk
            chunk = []
    if chunk:
        yield chunk
