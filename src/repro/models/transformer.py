"""Unified model stack: dense / MoE / SSM / hybrid decoders and the whisper
encoder-decoder, built as (optional prefix layers) + scan-over-layer-blocks.

Scan-over-layers keeps the HLO size O(period) instead of O(num_layers) —
essential for compiling 80-layer configs in the multi-pod dry-run.  Hybrid
archs (jamba: 1 attn per 8 layers, MoE every 2) scan over their repeating
period; irregular prefixes (deepseek's dense first layer) sit outside the
scan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.axes import Initializer, Pm, abstract_like_block, is_pm, split_tree, stack_block_params

COMPUTE_DTYPE = L.COMPUTE_DTYPE


# ---------------------------------------------------------------------------
# Structure resolution
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StackPlan:
    prefix_specs: Tuple[LayerSpec, ...]
    period_specs: Tuple[LayerSpec, ...]
    n_blocks: int


def plan_stack(cfg: ModelConfig) -> StackPlan:
    specs = cfg.layer_specs()
    # Pull an irregular prefix (e.g. deepseek dense first layer[s]) out front.
    for prefix_len in range(0, min(len(specs), 4)):
        rest = specs[prefix_len:]
        for period in (1, 2, 4, 8, 16):
            if len(rest) == 0 or len(rest) % period:
                continue
            blocks = [tuple(rest[i : i + period]) for i in range(0, len(rest), period)]
            if all(b == blocks[0] for b in blocks):
                return StackPlan(tuple(specs[:prefix_len]), blocks[0],
                                 len(rest) // period)
    # Fully irregular: everything is prefix (no scan).
    return StackPlan(tuple(specs), (), 0)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------
def _init_layer(ini: Initializer, cfg: ModelConfig, spec: LayerSpec,
                cross_attn: bool = False) -> Dict[str, Any]:
    p: Dict[str, Any] = {"ln1": L.init_rmsnorm(ini, cfg.d_model)}
    if spec.kind == "attn":
        p["mixer"] = L.init_attention(ini, cfg)
    else:
        p["mixer"] = S.init_mamba(ini, cfg)
    if cross_attn:
        p["lnx"] = L.init_rmsnorm(ini, cfg.d_model)
        p["xattn"] = L.init_attention(ini, cfg)
    if cfg.d_ff > 0:
        p["ln2"] = L.init_rmsnorm(ini, cfg.d_model)
        p["mlp"] = L.init_moe(ini, cfg) if spec.moe else L.init_mlp(
            ini, cfg.d_model, cfg.d_ff)
    return p


def _apply_layer(
    lp, cfg: ModelConfig, spec: LayerSpec, x, *,
    positions, mode: str, cache=None, cache_pos=None, max_len: int = 0,
    xattn_kv=None, cross_attn: bool = False,
):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(lp["ln1"], x, cfg.rmsnorm_eps)

    if spec.kind == "attn":
        if mode == "decode":
            y, new_cache = L.apply_attention(
                lp["mixer"], cfg, h, positions=positions, local=spec.local,
                cache=cache, cache_pos=cache_pos)
        else:
            y, kv = L.apply_attention(
                lp["mixer"], cfg, h, positions=positions, local=spec.local,
                causal=not (cross_attn is False and cfg.encoder_decoder and mode == "encode"))
            new_cache = None
            if mode == "prefill":
                new_cache = _pad_kv(kv, max_len)
    else:
        state = cache if mode == "decode" else None
        y, new_state = S.apply_mamba(lp["mixer"], cfg, h, state=state)
        new_cache = new_state if mode in ("decode", "prefill") else None
    x = x + y

    if cross_attn:
        hx = L.rmsnorm(lp["lnx"], x, cfg.rmsnorm_eps)
        yx, _ = L.apply_attention(
            lp["xattn"], cfg, hx, positions=positions, xattn_kv=xattn_kv)
        x = x + yx

    if cfg.d_ff > 0:
        h2 = L.rmsnorm(lp["ln2"], x, cfg.rmsnorm_eps)
        if spec.moe:
            y2, aux = L.apply_moe(lp["mlp"], cfg, h2)
        else:
            y2 = L.apply_mlp(lp["mlp"], h2)
        x = x + y2
    return x, new_cache, aux


def _pad_kv(kv, max_len: int):
    """Pad prefill k/v (B, S, H, D) along seq to max_len cache slots."""
    if max_len <= 0:
        return kv
    out = {}
    for key in ("k", "v"):
        arr = kv[key]
        s = arr.shape[1]
        if s < max_len:
            pad = jnp.zeros((arr.shape[0], max_len - s) + arr.shape[2:], arr.dtype)
            arr = jnp.concatenate([arr, pad], axis=1)
        out[key] = arr.astype(COMPUTE_DTYPE)
    return out


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, seed: int = 0, abstract: bool = False,
                dtype=None):
    """Returns (values_tree, axes_tree).  dtype=bf16 for serving-only params
    (halves weight HBM traffic; training keeps fp32 masters)."""
    import jax.numpy as _jnp
    ini = Initializer(seed=seed, abstract=abstract,
                      dtype=dtype or _jnp.float32)
    plan = plan_stack(cfg)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(ini, cfg),
        "final_norm": L.init_rmsnorm(ini, cfg.d_model),
    }
    cross = cfg.encoder_decoder
    params["prefix"] = {
        f"layer{i}": _init_layer(ini, cfg, spec, cross_attn=cross)
        for i, spec in enumerate(plan.prefix_specs)
    }
    if plan.n_blocks > 0:
        block = {
            f"layer{j}": _init_layer(ini, cfg, spec, cross_attn=cross)
            for j, spec in enumerate(plan.period_specs)
        }
        if abstract:
            params["blocks"] = abstract_like_block(block, plan.n_blocks)
        else:
            blocks = []
            for b in range(plan.n_blocks):
                ini_b = Initializer(seed=seed * 1000 + b + 1, abstract=False)
                blocks.append({
                    f"layer{j}": _init_layer(ini_b, cfg, spec, cross_attn=cross)
                    for j, spec in enumerate(plan.period_specs)
                })
            params["blocks"] = stack_block_params(blocks)
    if cfg.encoder_decoder:
        params["encoder"] = _init_encoder(ini, cfg)
    return split_tree(params)


def _init_encoder(ini: Initializer, cfg: ModelConfig) -> Dict[str, Any]:
    spec = LayerSpec(kind="attn", moe=False, local=False)
    block = {"layer0": _init_layer(ini, cfg, spec)}
    return {
        "blocks": (abstract_like_block(block, cfg.enc_layers)
                   if ini.abstract else stack_block_params(
                       [{"layer0": _init_layer(
                           Initializer(seed=7000 + b, abstract=False), cfg, spec)}
                        for b in range(cfg.enc_layers)])),
        "final_norm": L.init_rmsnorm(ini, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------
def _run_stack(params, cfg: ModelConfig, plan: StackPlan, x, *, positions,
               mode: str, caches=None, cache_pos=None, max_len: int = 0,
               xattn_kv=None, remat: bool = False):
    """Run prefix + scanned blocks. Returns (x, new_caches, aux_total).

    caches/new_caches structure:
      {"prefix": {"layer{i}": cache_i}, "blocks": {"layer{j}": stacked}}
    """
    cross = cfg.encoder_decoder and xattn_kv is not None
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = {}
    for i, spec in enumerate(plan.prefix_specs):
        name = f"layer{i}"
        c_in = caches["prefix"][name] if caches is not None else None
        xkv_i = None
        if cross:
            xkv_i = (xattn_kv["prefix"][name]["xk"], xattn_kv["prefix"][name]["xv"])
        x, c_out, aux = _apply_layer(
            params["prefix"][name], cfg, spec, x, positions=positions,
            mode=mode, cache=c_in, cache_pos=cache_pos, max_len=max_len,
            xattn_kv=xkv_i, cross_attn=cross)
        aux_total = aux_total + aux
        if c_out is not None:
            new_prefix[name] = c_out

    new_blocks = None
    if plan.n_blocks > 0:
        def body(carry, xs):
            xc, auxc = carry
            if mode == "decode":
                bp, bc, bxkv = xs
            elif cross:
                bp, bxkv = xs
                bc = None
            else:
                bp = xs
                bc, bxkv = None, None
            block_caches = {}
            for j, spec in enumerate(plan.period_specs):
                name = f"layer{j}"
                c_in = bc[name] if bc is not None else None
                xkv_j = (bxkv[name]["xk"], bxkv[name]["xv"]) if cross else None
                xc, c_out, aux = _apply_layer(
                    bp[name], cfg, spec, xc, positions=positions, mode=mode,
                    cache=c_in, cache_pos=cache_pos, max_len=max_len,
                    xattn_kv=xkv_j, cross_attn=cross)
                auxc = auxc + aux
                if c_out is not None:
                    block_caches[name] = c_out
            ys = block_caches if block_caches else None
            return (xc, auxc), ys

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)

        if mode == "decode":
            xs = (params["blocks"], caches["blocks"],
                  xattn_kv["blocks"] if cross else _none_like(params["blocks"]))
        elif cross:
            xs = (params["blocks"], xattn_kv["blocks"])
        else:
            xs = params["blocks"]
        (x, aux_total), new_blocks = jax.lax.scan(body, (x, aux_total), xs)

    new_caches = None
    if mode in ("prefill", "decode"):
        new_caches = {"prefix": new_prefix, "blocks": new_blocks or {}}
        if mode == "decode":
            new_caches = _merge_decode_updates(new_caches, caches, cache_pos)
    return x, new_caches, aux_total


def _merge_decode_updates(new_caches, caches, cache_pos):
    """Write the per-layer (k_new, v_new) token slices into the full cache
    buffers with ONE dynamic-update-slice per (stacked) buffer.

    A vector ``cache_pos`` (B,) writes each batch row at its own position
    (slot-arena decode): one dynamic-update-slice per row via vmap, which
    XLA lowers to a scatter over the batch axis."""
    per_slot = jnp.ndim(cache_pos) == 1

    def _row_write(b_old, upd, p):
        # b_old (Smax, H, D); upd (S, H, D) — S consecutive rows from p
        return jax.lax.dynamic_update_slice(b_old, upd, (p,) + (0,) * (b_old.ndim - 1))

    def _merge(sub, old, stacked: bool):
        out = {}
        for name, c in sub.items():
            if isinstance(c, dict) and "k_new" in c:
                buf = {}
                for key, nk in (("k", "k_new"), ("v", "v_new")):
                    b_old = old[name][key]
                    upd = c[nk].astype(b_old.dtype)
                    if per_slot:
                        row = jax.vmap(_row_write)
                        if stacked:  # (N, B, S, H, D)
                            buf[key] = jax.vmap(
                                lambda bo, up: row(bo, up, cache_pos))(b_old, upd)
                        else:        # (B, S, H, D)
                            buf[key] = row(b_old, upd, cache_pos)
                    else:
                        idx = ((0, 0, cache_pos, 0, 0) if stacked
                               else (0, cache_pos, 0, 0))
                        buf[key] = jax.lax.dynamic_update_slice(b_old, upd, idx)
                out[name] = buf
            else:
                out[name] = c  # mamba state: carried whole (it is small)
        return out

    return {
        "prefix": _merge(new_caches["prefix"], caches["prefix"], False),
        "blocks": _merge(new_caches["blocks"], caches["blocks"], True),
    }


def _none_like(tree):
    # scan xs placeholder aligned with blocks' leading dim
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    return jnp.zeros((n, 1), jnp.int8)


# ---------------------------------------------------------------------------
# Embedding helpers / positions
# ---------------------------------------------------------------------------
def _positions_for(cfg: ModelConfig, batch: Dict[str, Any], seq: int, bsz: int):
    if cfg.mrope:
        if "positions" in batch:
            return batch["positions"]
        p = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, None],
                             (3, bsz, seq))
        return p
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (bsz, seq))


def _embed_inputs(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    x = L.embed_tokens(params["embed"], batch["tokens"]).astype(COMPUTE_DTYPE)
    if cfg.vision_prefix_frac > 0 and "patch_embeds" in batch:
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(COMPUTE_DTYPE), x], axis=1)
    return x


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch, remat: bool = False):
    """Full-sequence forward (training). Returns (logits, aux)."""
    plan = plan_stack(cfg)
    if cfg.encoder_decoder:
        return _forward_encdec(cfg, params, batch, plan, remat)
    x = _embed_inputs(params, cfg, batch)
    bsz, seq = x.shape[0], x.shape[1]
    positions = _positions_for(cfg, batch, seq, bsz)
    x, _, aux = _run_stack(params, cfg, plan, x, positions=positions,
                           mode="train", remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, aux


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    bsz, s, _ = frames.shape
    # Sinusoidal positions (whisper encoder).
    d = cfg.d_model
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    x = frames.astype(COMPUTE_DTYPE) + pe.astype(COMPUTE_DTYPE)[None]

    spec = LayerSpec(kind="attn", moe=False, local=False)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))

    def body(carry, bp):
        xc = carry
        xc, _, _ = _apply_layer(bp["layer0"], cfg, spec, xc,
                                positions=positions, mode="encode")
        return xc, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return L.rmsnorm(params["encoder"]["final_norm"], x, cfg.rmsnorm_eps)


def _cross_kv(cfg: ModelConfig, params, enc_out):
    """Precompute per-decoder-layer cross-attention K/V from encoder output."""
    plan = plan_stack(cfg)

    def kv_of(lp):
        xc = enc_out.astype(COMPUTE_DTYPE)
        k = jnp.einsum("bsd,dhk->bshk", xc, lp["xattn"]["wk"].astype(COMPUTE_DTYPE))
        v = jnp.einsum("bsd,dhk->bshk", xc, lp["xattn"]["wv"].astype(COMPUTE_DTYPE))
        return {"xk": k, "xv": v}

    prefix = {f"layer{i}": kv_of(params["prefix"][f"layer{i}"])
              for i in range(len(plan.prefix_specs))}
    blocks = None
    if plan.n_blocks > 0:
        def body(_, bp):
            return None, {f"layer{j}": kv_of(bp[f"layer{j}"])
                          for j in range(len(plan.period_specs))}
        _, blocks = jax.lax.scan(body, None, params["blocks"])
    return {"prefix": prefix, "blocks": blocks or {}}


def _forward_encdec(cfg: ModelConfig, params, batch, plan: StackPlan, remat):
    enc_out = _encode(cfg, params, batch["frames"])
    xattn_kv = _cross_kv(cfg, params, enc_out)
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    x = L.embed_tokens(params["embed"], tokens).astype(COMPUTE_DTYPE)
    positions = _positions_for(cfg, batch, seq, bsz)
    x, _, aux = _run_stack(params, cfg, plan, x, positions=positions,
                           mode="train", xattn_kv=xattn_kv, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    return L.unembed(params["embed"], cfg, x), aux


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Prompt processing. Returns (last_token_logits, caches)."""
    plan = plan_stack(cfg)
    xattn_kv = None
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
        xattn_kv = _cross_kv(cfg, params, enc_out)
        x = L.embed_tokens(params["embed"], batch["tokens"]).astype(COMPUTE_DTYPE)
    else:
        x = _embed_inputs(params, cfg, batch)
    bsz, seq = x.shape[0], x.shape[1]
    positions = _positions_for(cfg, batch, seq, bsz)
    x, caches, _ = _run_stack(params, cfg, plan, x, positions=positions,
                              mode="prefill", max_len=max_len,
                              xattn_kv=xattn_kv)
    x = L.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = L.unembed(params["embed"], cfg, x[:, -1:, :])
    if cfg.encoder_decoder:
        caches = {"self": caches, "cross": xattn_kv}
    return logits, caches


def decode_step(cfg: ModelConfig, params, caches, tokens, pos):
    """One decode step. tokens (B, S); pos scalar int32 (next slot index),
    or (B,) int32 for the batched slot arena, where every cache row sits at
    its own position (ragged continuous-batching decode).  S == 1 is the
    plain step; S > 1 is the speculative multi-token verify step — the S
    tokens occupy consecutive positions pos..pos+S-1 and the logits come
    back for every position."""
    plan = plan_stack(cfg)
    xattn_kv = None
    if cfg.encoder_decoder:
        xattn_kv = caches["cross"]
        self_caches = caches["self"]
    else:
        self_caches = caches
    x = L.embed_tokens(params["embed"], tokens).astype(COMPUTE_DTYPE)
    bsz, s = x.shape[0], x.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    offs = jnp.arange(s, dtype=jnp.int32)
    if cfg.mrope:
        base = pos[None, :, None] if pos.ndim == 1 else pos
        positions = jnp.broadcast_to(base + offs[None, None, :], (3, bsz, s))
    else:
        positions = ((pos[:, None] + offs[None, :]) if pos.ndim == 1
                     else jnp.broadcast_to(pos + offs, (bsz, s)))
    x, new_caches, _ = _run_stack(params, cfg, plan, x, positions=positions,
                                  mode="decode", caches=self_caches,
                                  cache_pos=pos, xattn_kv=xattn_kv)
    x = L.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    if cfg.encoder_decoder:
        new_caches = {"self": new_caches, "cross": xattn_kv}
    return logits, new_caches


# ---------------------------------------------------------------------------
# Cache construction (for dry-run decode cells and the serving engine)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0,
               abstract: bool = False):
    """Build an (abstract) cache pytree for decode-mode lowering."""
    plan = plan_stack(cfg)
    hd = cfg.resolved_head_dim

    def attn_cache():
        shape = (batch, max_len, cfg.kv_heads, hd)
        if abstract:
            return {"k": jax.ShapeDtypeStruct(shape, COMPUTE_DTYPE),
                    "v": jax.ShapeDtypeStruct(shape, COMPUTE_DTYPE)}
        return {"k": jnp.zeros(shape, COMPUTE_DTYPE),
                "v": jnp.zeros(shape, COMPUTE_DTYPE)}

    def layer_cache(spec: LayerSpec):
        if spec.kind == "attn":
            return attn_cache()
        return S.init_mamba_state(cfg, batch, abstract=abstract)

    def lift(tree, n):
        def _l(x):
            if abstract:
                return jax.ShapeDtypeStruct((n,) + tuple(x.shape), x.dtype)
            return jnp.broadcast_to(x[None], (n,) + tuple(x.shape)).copy() \
                if hasattr(x, "shape") else x
        return jax.tree_util.tree_map(_l, tree)

    prefix = {f"layer{i}": layer_cache(spec)
              for i, spec in enumerate(plan.prefix_specs)}
    blocks = {}
    if plan.n_blocks > 0:
        one = {f"layer{j}": layer_cache(spec)
               for j, spec in enumerate(plan.period_specs)}
        blocks = lift(one, plan.n_blocks)
    caches = {"prefix": prefix, "blocks": blocks}

    if cfg.encoder_decoder:
        xshape = (batch, enc_len or max_len, cfg.kv_heads, hd)
        def xkv():
            if abstract:
                return {"xk": jax.ShapeDtypeStruct(xshape, COMPUTE_DTYPE),
                        "xv": jax.ShapeDtypeStruct(xshape, COMPUTE_DTYPE)}
            return {"xk": jnp.zeros(xshape, COMPUTE_DTYPE),
                    "xv": jnp.zeros(xshape, COMPUTE_DTYPE)}
        xprefix = {f"layer{i}": xkv() for i in range(len(plan.prefix_specs))}
        xblocks = {}
        if plan.n_blocks > 0:
            xone = {f"layer{j}": xkv() for j in range(len(plan.period_specs))}
            xblocks = lift(xone, plan.n_blocks)
        caches = {"self": caches, "cross": {"prefix": xprefix, "blocks": xblocks}}
    return caches
