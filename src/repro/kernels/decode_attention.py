"""Pallas TPU kernel: quantized flash-decode attention (beyond-paper, §7.2
of DESIGN.md).

Decode is memory-bound: each step streams the whole KV cache from HBM.  The
paper decompresses KV to BF16 *before* attention; this kernel instead reads
int8 / packed-int4 KV directly and dequantizes in VMEM inside the online-
softmax loop — HBM traffic drops by ≈16/bits with zero extra passes.

Grid: (B, Hkv, S/BS).  The S axis is the innermost (sequential) dimension;
running max / denominator / accumulator live in VMEM scratch and persist
across S blocks (standard flash-decoding).  The Gq query rows of one GQA
group ride together so the (Gq × D) @ (D × BS) score matmul feeds the MXU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _attn_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref, *rest,
                 bits: int, group: int, kv_len: Optional[int],
                 block_s: int, sm_scale: float):
    if kv_len is None:
        # Multi-slot decode: per-row valid lengths streamed in via SMEM —
        # each batch program masks against its own slot's length.
        kvl_ref, o_ref, m_scr, l_scr, acc_scr = rest
        kv_len = kvl_ref[pl.program_id(0)]
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _dequant(c_ref, s_ref):
        c = c_ref[0, 0]  # (BS, D') packed
        if bits == 4:
            lo = (c & jnp.uint8(0x0F)).astype(jnp.int32) - 8
            hi = (c >> jnp.uint8(4)).astype(jnp.int32) - 8
            q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0], c.shape[1] * 2)
        else:
            q = c.astype(jnp.int32)
        bs, d = q.shape
        sc = s_ref[0, 0].astype(jnp.float32)  # (BS, D/group)
        x = q.reshape(bs, d // group, group).astype(jnp.float32) * sc[..., None]
        return x.reshape(bs, d)

    k = _dequant(kc_ref, ks_ref)  # (BS, D) f32
    v = _dequant(vc_ref, vs_ref)
    q = q_ref[0, 0].astype(jnp.float32)  # (Gq, D)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale  # (Gq, BS)

    # mask out cache slots beyond kv_len
    base = s_idx * block_s
    pos = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < kv_len, scores, -jnp.inf)

    m_prev = m_scr[...]           # (Gq, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)   # (Gq, BS)
    l_new = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, Hkv, Gq, D)
    k_codes: jnp.ndarray,  # (B, Hkv, S, D) int8  or (B, Hkv, S, D/2) uint8
    k_scale: jnp.ndarray,  # (B, Hkv, S, D/group) f32
    v_codes: jnp.ndarray,
    v_scale: jnp.ndarray,
    *,
    bits: int = 8,
    group: int = 64,
    kv_len=None,           # None | int | (B,) int32 per-slot valid lengths
    block_s: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Quantized flash-decode attention.

    ``kv_len`` as a static int masks every row at the same length (the
    single-sequence decode of PR 1); a (B,) int32 array is the slot-arena
    path — each batch row is one serving slot at its own ragged length,
    masked inside the kernel from an SMEM-resident length vector.
    """
    b, hkv, gq, d = q.shape
    s = k_codes.shape[2]
    bs = min(block_s, s)
    assert s % bs == 0, (s, bs)
    cw = k_codes.shape[3]
    ng = k_scale.shape[3]
    sm_scale = 1.0 / math.sqrt(d)

    multi_slot = kv_len is not None and jnp.ndim(kv_len) == 1
    static_len = s if kv_len is None else (None if multi_slot else int(kv_len))

    kernel = functools.partial(
        _attn_kernel, bits=bits, group=group, kv_len=static_len, block_s=bs,
        sm_scale=sm_scale)

    in_specs = [
        pl.BlockSpec((1, 1, gq, d), lambda i, j, k: (i, j, 0, 0)),
        pl.BlockSpec((1, 1, bs, cw), lambda i, j, k: (i, j, k, 0)),
        pl.BlockSpec((1, 1, bs, ng), lambda i, j, k: (i, j, k, 0)),
        pl.BlockSpec((1, 1, bs, cw), lambda i, j, k: (i, j, k, 0)),
        pl.BlockSpec((1, 1, bs, ng), lambda i, j, k: (i, j, k, 0)),
    ]
    args = [q, k_codes, k_scale, v_codes, v_scale]
    if multi_slot:
        assert kv_len.shape == (b,), (kv_len.shape, b)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        args.append(jnp.asarray(kv_len, jnp.int32))

    return pl.pallas_call(
        kernel,
        grid=(b, hkv, s // bs),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, gq, d), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gq, 1), jnp.float32),   # running max
            pltpu.VMEM((gq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((gq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(*args)
