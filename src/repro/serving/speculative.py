"""Draft proposers + accept utilities for speculative decoding (DESIGN.md §15).

The decode arena's speculative path splits each iteration into a cheap
*draft* phase (propose up to ``k`` tokens per slot) and ONE masked jitted
multi-token *verify* step over the whole arena.  Greedy verification
commits the longest draft prefix the target model itself would have
emitted, so the output stream is token-exact with plain decode — drafts
only change how many serial steps it takes to produce it.

Two proposers:

* :class:`NGramDraft` — draft-free lookahead: a per-slot suffix-match
  table over the prompt + already-generated tokens.  The most recent
  earlier occurrence of the current 2-gram (falling back to 1-gram)
  suffix proposes the tokens that followed it — free drafts that hit
  hard on repetitive continuations (code, templated text) and simply
  propose nothing when the history has no match (the slot decodes
  normally that iteration).
* :class:`ModelDraft` — the two-model path: a small draft model runs its
  own dense slot arena in lock-step with the target worker's and
  proposes its greedy continuations.  Rejection recovery is automatic:
  every draft phase starts from the slot's *committed* position and
  token, and the draft cache's garbage beyond that position is never
  attended to (reads are capped at the committed position) and is
  overwritten by the next proposal pass.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def accept_length(drafts: Sequence[int], outputs: Sequence[int]) -> int:
    """Longest accepted draft prefix: ``a`` such that ``drafts[j] ==
    outputs[j]`` for all ``j < a``.  ``outputs[j]`` is the target's greedy
    argmax at the position draft ``j`` was fed, so accepting exactly this
    prefix (and emitting ``outputs[a]`` as the bonus token) reproduces the
    sequential greedy stream token for token."""
    a = 0
    for d, o in zip(drafts, outputs):
        if int(d) != int(o):
            break
        a += 1
    return a


# ---------------------------------------------------------------------------
# Draft-free n-gram lookahead
# ---------------------------------------------------------------------------
class NGramDraft:
    """Per-slot suffix-match proposer over prompt + generated history.

    The index maps every n-gram (n <= ``max_ngram``) to the most recent
    position it ended at *that has a continuation*, so a lookup always
    yields at least one follow-on token.  All host-side bookkeeping —
    no model calls, no device syncs."""

    kind = "ngram"

    def __init__(self, max_ngram: int = 2):
        self.max_ngram = max_ngram
        self._hist: Dict[int, List[int]] = {}
        self._index: Dict[int, Dict[Tuple[int, ...], int]] = {}

    # -- lifecycle -----------------------------------------------------
    def start(self, idx: int, rid: int, prompt_tokens: Sequence[int],
              first: int) -> None:
        del idx
        self._hist[rid] = []
        self._index[rid] = {}
        self.commit(0, rid, [int(t) for t in prompt_tokens] + [int(first)])

    def commit(self, idx: int, rid: int, tokens: Sequence[int]) -> None:
        """Append committed tokens, indexing each n-gram that just gained
        a continuation (the gram ending one position back)."""
        del idx
        hist = self._hist[rid]
        index = self._index[rid]
        for t in tokens:
            i = len(hist)           # position the new token will occupy
            for n in range(1, self.max_ngram + 1):
                if i - n >= 0:
                    index[tuple(hist[i - n:i])] = i - 1
            hist.append(int(t))

    def stop(self, idx: int, rid: int) -> None:
        del idx
        self._hist.pop(rid, None)
        self._index.pop(rid, None)

    # -- proposals -----------------------------------------------------
    def propose_all(self, items: Sequence[Tuple[int, int, int, int]],
                    k: Dict[int, int]) -> Dict[int, List[int]]:
        """``items`` is ``(idx, rid, last_tok, pos)`` per live speculative
        slot; ``k[idx]`` its draft budget.  Returns ``{idx: drafts}``
        (possibly shorter than the budget, possibly empty)."""
        out: Dict[int, List[int]] = {}
        for idx, rid, _last, _pos in items:
            hist = self._hist.get(rid)
            index = self._index.get(rid)
            drafts: List[int] = []
            if hist and index:
                for n in range(min(self.max_ngram, len(hist)), 0, -1):
                    p = index.get(tuple(hist[-n:]))
                    if p is not None:
                        drafts = hist[p + 1:p + 1 + k.get(idx, 0)]
                        break
            out[idx] = drafts
        return out


# ---------------------------------------------------------------------------
# Two-model draft path
# ---------------------------------------------------------------------------
class ModelDraft:
    """A draft model running its own dense slot arena beside the target's.

    ``model`` is any object with ``cfg``/``params`` (a
    :class:`~repro.serving.workers.ModelHandle`); by default the caller
    passes the target's own handle — acceptance is then ~1 and the test
    suite exercises the full two-model dataflow without training a second
    model.  The draft arena mirrors the worker's slot indexing; each
    proposal pass runs ``k_max + 1`` masked batched draft steps (the +1
    writes the last draft's own KV row, so a fully-accepted round leaves
    the draft cache complete through the new committed position)."""

    kind = "model"

    def __init__(self, model: Any, seq: int, n_slots: int, max_len: int):
        self.model = model
        self.seq = seq
        self.n_slots = n_slots
        self.max_len = max_len
        self._caches: Any = None
        self._fns = None
        self._positions = np.zeros(n_slots, np.int32)

    def _jitted(self):
        if self._fns is None:
            from repro.core.quality import _jitted_steps
            self._fns = _jitted_steps(self.model.cfg.name, self.seq,
                                      self.n_slots, self.max_len)
        return self._fns

    def _ensure(self):
        if self._caches is None:
            from repro.models.transformer import init_cache
            self._caches = init_cache(self.model.cfg, self.n_slots,
                                      self.max_len)
        return self._caches

    # -- lifecycle -----------------------------------------------------
    def start(self, idx: int, rid: int, prompt_tokens: Sequence[int],
              first: int) -> None:
        del rid, first
        from repro.core.quality import copy_cache_slot
        pre, _, _ = self._jitted()
        self._ensure()
        toks = jnp.asarray(np.asarray(prompt_tokens, np.int32)[None, :])
        _, caches = pre(self.model.params, {"tokens": toks})
        self._caches = copy_cache_slot(self.model.cfg, self._caches,
                                       caches, idx)
        self._positions[idx] = self.seq

    def commit(self, idx: int, rid: int, tokens: Sequence[int]) -> None:
        # The draft cache self-corrects: accepted draft positions already
        # hold the committed tokens' KV, and everything beyond the
        # committed position is masked garbage the next pass overwrites.
        del idx, rid, tokens

    def stop(self, idx: int, rid: int) -> None:
        del rid
        self._positions[idx] = 0

    # -- proposals -----------------------------------------------------
    def propose_all(self, items: Sequence[Tuple[int, int, int, int]],
                    k: Dict[int, int]) -> Dict[int, List[int]]:
        if not items:
            return {}
        _, _, arena = self._jitted()
        self._ensure()
        k_max = max(k.get(idx, 0) for idx, _, _, _ in items)
        if k_max <= 0:
            return {idx: [] for idx, _, _, _ in items}
        mask = np.zeros(self.n_slots, bool)
        toks = np.zeros(self.n_slots, np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        for idx, _rid, last_tok, p in items:
            mask[idx] = True
            toks[idx] = last_tok
            pos[idx] = p
            self._positions[idx] = p
        jmask = jnp.asarray(mask)
        proposals: Dict[int, List[int]] = {idx: [] for idx, _, _, _ in items}
        # k_max proposal steps + one extra that only lands the last
        # draft's KV row (its output is discarded).
        for step in range(k_max + 1):
            nxt, self._caches = arena(
                self.model.params, self._caches, jnp.asarray(toks[:, None]),
                jnp.asarray(pos + step), jmask)
            # lint: sync-ok(draft-side proposal pull - the k+1 small host reads per verify step are the two-model path's documented cost)
            nxt = np.asarray(nxt)
            if step < k_max:
                for idx, _rid, _lt, _p in items:
                    if step < k.get(idx, 0):
                        proposals[idx].append(int(nxt[idx]))
            toks = nxt
        return proposals
