"""Config alias for --arch qwen3-4b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("qwen3-4b")
