"""repro.analysis: per-rule positive/negative fixtures + suppression
grammar + the no-dead-rules meta-test (DESIGN.md §13).

Fixture convention: each entry is ``(fires, {relpath: source})`` — a tiny
project written to tmp_path.  ``fires=True`` fixtures exhibit the bug
class and MUST produce at least one finding of their rule;
``fires=False`` fixtures are the idiomatic clean shape and must produce
none.  Every registered rule needs at least one of each (no dead rules).
"""
import json
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES
from repro.analysis.cli import main, run_paths

REPO = Path(__file__).resolve().parents[1]

# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------
SYNC_BAD = """\
import jax
import jax.numpy as jnp
import numpy as np

def step(state):
    logits = jnp.ones((4, 8))
    tok = np.asarray(jnp.argmax(logits, axis=-1))
    jax.block_until_ready(logits)
    n = int(logits.sum())
    return tok, n
"""

SYNC_CLEAN = """\
import numpy as np

def step(batch):
    toks = np.asarray(batch["tokens"])   # host data: no device taint
    return toks.sum()
"""

SYNC_LAUNDERED = """\
import jax.numpy as jnp
import numpy as np

def helper(x):
    return [int(v) for v in x]

def step(state):
    logits = jnp.ones((4, 8))
    # lint: sync-ok(fixture - the sanctioned once-per-iteration pull)
    host = np.asarray(logits)
    n = int(host.sum())        # host value: laundered, no finding
    hv = helper(logits)        # project def: result is host
    m = float(hv[0])
    return n, m
"""

# ---------------------------------------------------------------------------
# clock-accounting
# ---------------------------------------------------------------------------
CLOCK_DEAD_T = """\
def bill(req, now):
    t_comm = 0.25              # computed, never billed anywhere
    req.breakdown["queue"] = now - req.arrival
    return req
"""

CLOCK_DOUBLE = """\
def bill(req, t_comm):
    req.breakdown["comm"] = t_comm
    req.breakdown["comm"] = 2 * t_comm   # first component dropped
    return req
"""

CLOCK_BACKWARDS = """\
class Wire:
    def send(self, ready, t_comm):
        self.free_at = ready + t_comm    # can move the clock backwards
        return t_comm
"""

CLOCK_CLEAN = """\
class Wire:
    def __init__(self):
        self.free_at = 0.0               # __init__ is exempt

    def send(self, ready, t_comm):
        start = max(ready, self.free_at)
        self.free_at = start + t_comm    # derived from max(): monotone
        return start

def bill(req, now, t_comm):
    if req.hit:
        req.breakdown["comm"] = t_comm   # branches are separate paths
    else:
        req.breakdown["comm"] = 2 * t_comm
    req.breakdown["queue"] = now - req.arrival
    req.breakdown["queue"] += 0.5        # += accumulates, never flags
    return t_comm
"""

# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------
UNITS_MIX = """\
def route_cost(wire_bytes, free_at, t_slo, bandwidth):
    score = wire_bytes + free_at         # bytes + seconds
    if wire_bytes > t_slo:               # bytes vs seconds
        score += 1.0
    t_comm = wire_bytes                  # seconds name, bytes value
    return score + t_comm
"""

UNITS_CLEAN = """\
def route_cost(wire_bytes, free_at, now, bandwidth, ctx_tokens,
               prefill_tok_s):
    t_comm = wire_bytes / bandwidth          # bytes / (bytes/s) -> s
    t_prefill = ctx_tokens / prefill_tok_s   # tokens / (tokens/s) -> s
    wait = max(free_at - now, 0.0)
    payload = bandwidth * t_comm             # (bytes/s) * s -> bytes
    return t_comm + t_prefill + wait, payload + wire_bytes
"""

# ---------------------------------------------------------------------------
# kernel-contract
# ---------------------------------------------------------------------------
KC_INIT_BAD = """\
from pkg.kernels.ops import foo_op

__all__ = ["foo_op"]
"""
KC_REF_BAD = """\
def bar_ref(x):                 # orphan: no export, no oracle uses it
    return x
"""
KC_OPS_BAD = """\
def foo_op(x):                  # no interpret fallback
    return x
"""

KC_INIT_OK = """\
from pkg.kernels.ops import foo_op

__all__ = ["foo_op"]
"""
KC_REF_OK = """\
def _scale_ref(x):
    return x * 2

def foo_ref(x):
    return _scale_ref(x)
"""
KC_OPS_OK = """\
def foo_op(x, interpret=None):
    return x
"""
KC_TEST_OK = """\
def test_foo_parity():
    assert foo_op is not None and foo_ref is not None
"""

# ---------------------------------------------------------------------------
# ownership
# ---------------------------------------------------------------------------
OWN_BAD = """\
class PoolRuntime:
    def __init__(self, spec):
        self.scheduler = ContinuousScheduler()
        self._shared_remote = KVTier(spec)
        self._shared_remote.shared = True

    def rebind(self):
        self.scheduler = ContinuousScheduler()   # other holders keep old

    def poke(self):
        self.scheduler._queue.append(1)          # bypasses the owner API

    def promote(self, hit):
        return hit.tier.store._entries.pop(hit.key)   # MOVE, unguarded

    def choose_worker(self, routes):
        for r in set(routes):                    # hash-order decision
            return r
"""

OWN_CLEAN = """\
from dataclasses import replace


class PoolRuntime:
    def __init__(self, spec):
        self.scheduler = ContinuousScheduler()
        self._shared_remote = KVTier(spec)
        self._shared_remote.shared = True        # construction site: owner
        self.tiers = [KVTier(spec), self._shared_remote]

    def promote(self, hit):
        if hit.tier.shared:
            return replace(hit.entry)            # COPY out of the pool
        return hit.tier.store._entries.pop(hit.key)   # proven local

    def refresh(self, key):
        for t in self.tiers:
            if t.shared:
                continue                         # never clobber the pool
            t.store.discard(key)

    def choose_worker(self, routes):
        return min(routes, key=lambda r: r.index)    # stable field
"""

# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
DET_BAD = """\
import random
import time

import numpy as np


def _run_events(cfg):
    t0 = time.perf_counter()                 # wall clock in virtual time
    rng = np.random.default_rng()            # entropy-seeded
    jit = np.random.normal()                 # legacy global-state API
    x = random.random()                      # stdlib module RNG
    order = sorted(cfg.nodes, key=id)        # allocation-address order
    return t0, rng, jit, x, order


class Trace:
    def _jitter(self, start, nbytes):
        return 1.0 + 0.05 * self.rng.normal()    # long-lived generator
"""

DET_CLEAN = """\
import numpy as np


def _run_events(cfg, now):
    rng = np.random.default_rng(cfg.seed)
    order = sorted(cfg.nodes, key=lambda n: n.nid)
    draw = rng.standard_normal()
    return now + draw, order


def _jitter_mult(seed, start, nbytes):
    rng = np.random.default_rng((seed * 1000003) ^ nbytes)
    return 1.0 + 0.05 * rng.standard_normal()
"""

FIXTURES = {
    "host-sync": [
        (True, {"serving/engine.py": SYNC_BAD}),
        (False, {"serving/engine.py": SYNC_CLEAN}),
        (False, {"serving/engine.py": SYNC_LAUNDERED}),
    ],
    "clock-accounting": [
        (True, {"serving/billing.py": CLOCK_DEAD_T}),
        (True, {"serving/billing.py": CLOCK_DOUBLE}),
        (True, {"serving/wire.py": CLOCK_BACKWARDS}),
        (False, {"serving/runtime.py": CLOCK_CLEAN}),
    ],
    "units": [
        (True, {"serving/route.py": UNITS_MIX}),
        (False, {"serving/route.py": UNITS_CLEAN}),
    ],
    "kernel-contract": [
        (True, {"src/pkg/kernels/__init__.py": KC_INIT_BAD,
                "src/pkg/kernels/ref.py": KC_REF_BAD,
                "src/pkg/kernels/ops.py": KC_OPS_BAD,
                "tests/test_foo.py": "def test_nothing(): pass\n"}),
        (False, {"src/pkg/kernels/__init__.py": KC_INIT_OK,
                 "src/pkg/kernels/ref.py": KC_REF_OK,
                 "src/pkg/kernels/ops.py": KC_OPS_OK,
                 "tests/test_foo.py": KC_TEST_OK}),
    ],
    "ownership": [
        (True, {"serving/cluster.py": OWN_BAD}),
        (False, {"serving/cluster.py": OWN_CLEAN}),
    ],
    "determinism": [
        (True, {"serving/simulator.py": DET_BAD}),
        (False, {"serving/simulator.py": DET_CLEAN}),
    ],
}


def _write(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return tmp_path


def _run(tmp_path):
    return run_paths([str(tmp_path)], base=tmp_path)


@pytest.mark.parametrize(
    "rule_id,fires,files",
    [(rid, fires, files) for rid, cases in FIXTURES.items()
     for fires, files in cases],
    ids=[f"{rid}-{'fires' if fires else 'clean'}-{i}"
         for rid, cases in FIXTURES.items()
         for i, (fires, _) in enumerate(cases)])
def test_fixture(tmp_path, rule_id, fires, files):
    open_, _ = _run(_write(tmp_path, files))
    hits = [f for f in open_ if f.rule == rule_id]
    if fires:
        assert hits, f"{rule_id} did not fire on its bug fixture"
        for f in hits:   # findings are addressable and actionable
            assert f.path and f.line > 0 and f.message
    else:
        assert not hits, [f.render() for f in hits]


def test_no_dead_rules():
    """Meta-test: every registered rule has >=1 firing and >=1 clean
    fixture above — a rule nothing can trigger is dead weight."""
    assert {r.id for r in ALL_RULES} == set(FIXTURES)
    for rid, cases in FIXTURES.items():
        flags = {fires for fires, _ in cases}
        assert flags == {True, False}, f"{rid} lacks a fixture kind"


def test_rule_tokens_unique():
    tokens = [r.token for r in ALL_RULES]
    assert len(tokens) == len(set(tokens))


# ---------------------------------------------------------------------------
# suppression grammar
# ---------------------------------------------------------------------------
def test_suppression_documents_finding(tmp_path):
    src = SYNC_BAD.replace(
        "    tok = np.asarray(jnp.argmax(logits, axis=-1))",
        "    # lint: sync-ok(fixture reason)\n"
        "    tok = np.asarray(jnp.argmax(logits, axis=-1))")
    open_, closed = _run(_write(tmp_path, {"serving/engine.py": src}))
    assert not any(f.rule == "host-sync" and "np.asarray" in f.message
                   for f in open_)
    doc = [f for f in closed if f.rule == "host-sync"]
    assert doc and doc[0].reason == "fixture reason"


def test_suppression_requires_reason(tmp_path):
    src = "import jax\n\ndef step(x):\n" \
          "    jax.block_until_ready(x)  # lint: sync-ok()\n"
    open_, _ = _run(_write(tmp_path, {"serving/engine.py": src}))
    assert any(f.rule == "lint-suppression" and "no reason" in f.message
               for f in open_)
    # ... and the empty suppression does NOT silence the finding
    assert any(f.rule == "host-sync" for f in open_)


def test_suppression_unknown_token(tmp_path):
    src = "def f():\n    return 1  # lint: bogus-ok(whatever)\n"
    open_, _ = _run(_write(tmp_path, {"serving/x.py": src}))
    assert any(f.rule == "lint-suppression" and "unknown" in f.message
               for f in open_)


def test_suppression_in_docstring_ignored(tmp_path):
    src = '"""Docs may show `# lint: sync-ok(reason)` freely."""\n' \
          "def f():\n    return 1\n"
    open_, _ = _run(_write(tmp_path, {"serving/x.py": src}))
    assert not open_


def test_parse_error_is_finding(tmp_path):
    open_, _ = _run(_write(tmp_path, {"serving/x.py": "def broken(:\n"}))
    assert any(f.rule == "parse-error" for f in open_)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_json_and_exit_codes(tmp_path, capsys, monkeypatch):
    _write(tmp_path, {"serving/engine.py": SYNC_BAD})
    monkeypatch.chdir(tmp_path)
    rc = main(["--format=json", "serving"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["counts"]["open"] >= 1
    assert all({"rule", "path", "line", "message", "hint"} <=
               set(f) for f in payload["findings"])

    _write(tmp_path, {"serving/engine.py": SYNC_CLEAN})
    rc = main(["--format=json", "serving"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0 and payload["counts"]["open"] == 0


def test_cli_baseline_diff(tmp_path, capsys, monkeypatch):
    """--baseline diffs against a prior json report: pre-existing
    findings don't fail the run, new ones do, fixed ones count as
    resolved.  Identity is (rule, path, message) — line-number drift
    from unrelated edits must not resurrect old findings."""
    _write(tmp_path, {"serving/engine.py": SYNC_BAD})
    monkeypatch.chdir(tmp_path)
    main(["--format=json", "serving"])
    (tmp_path / "base.json").write_text(capsys.readouterr().out)

    # same tree vs its own report: green
    rc = main(["--format=json", "--baseline", "base.json", "serving"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert payload["baseline"] == {"new": 0, "resolved": 0}

    # a NEW bug on top of the known ones: only it is reported, run fails
    _write(tmp_path, {"serving/engine.py": SYNC_BAD +
                      "\ndef run(state):\n"
                      "    jax.block_until_ready(state)\n"})
    rc = main(["--format=json", "--baseline", "base.json", "serving"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["baseline"]["new"] == len(payload["findings"]) == 1
    assert "run()" in payload["findings"][0]["message"]

    # everything fixed: green again, baseline findings counted resolved
    _write(tmp_path, {"serving/engine.py": SYNC_CLEAN})
    rc = main(["--format=json", "--baseline", "base.json", "serving"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert payload["baseline"]["new"] == 0
    assert payload["baseline"]["resolved"] >= 1


# ---------------------------------------------------------------------------
# the real tree stays clean (the CI gate, enforced from the test suite too)
# ---------------------------------------------------------------------------
def test_repo_tree_is_clean():
    open_, closed = run_paths(
        [str(REPO / "src"), str(REPO / "benchmarks")], base=REPO)
    assert not open_, "\n".join(f.render() for f in open_)
    # every suppression in the tree carries a non-empty reason
    assert all(f.reason for f in closed)
    # the sanctioned decode-loop sync stays documented, not silenced
    assert any(f.path.endswith("serving/workers.py") and f.rule == "host-sync"
               for f in closed)
