"""Pallas TPU kernel: fused symmetric group-quantize + bit-pack.

The KV-compression hot path on the prefill worker: read a bf16 KV tile from
HBM once, quantize per group in VMEM, and emit int8 codes (or nibble-packed
int4) plus fp16-representable scales.  One pass — no intermediate bf16
round-trip to HBM (the GPU implementations in the paper run quant and pack
as separate kernels).

Tiling: rows are tokens (8·k sublanes), the channel dim D sits in lanes
(128-aligned for head_dim ∈ {64,128,256} after flattening heads).  Block
shape (BT, D): the working set BT*D*4B plus outputs stays well under VMEM
(BT=256, D=512 → ~1 MB).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, codes_ref, scale_ref, *, bits: int, group: int):
    x = x_ref[...].astype(jnp.float32)  # (BT, D)
    bt, d = x.shape
    qmax = (1 << (bits - 1)) - 1
    xg = x.reshape(bt, d // group, group)
    amax = jnp.max(jnp.abs(xg), axis=-1)
    scale = jnp.maximum(amax / qmax, 1e-8)  # (BT, D/group)
    q = jnp.clip(jnp.round(xg / scale[..., None]), -qmax - 1, qmax)
    q = q.reshape(bt, d).astype(jnp.int8)
    if bits == 4:
        u = (q.astype(jnp.int32) + 8).astype(jnp.uint8)
        codes_ref[...] = (u[:, 0::2] | (u[:, 1::2] << 4)).astype(jnp.uint8)
    else:
        codes_ref[...] = q
    scale_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(codes_ref, scale_ref, out_ref, *, bits: int, group: int,
                    out_dtype):
    c = codes_ref[...]
    if bits == 4:
        lo = (c & jnp.uint8(0x0F)).astype(jnp.int32) - 8
        hi = (c >> jnp.uint8(4)).astype(jnp.int32) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0], c.shape[1] * 2)
    else:
        q = c.astype(jnp.int32)
    bt, d = q.shape
    scale = scale_ref[...].astype(jnp.float32)  # (BT, D/group)
    x = q.reshape(bt, d // group, group).astype(jnp.float32) * scale[..., None]
    out_ref[...] = x.reshape(bt, d).astype(out_dtype)


def quant_pack(x: jnp.ndarray, bits: int = 8, group: int = 64,
               block_tokens: int = 256, interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (T, D) -> (codes (T, D*bits/8) int8/uint8, scales (T, D/group) f32).

    T need not divide ``block_tokens``: the tail block is zero-padded on
    the way in and sliced off the outputs (each token quantizes
    independently, so padding rows cannot perturb real ones).
    """
    t, d = x.shape
    assert d % group == 0 and bits in (4, 8)
    assert group % 2 == 0
    bt = min(block_tokens, t)
    pad = -t % bt
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
    tp = t + pad
    cw = d if bits == 8 else d // 2
    cdtype = jnp.int8 if bits == 8 else jnp.uint8
    kernel = functools.partial(_quant_kernel, bits=bits, group=group)
    codes, scales = pl.pallas_call(
        kernel,
        grid=(tp // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, cw), lambda i: (i, 0)),
            pl.BlockSpec((bt, d // group), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, cw), cdtype),
            jax.ShapeDtypeStruct((tp, d // group), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    if pad:
        codes, scales = codes[:t], scales[:t]
    return codes, scales


def dequant_unpack(codes: jnp.ndarray, scales: jnp.ndarray, bits: int = 8,
                   group: int = 64, block_tokens: int = 256,
                   out_dtype=jnp.bfloat16, interpret: bool = False
                   ) -> jnp.ndarray:
    t = codes.shape[0]
    d = codes.shape[1] * (2 if bits == 4 else 1)
    bt = min(block_tokens, t)
    pad = -t % bt
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.zeros((pad,) + codes.shape[1:], codes.dtype)], axis=0)
        scales = jnp.concatenate(
            [scales, jnp.zeros((pad,) + scales.shape[1:], scales.dtype)],
            axis=0)
    tp = t + pad
    kernel = functools.partial(_dequant_kernel, bits=bits, group=group,
                               out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, codes.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bt, d // group), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), out_dtype),
        interpret=interpret,
    )(codes, scales)
    return out[:t] if pad else out
