"""shard_hint: identity off-mesh, constraint on-mesh, divisibility rules."""
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.utils.shard_hint import shard_hint

ROOT = Path(__file__).parent.parent


def test_identity_without_mesh():
    x = jnp.ones((8, 4))
    y = shard_hint(x, "model", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constraint_under_mesh_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.utils.shard_hint import shard_hint

mesh = make_mesh((2, 4), ("data", "model"))
def f(x):
    return shard_hint(x * 2, None, "model")
with mesh:
    txt = jax.jit(f).lower(jax.ShapeDtypeStruct((6, 16), jnp.float32)).as_text()
assert "sharding" in txt, "constraint not applied"
# indivisible dim -> no constraint, still compiles
def g(x):
    return shard_hint(x * 2, "model", None)  # 6 % 4 != 0
with mesh:
    jax.jit(g).lower(jax.ShapeDtypeStruct((6, 16), jnp.float32)).compile()
print("ok")
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ok" in r.stdout
