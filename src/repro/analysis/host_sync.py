"""host-sync: device->host synchronisation in serving hot paths.

The continuous runtime's contract (DESIGN.md §9/§12) is ONE host sync
per decode iteration — the (B,) next-token pull.  Every other
``np.asarray``/``.item()``/``float()``/``int()`` on a device array, and
every ``jax.device_get``/``block_until_ready``, reachable from the
per-iteration serving loops stalls the dispatch pipeline and must be
either hoisted out or documented with ``# lint: sync-ok(reason)``.

Mechanics
---------
* **Hot roots**: defs named ``step``/``run``/``serve``/
  ``decode_iteration``/``prefill`` (or ``_run_*``) in modules under a
  ``serving`` directory, plus ``replay*`` defs under ``workloads/``
  (the trace-replay loops step the runtime per event and are just as
  hot).
* **Reachability**: a name-based call graph over the scanned ``repro``
  sources (tests and benchmarks are excluded — they are offline by
  definition).  Over-approximate on purpose: a bare-name match is an
  edge.
* **Device taint** (per hot function, flow-sensitive): values produced
  by ``jnp.*``/``jax.*`` calls, by jitted callables (defs containing
  ``jax.jit``, and locals/attributes assigned from them), and anything
  derived from those are "devicey".  ``np.asarray``/``float``/``int``
  convert back to host values, so the single sanctioned sync does not
  taint everything downstream.  Loop bodies are walked twice so
  loop-carried taint (e.g. ``toks = jnp.argmax(...)`` at the bottom of a
  decode loop) is seen by the loop's own reads.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.core import Finding, Project, SourceFile, dotted, func_defs

RULE_ID = "host-sync"
TOKEN = "sync-ok"

ROOT_NAMES = {"step", "run", "serve", "decode_iteration", "prefill"}
# device->host converters: flagged when fed a devicey value, and their
# result is a host value (kills taint on reassignment).
HOST_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
CASTS = {"float", "int", "bool"}


def _is_scanned(f: SourceFile) -> bool:
    return not (f.in_dir("tests") or f.in_dir("benchmarks")
                or f.in_dir("examples"))


def _contains_jit(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and dotted(n.func) == "jax.jit":
            return True
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in n.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                if dotted(d) == "jax.jit":
                    return True
    return False


class _Index:
    """Project-wide def table + producer/device-callable sets."""

    def __init__(self, files: List[SourceFile]):
        self.defs: Dict[str, List[Tuple[SourceFile, ast.FunctionDef]]] = {}
        for f in files:
            for fn in func_defs(f.tree):
                self.defs.setdefault(fn.name, []).append((f, fn))
        # Producers: defs whose result (or the callables they hand out)
        # produce device arrays.  Seed: anything touching jax.jit.
        self.producers: Set[str] = {
            name for name, defs in self.defs.items()
            if any(_contains_jit(fn) for _, fn in defs)}
        # Attributes assigned from producer calls (self._pre = _jitted..)
        self.device_attrs: Set[str] = set()
        for _ in range(4):  # tiny fixpoint: producer -> attr -> producer
            before = (len(self.producers), len(self.device_attrs))
            for f in files:
                for node in ast.walk(f.tree):
                    if isinstance(node, ast.Assign) and \
                            self._produces(node.value):
                        for tgt in node.targets:
                            for el in (tgt.elts if isinstance(
                                    tgt, (ast.Tuple, ast.List)) else [tgt]):
                                if isinstance(el, ast.Attribute):
                                    self.device_attrs.add(el.attr)
            for name, defs in self.defs.items():
                if name in self.producers:
                    continue
                for _, fn in defs:
                    for n in ast.walk(fn):
                        if isinstance(n, ast.Return) and n.value is not None \
                                and self._mentions_device(n.value):
                            self.producers.add(name)
                            break
            if (len(self.producers), len(self.device_attrs)) == before:
                break

    def _produces(self, expr: ast.AST) -> bool:
        if not isinstance(expr, ast.Call):
            return False
        d = dotted(expr.func)
        tail = d.rsplit(".", 1)[-1]
        return d == "jax.jit" or tail in self.producers

    def _mentions_device(self, expr: ast.AST) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Attribute) and n.attr in self.device_attrs:
                return True
            if isinstance(n, ast.Call) and self._produces(n):
                return True
        return False


def _reachable(index: _Index, files: List[SourceFile]
               ) -> List[Tuple[SourceFile, ast.FunctionDef]]:
    roots = [
        (f, fn) for f in files
        for fn in func_defs(f.tree)
        if (f.in_dir("serving")
            and (fn.name in ROOT_NAMES or fn.name.startswith("_run")))
        or (f.in_dir("workloads") and fn.name.startswith("replay"))]
    seen: Set[Tuple[str, int]] = set()
    work = list(roots)
    out: List[Tuple[SourceFile, ast.FunctionDef]] = []
    while work:
        f, fn = work.pop()
        key = (f.rel, fn.lineno)
        if key in seen:
            continue
        seen.add(key)
        out.append((f, fn))
        for n in ast.walk(fn):
            if not isinstance(n, ast.Call):
                continue
            name = dotted(n.func).rsplit(".", 1)[-1]
            for callee in index.defs.get(name, ()):
                work.append(callee)
    return out


class _TaintWalker:
    """Flow-sensitive device-taint over one function body."""

    def __init__(self, index: _Index, f: SourceFile, fn: ast.FunctionDef):
        self.index = index
        self.f = f
        self.fn = fn
        self.findings: Dict[Tuple[int, int], Finding] = {}

    # -- expression taint -------------------------------------------------
    def _call_is_device(self, call: ast.Call, env: Set[str]) -> bool:
        d = dotted(call.func)
        head, tail = d.split(".", 1)[0] if d else "", d.rsplit(".", 1)[-1]
        if head in ("jnp", "jax") and d not in ("jax.device_get",):
            return True
        if tail in self.index.producers or tail in self.index.device_attrs:
            return True
        if isinstance(call.func, ast.Name) and call.func.id in env:
            return True  # call to a device-callable local
        return False

    def _tainted(self, expr: ast.AST, env: Set[str]) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in env
        if isinstance(expr, ast.Attribute):
            return expr.attr in self.index.device_attrs or \
                self._tainted(expr.value, env)
        if isinstance(expr, ast.Call):
            if self._call_is_device(expr, env):
                return True
            d = dotted(expr.func)
            tail = d.rsplit(".", 1)[-1]
            # host converters sync and return host values: taint stops here
            if d in HOST_CONVERTERS or (isinstance(expr.func, ast.Name)
                                        and expr.func.id in CASTS):
                return False
            # calls to known project defs that are NOT producers return
            # host values (their own internals are analysed separately) —
            # a tainted argument does not taint the result
            if tail in self.index.defs:
                return False
            return any(self._tainted(c, env)
                       for c in ast.iter_child_nodes(expr))
        return any(self._tainted(c, env)
                   for c in ast.iter_child_nodes(expr))

    # -- sync-site detection ----------------------------------------------
    def _flag(self, node: ast.AST, what: str, hint: str) -> None:
        key = (node.lineno, node.col_offset)
        if key not in self.findings:
            self.findings[key] = Finding(
                RULE_ID, self.f.rel, node.lineno,
                f"{what} in a serving hot path "
                f"(reachable from a per-iteration loop via "
                f"{self.fn.name}())", hint)

    def _check_calls(self, expr: ast.AST, env: Set[str]) -> None:
        for n in ast.walk(expr):
            if not isinstance(n, ast.Call):
                continue
            d = dotted(n.func)
            tail = d.rsplit(".", 1)[-1]
            if d == "jax.device_get":
                self._flag(n, "jax.device_get (host sync)",
                           "keep the value on device or annotate "
                           "`# lint: sync-ok(reason)`")
            elif tail == "block_until_ready":
                self._flag(n, "block_until_ready (host sync)",
                           "only wall-clock measurement should block; "
                           "annotate `# lint: sync-ok(reason)` if so")
            elif d in HOST_CONVERTERS and n.args and \
                    self._tainted(n.args[0], env):
                self._flag(n, f"{d} on a device value (host sync)",
                           "pull once per iteration at most; annotate "
                           "`# lint: sync-ok(reason)` for the sanctioned "
                           "pull")
            elif isinstance(n.func, ast.Name) and n.func.id in CASTS and \
                    n.args and self._tainted(n.args[0], env):
                self._flag(n, f"{n.func.id}() on a device value (host sync)",
                           "scalarizing a device array blocks dispatch; "
                           "batch the readback or annotate "
                           "`# lint: sync-ok(reason)`")
            elif isinstance(n.func, ast.Attribute) and n.func.attr == "item" \
                    and not n.args and self._tainted(n.func.value, env):
                self._flag(n, ".item() on a device value (host sync)",
                           "use a batched readback instead of per-element "
                           ".item()")

    # -- statement walk ----------------------------------------------------
    def _assign(self, targets: List[ast.AST], value: ast.AST,
                env: Set[str]) -> None:
        devicey = self._tainted(value, env)
        # host converters at the top level launder the value back to host
        if isinstance(value, ast.Call):
            d = dotted(value.func)
            if d in HOST_CONVERTERS or (isinstance(value.func, ast.Name)
                                        and value.func.id in CASTS):
                devicey = False
        for tgt in targets:
            els = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
            for el in els:
                if isinstance(el, ast.Name):
                    (env.add if devicey else env.discard)(el.id)

    def _walk(self, stmts: List[ast.stmt], env: Set[str]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # nested defs analysed via their own reachability
            for expr in ast.iter_child_nodes(st):
                if isinstance(expr, ast.expr):
                    self._check_calls(expr, env)
            if isinstance(st, ast.Assign):
                self._assign(st.targets, st.value, env)
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                self._assign([st.target], st.value, env)
            elif isinstance(st, ast.AugAssign):
                if self._tainted(st.value, env) and \
                        isinstance(st.target, ast.Name):
                    env.add(st.target.id)
            elif isinstance(st, (ast.For, ast.AsyncFor)):
                if self._tainted(st.iter, env) and \
                        isinstance(st.target, ast.Name):
                    env.add(st.target.id)
                for _ in range(2):  # expose loop-carried taint
                    self._walk(st.body, env)
                self._walk(st.orelse, env)
            elif isinstance(st, ast.While):
                for _ in range(2):
                    self._walk(st.body, env)
                self._walk(st.orelse, env)
            elif isinstance(st, ast.If):
                b, o = set(env), set(env)
                self._walk(st.body, b)
                self._walk(st.orelse, o)
                env |= b | o
            elif isinstance(st, ast.With):
                self._walk(st.body, env)
            elif isinstance(st, ast.Try):
                self._walk(st.body, env)
                for h in st.handlers:
                    self._walk(h.body, env)
                self._walk(st.orelse, env)
                self._walk(st.finalbody, env)

    def run(self) -> List[Finding]:
        self._walk(self.fn.body, set())
        return list(self.findings.values())


def check(project: Project) -> List[Finding]:
    files = project.matching(_is_scanned)
    index = _Index(files)
    findings: List[Finding] = []
    seen: Set[Tuple[str, int, int]] = set()
    for f, fn in _reachable(index, files):
        for fd in _TaintWalker(index, f, fn).run():
            key = (fd.path, fd.line, fd.message)
            if key not in seen:
                seen.add(key)
                findings.append(fd)
    return findings
