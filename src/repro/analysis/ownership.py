"""ownership: worker-local vs cluster-shared object discipline (DESIGN.md §14).

Disaggregation makes KV explicitly *shared cluster state*: the N x M
``ClusterRuntime`` hands every worker the same ``ModelHandle``, the same
``ContinuousScheduler``, the same ``NetworkTopology`` links — and, in
pool mode, ONE cluster-wide shared remote ``KVTier`` that every decode
worker's hierarchy ends in.  Two PR-5 review passes caught, by hand, the
two bug shapes this rule now catches mechanically:

* a MOVE-shaped operation (``discard``/``_entries.pop``/``del``/
  ``.store`` reassignment) on a tier that may be cluster-shared, without
  a ``.shared`` guard — promotion out of a shared pool must COPY, never
  move, or the entry vanishes for every other worker;
* one worker's code path clobbering shared state (``put()``
  pre-removing a shared tier's copy during a local refresh).

Checks
------
1. **Shared-object mutation outside owner methods.**  Per class,
   attributes assigned from ``ModelHandle(...)`` / ``NetworkTopology(...)``
   / ``ContinuousScheduler(...)`` constructor calls, attributes whose
   name matches ``_shared*``, and attributes annotated ``.shared = True``
   are classified cluster-SHARED at their construction/annotation site.
   Writing *into* such an object (``self._model.cfg = ...``), rebinding
   it, or calling a raw container mutator on its private state
   (``self.scheduler._free_slots.append``) outside the allowlisted
   owner-method set (:data:`OWNER_METHODS`) is a finding.
2. **MOVE-shaped ops on maybe-shared tiers.**  Within a function, tier
   expressions (loop vars over ``*.tiers``, names assigned from
   ``*.tiers[i]``, dotted paths ending ``.tier``, ``_shared*`` attrs)
   are tracked flow-sensitively through ``if X.shared:`` guards; a
   ``discard``/``_entries.pop``/``del _entries[...]``/``.store =``
   on a tier NOT proven worker-local flags.  ``if t.shared: continue``
   and the ``else`` arm of ``if hit.tier.shared:`` prove locality.
3. **Unordered iteration feeding decisions.**  In routing/eviction
   decision functions (name matches ``choose|route|admit|evict|victim|
   place|promote|select|schedule``), iterating a set (literal,
   ``set()``, set comprehension) or a raw dict view (``.keys()`` /
   ``.values()`` / ``.items()``) — or ``next(iter(...))`` over one —
   makes the decision depend on insertion/hash order, which differs
   across workers and replays.  ``sorted(...)`` with an explicit key is
   the sanctioned shape.

Scope: ``serving/``.  Suppression token: ``own-ok``.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, SourceFile, dotted, func_defs

RULE_ID = "ownership"
TOKEN = "own-ok"

# Constructor calls whose results are cluster-shared by design: every
# worker reads the model through one handle, the scheduler admits for the
# whole mesh, the topology owns every (src, dst) link.
SHARED_CONSTRUCTORS = {"ModelHandle", "NetworkTopology", "ContinuousScheduler"}
SHARED_NAME_RE = re.compile(r"^_?shared")

# Construction/annotation sites: the owner-method allowlist.  These are
# where shared objects are built, wired and flagged — mutation there IS
# ownership.
OWNER_METHODS = {"__init__", "__post_init__", "_build_store", "wrap_flat"}

# Raw container mutators: calling one on a shared object's private state
# bypasses its owner API.
MUTATORS = {"pop", "clear", "update", "remove", "append", "extend",
            "insert", "setdefault", "popitem", "discard"}

DECISION_RE = re.compile(
    r"choose|route|admit|evict|victim|place|promote|select|schedule")


def _in_scope(f: SourceFile) -> bool:
    return f.in_dir("serving") and not f.in_dir("tests")


# ---------------------------------------------------------------------------
# Check 1: shared-object mutation outside the owner-method allowlist
# ---------------------------------------------------------------------------
def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' for a `self.x` attribute node, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _shared_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attribute names classified cluster-shared from their
    construction/annotation sites anywhere in the class."""
    shared: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            a = _self_attr(tgt)
            if a is not None:
                if SHARED_NAME_RE.match(a):
                    shared.add(a)
                if isinstance(node.value, ast.Call) and \
                        dotted(node.value.func).rsplit(".", 1)[-1] \
                        in SHARED_CONSTRUCTORS:
                    shared.add(a)
            # self.<A>.shared = True annotates <A> as a shared tier
            if isinstance(tgt, ast.Attribute) and tgt.attr == "shared":
                base = _self_attr(tgt.value)
                if base is not None and isinstance(node.value, ast.Constant) \
                        and node.value.value is True:
                    shared.add(base)
    return shared


def _chain_base(node: ast.AST) -> Tuple[Optional[str], int, bool]:
    """Unroll an Attribute/Subscript chain.  Returns ``(self_attr,
    depth, has_private)``: the `self.<attr>` base (or None), how many
    attribute hops sit above it (0 = the base itself), and whether any
    hop above the base is underscore-private."""
    depth, private = 0, False
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if isinstance(node, ast.Attribute):
            base = _self_attr(node)
            if base is not None:
                return base, depth, private
            if node.attr.startswith("_"):
                private = True
            depth += 1
        node = node.value
    return None, depth, private


def _check_shared_mutation(f: SourceFile, cls: ast.ClassDef,
                           shared: Set[str]) -> List[Finding]:
    findings: List[Finding] = []
    hint = ("mutate shared objects only from their owner methods "
            f"({', '.join(sorted(OWNER_METHODS))}); annotate "
            "`# lint: own-ok(reason)` if this site is an intentional "
            "cluster-wide mutation")
    for fn in (n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))):
        if fn.name in OWNER_METHODS:
            continue
        for node in ast.walk(fn):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = list(node.targets)
            for tgt in targets:
                base, depth, _ = _chain_base(tgt)
                if base not in shared:
                    continue
                if depth == 0 and isinstance(tgt, ast.Attribute):
                    findings.append(Finding(
                        RULE_ID, f.rel, node.lineno,
                        f"cluster-shared `{base}` rebound in "
                        f"{cls.name}.{fn.name}() — other holders keep the "
                        f"old object", hint))
                else:
                    findings.append(Finding(
                        RULE_ID, f.rel, node.lineno,
                        f"write into cluster-shared `{base}` in "
                        f"{cls.name}.{fn.name}() (outside the owner-method "
                        f"allowlist)", hint))
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in MUTATORS:
                base, depth, private = _chain_base(node.func.value)
                if base in shared and private:
                    findings.append(Finding(
                        RULE_ID, f.rel, node.lineno,
                        f"raw `{node.func.attr}()` on cluster-shared "
                        f"`{base}`'s private state in "
                        f"{cls.name}.{fn.name}()", hint))
    return findings


# ---------------------------------------------------------------------------
# Check 2: MOVE-shaped operations on maybe-shared tiers
# ---------------------------------------------------------------------------
def _ends_with(node: ast.AST, attr: str) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == attr


class _TierWalker:
    """Flow-sensitive `.shared` narrowing over one function body.

    Env maps a tier key -> True (proven shared) | False (proven
    worker-local) | None (unknown).  MOVE ops flag unless the key is
    proven False at the site."""

    def __init__(self, f: SourceFile, fn: ast.FunctionDef):
        self.f = f
        self.fn = fn
        self.loop_vars: Set[str] = set()     # for X in *.tiers
        self.sub_names: Set[str] = set()     # X = *.tiers[i]
        self.findings: List[Finding] = []

    # -- candidate tier expressions ------------------------------------
    def _tier_key(self, node: ast.AST) -> Tuple[Optional[str],
                                                Optional[bool]]:
        """(key, known) for a candidate tier expression, (None, None)
        otherwise.  `_shared*` attrs are known-shared a priori."""
        if isinstance(node, ast.Name) and \
                (node.id in self.loop_vars or node.id in self.sub_names
                 or node.id == "tier"):
            return node.id, None
        if isinstance(node, ast.Attribute):
            if SHARED_NAME_RE.match(node.attr):
                return dotted(node) or node.attr, True
            if node.attr == "tier" or node.attr.endswith("tier"):
                d = dotted(node)
                return (d, None) if d else (None, None)
        if isinstance(node, ast.Subscript):
            v = node.value
            if isinstance(v, ast.Attribute) and v.attr == "tiers":
                return (dotted(v) or "tiers") + "[i]", None
        return None, None

    def _collect_candidates(self) -> None:
        for node in ast.walk(self.fn):
            if isinstance(node, (ast.For, ast.AsyncFor)) and \
                    isinstance(node.target, ast.Name) and \
                    _ends_with(node.iter, "tiers"):
                self.loop_vars.add(node.target.id)
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    isinstance(node.value, ast.Subscript) and \
                    _ends_with(node.value.value, "tiers"):
                self.sub_names.add(node.targets[0].id)

    # -- MOVE-shape detection ------------------------------------------
    def _flag(self, node: ast.AST, key: str, what: str,
              known: Optional[bool]) -> None:
        kind = ("a cluster-SHARED tier" if known
                else "a possibly-shared tier (no `.shared` guard)")
        self.findings.append(Finding(
            RULE_ID, self.f.rel, node.lineno,
            f"MOVE-shaped {what} on {kind} `{key}` — promotion out of a "
            f"shared pool must COPY; the pool copy stays visible to "
            f"every other worker",
            "guard with `if X.shared:` (COPY via dataclasses.replace) "
            "or prove the tier worker-local; annotate "
            "`# lint: own-ok(reason)` if intentional"))

    def _move_site(self, node: ast.AST
                   ) -> Optional[Tuple[ast.AST, str, str]]:
        """(tier_expr, op, site_node) when `node` is a MOVE shape."""
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute):
            recv = node.func.value
            if node.func.attr == "discard":
                if _ends_with(recv, "store"):
                    return recv.value, "discard()", "call"
                return recv, "discard()", "call"
            if node.func.attr == "pop" and _ends_with(recv, "_entries") \
                    and _ends_with(recv.value, "store"):
                return recv.value.value, "_entries.pop()", "call"
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if _ends_with(tgt, "store"):
                    return tgt.value, ".store reassignment", "assign"
        if isinstance(node, ast.Delete):
            for tgt in node.targets:
                if isinstance(tgt, ast.Subscript) and \
                        _ends_with(tgt.value, "_entries") and \
                        _ends_with(tgt.value.value, "store"):
                    return tgt.value.value.value, "del _entries[...]", "del"
        return None

    # -- statement walk -------------------------------------------------
    @staticmethod
    def _terminates(stmts: List[ast.stmt]) -> bool:
        return bool(stmts) and isinstance(
            stmts[-1], (ast.Continue, ast.Return, ast.Raise, ast.Break))

    def _guard_key(self, test: ast.AST) -> Tuple[Optional[str], bool]:
        """(key, polarity) for an `X.shared` / `not X.shared` test."""
        neg = False
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            test, neg = test.operand, True
        if _ends_with(test, "shared"):
            key, _ = self._tier_key(test.value)
            if key is not None:
                return key, not neg
        return None, False

    def _check_node(self, node: ast.AST,
                    env: Dict[str, Optional[bool]]) -> None:
        for n in ast.walk(node):
            site = self._move_site(n)
            if site is None:
                continue
            expr, op, _ = site
            key, known = self._tier_key(expr)
            if key is None:
                continue
            proven = known if known is not None else env.get(key)
            if proven is not False:
                self._flag(n, key, op, proven)

    def _walk(self, stmts: List[ast.stmt],
              env: Dict[str, Optional[bool]]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.If):
                key, truthy = self._guard_key(st.test)
                benv, oenv = dict(env), dict(env)
                if key is not None:
                    benv[key] = truthy
                    oenv[key] = not truthy
                self._walk(st.body, benv)
                self._walk(st.orelse, oenv)
                if key is not None:
                    if self._terminates(st.body):
                        env[key] = not truthy
                    elif st.orelse and self._terminates(st.orelse):
                        env[key] = truthy
                continue
            if isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
                benv = dict(env)
                if isinstance(st, (ast.For, ast.AsyncFor)) and \
                        isinstance(st.target, ast.Name):
                    benv.pop(st.target.id, None)   # fresh binding per iter
                self._check_node(st.iter if isinstance(
                    st, (ast.For, ast.AsyncFor)) else st.test, env)
                self._walk(st.body, benv)
                self._walk(st.orelse, dict(env))
                continue
            if isinstance(st, (ast.With, ast.AsyncWith)):
                self._walk(st.body, env)
                continue
            if isinstance(st, ast.Try):
                self._walk(st.body, dict(env))
                for h in st.handlers:
                    self._walk(h.body, dict(env))
                self._walk(st.orelse, dict(env))
                self._walk(st.finalbody, dict(env))
                continue
            self._check_node(st, env)

    def run(self) -> List[Finding]:
        self._collect_candidates()
        self._walk(self.fn.body, {})
        return self.findings


# ---------------------------------------------------------------------------
# Check 3: unordered iteration feeding routing/eviction decisions
# ---------------------------------------------------------------------------
def _check_decision_order(f: SourceFile, fn: ast.FunctionDef
                          ) -> List[Finding]:
    if not DECISION_RE.search(fn.name):
        return []
    findings: List[Finding] = []
    set_names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            v = node.value
            if isinstance(v, (ast.Set, ast.SetComp)) or (
                    isinstance(v, ast.Call) and dotted(v.func) == "set"):
                set_names.add(node.targets[0].id)

    def unordered(expr: ast.AST) -> Optional[str]:
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return "a set"
        if isinstance(expr, ast.Name) and expr.id in set_names:
            return f"set `{expr.id}`"
        if isinstance(expr, ast.Call):
            d = dotted(expr.func)
            if d == "set":
                return "a set"
            if d.endswith((".keys", ".values", ".items")):
                return f"raw dict view `{d.rsplit('.', 1)[-1]}()`"
        return None

    def flag(node: ast.AST, what: str) -> None:
        findings.append(Finding(
            RULE_ID, f.rel, node.lineno,
            f"iteration over {what} feeds the order-sensitive decision "
            f"`{fn.name}()` — set/dict order varies across workers and "
            f"replays",
            "iterate a list kept in a deterministic order, or wrap in "
            "`sorted(..., key=...)`; annotate `# lint: own-ok(reason)` "
            "if order provably cannot matter"))

    sorted_spans: Set[int] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                dotted(node.func) in ("sorted", "list"):
            for sub in ast.walk(node):
                if sub is not node:
                    sorted_spans.add(id(sub))
    for node in ast.walk(fn):
        iters: List[ast.AST] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(g.iter for g in node.generators)
        elif isinstance(node, ast.Call) and dotted(node.func) == "next" \
                and node.args and isinstance(node.args[0], ast.Call) \
                and dotted(node.args[0].func) == "iter" \
                and node.args[0].args:
            iters.append(node.args[0].args[0])
        for it in iters:
            if id(it) in sorted_spans:
                continue
            what = unordered(it)
            if what is not None:
                flag(node if hasattr(node, "lineno") else it, what)
    return findings


# ---------------------------------------------------------------------------
def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for f in project.matching(_in_scope):
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                shared = _shared_attrs(node)
                if shared:
                    findings.extend(
                        _check_shared_mutation(f, node, shared))
        for fn in func_defs(f.tree):
            # construction sites (the owner allowlist) wire hierarchies
            # together — a .store swap THERE is ownership, not a MOVE
            if fn.name not in OWNER_METHODS:
                findings.extend(_TierWalker(f, fn).run())
            findings.extend(_check_decision_order(f, fn))
    # dedupe (nested walks can reach one site twice)
    seen, uniq = set(), []
    for fd in findings:
        key = (fd.path, fd.line, fd.message)
        if key not in seen:
            seen.add(key)
            uniq.append(fd)
    return uniq
