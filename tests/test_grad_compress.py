"""Gradient compression with error feedback (beyond-paper extension)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distribution.grad_compress import (
    _quant_roundtrip,
    init_ef_state,
    make_grad_transform,
)


def test_quant_roundtrip_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    for bits in (4, 8):
        gh = _quant_roundtrip(g, bits, 64)
        qmax = (1 << (bits - 1)) - 1
        bound = float(jnp.abs(g).max()) / qmax + 1e-6
        assert float(jnp.abs(gh - g).max()) <= bound


def test_error_feedback_unbiased_over_time():
    """EF accumulates what quantization dropped; the running sum of applied
    gradients converges to the true sum."""
    rng = np.random.default_rng(1)
    transform = make_grad_transform(bits=4, group=32, error_feedback=True)
    g_true = jnp.asarray(rng.standard_normal((32, 32)) * 0.01, jnp.float32)
    opt_state = {"ef": init_ef_state({"w": g_true})["w"]}
    opt_state = {"ef": {"w": jnp.zeros_like(g_true)}}
    applied = jnp.zeros_like(g_true)
    n = 40
    for _ in range(n):
        gh, opt_state = transform({"w": g_true}, opt_state)
        applied = applied + gh["w"]
    # mean applied ≈ true gradient (residual bounded by one quant step)
    err = float(jnp.abs(applied / n - g_true).max())
    no_ef_err = float(jnp.abs(_quant_roundtrip(g_true, 4, 32) - g_true).max())
    assert err < no_ef_err / 2


def test_training_with_grad_compression_converges():
    """Reduced-config training with 8-bit EF grads reaches a loss close to
    uncompressed training."""
    from repro.configs import get_config
    from repro.configs.base import reduce_config
    from repro.data.synthetic import make_batch
    from repro.distribution.optimizer import OptConfig, init_opt_state
    from repro.distribution.steps import make_train_step
    from repro.models import init_params

    cfg = reduce_config(get_config("qwen3-4b"))

    def run(bits):
        params, _ = init_params(cfg, seed=0)
        oc = OptConfig(lr=3e-3, warmup_steps=2, total_steps=30)
        opt = init_opt_state(params)
        gt = None
        if bits:
            gt = make_grad_transform(bits=bits)
            opt["ef"] = init_ef_state(params)
        step = jax.jit(make_train_step(cfg, oc, remat=False,
                                       grad_transform=gt))
        loss = None
        for i in range(30):
            tokens, mask = make_batch("mixed", 4, 32, seed=i)
            tokens = np.minimum(tokens, cfg.vocab_size - 1)
            b = {"tokens": jnp.asarray(tokens),
                 "mask": jnp.asarray(mask[:, 1:])}
            params, opt, m = step(params, opt, b)
            loss = float(m["loss"])
        return loss

    base = run(0)
    comp = run(8)
    assert comp < base * 1.15, (base, comp)
