"""Multi-worker cluster runtime: scale-out throughput + routing policy
sweep (ISSUE 5 tentpole).

Three deterministic claims about the
:class:`~repro.serving.cluster.ClusterRuntime` (virtual clock => every
assert is exact):

  1. **Scale-out** — under saturating offered load a 2x2 cluster (two
     prefill workers, two decode arenas, a 2x2 link mesh) sustains
     >= 1.8x the completed-request throughput of the 1x1 runtime.
  2. **Load-aware routing** — on a heterogeneous topology (one 1 Gbps and
     one 50 Mbps link) the predicted-latency argmin router yields
     strictly lower mean JCT than round-robin placement, by keeping KV
     transfers off the slow wire (per-link goodput estimators are seeded
     from each link's OWN configured trace).
  3. **1x1 degeneracy** — a 1x1 ClusterRuntime reproduces the pinned PR-1
     token fixture bit-for-bit in BOTH ``pool`` and ``pd`` modes (and
     matches a live ServingRuntime run even when the trained reference
     model differs from the fixture's).

Emitted rows include the tail metrics (p50/p95/p99 TTFT and JCT) of each
configuration, not just means.

CLI: ``--smoke`` shrinks to CI-sized settings; ``--json PATH`` archives
the emitted rows.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import emit, write_json
from repro.serving import (
    BandwidthTrace,
    GBPS,
    NetworkTopology,
    SchedulerConfig,
)

WORKLOAD_CYCLE = ("qalike", "codelike", "mathlike", "summlike")


def _profile():
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel"),
                   cr=2.0, s_enc=5e8, s_dec=5e8)


def _cluster(*, mode="pd", seq, decode_tokens, n_prefill=1, n_decode=1,
             router="load_aware", topology=None, bandwidth=1 * GBPS,
             prefill_tok_s=200.0, max_prefills=1, max_slots=4):
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.engine import RuntimeConfig
    return ClusterRuntime(
        static_profile=_profile(),
        config=RuntimeConfig(seq=seq, decode_tokens=decode_tokens,
                             prefill_tok_s=prefill_tok_s,
                             decode_tok_s=500.0, mode=mode),
        trace=BandwidthTrace.constant(bandwidth),
        scheduler=SchedulerConfig(max_slots=max_slots,
                                  max_prefills_per_step=max_prefills,
                                  max_queue=1024),
        topology=topology, n_prefill=n_prefill, n_decode=n_decode,
        router=router)


def _tails(summary) -> str:
    keys = ("ttft_p50", "ttft_p95", "ttft_p99", "jct_p50", "jct_p95",
            "jct_p99")
    return " ".join(f"{k}={summary[k]:.4f}" for k in keys if k in summary)


# ---------------------------------------------------------------------------
# 1) scale-out throughput
# ---------------------------------------------------------------------------
def _throughput(n_prefill: int, n_decode: int, n_requests: int, seq: int
                ) -> Tuple[float, object]:
    rt = _cluster(mode="pd", seq=seq, decode_tokens=3,
                  n_prefill=n_prefill, n_decode=n_decode)
    for i in range(n_requests):
        # distinct prompts: a genuinely cold, saturating stream
        rt.submit(WORKLOAD_CYCLE[i % 4], prompt_seed=500 + 11 * i,
                  out_tokens=1)
    done = rt.run()
    assert len(done) == n_requests, "saturating load must fully drain"
    return n_requests / rt.clock, rt


def run_scaling(n_requests: int, seq: int) -> None:
    t0 = time.perf_counter()
    thr11, rt11 = _throughput(1, 1, n_requests, seq)
    thr22, rt22 = _throughput(2, 2, n_requests, seq)
    ratio = thr22 / thr11
    us = (time.perf_counter() - t0) * 1e6
    emit("cluster_throughput_1x1", us,
         f"rps={thr11:.3f} " + _tails(rt11.summary()))
    emit("cluster_throughput_2x2", 0.0,
         f"rps={thr22:.3f} scaling={ratio:.2f}x " + _tails(rt22.summary()))
    # Acceptance: near-linear scale-out of the prefill-bound regime.
    assert ratio >= 1.8, (thr11, thr22)
    # both prefill workers really shared the load
    by_pw = {}
    for r in rt22.completed:
        pw = r.route.split("->")[0]
        by_pw[pw] = by_pw.get(pw, 0) + 1
    assert set(by_pw) == {"p0", "p1"}, by_pw


# ---------------------------------------------------------------------------
# 2) routing policy on a heterogeneous mesh
# ---------------------------------------------------------------------------
def _routed_jct(router: str, n: int, seq: int) -> Tuple[float, int, dict]:
    slow = BandwidthTrace.constant(0.05 * GBPS)     # the 50 Mbps wire
    topo = NetworkTopology.full_mesh(
        1, 2, BandwidthTrace.constant(1 * GBPS), links={(0, 1): slow})
    rt = _cluster(mode="pd", seq=seq, decode_tokens=3, n_prefill=1,
                  n_decode=2, router=router, topology=topo,
                  prefill_tok_s=2000.0, max_slots=6)
    for i in range(n):
        rt.submit(WORKLOAD_CYCLE[i % 4], prompt_seed=900 + 7 * i,
                  out_tokens=1)
        rt.step()
    done = rt.run()
    assert len(done) == n and all(not r.pool_hit for r in done)
    slow_share = sum(1 for r in done if r.route == "p0->d1")
    return (float(np.mean([r.jct for r in done])), slow_share,
            rt.summary())


def run_routing(n: int, seq: int) -> None:
    t0 = time.perf_counter()
    jct_rr, slow_rr, sum_rr = _routed_jct("round_robin", n, seq)
    jct_la, slow_la, sum_la = _routed_jct("load_aware", n, seq)
    us = (time.perf_counter() - t0) * 1e6
    emit("cluster_routing_round_robin", us,
         f"mean_jct={jct_rr:.4f}s slow_link_requests={slow_rr} "
         + _tails(sum_rr))
    emit("cluster_routing_load_aware", 0.0,
         f"mean_jct={jct_la:.4f}s slow_link_requests={slow_la} "
         f"gain={jct_rr / jct_la:.2f}x " + _tails(sum_la))
    # Acceptance: load-aware placement strictly beats round-robin on the
    # heterogeneous mesh, by avoiding the 50 Mbps wire.
    assert jct_la < jct_rr, (jct_la, jct_rr)
    assert slow_la < slow_rr, (slow_la, slow_rr)


# ---------------------------------------------------------------------------
# 3) 1x1 degeneracy: pinned PR-1 fixture, both modes
# ---------------------------------------------------------------------------
def run_parity() -> None:
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tests"))
    from _runtime_scenario import FIXTURE, params_digest, run_scenario
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    fix = json.loads(FIXTURE.read_text())

    def build(cls, mode: str, **kw):
        return cls(
            static_profile=_profile(),
            config=RuntimeConfig(seq=64, decode_tokens=6,
                                 prefill_tok_s=2000.0, decode_tok_s=500.0,
                                 mode=mode),
            trace=BandwidthTrace.constant(1 * GBPS),
            scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                      max_queue=32), **kw)

    for mode in ("pool", "pd"):
        t0 = time.perf_counter()
        rt = build(ClusterRuntime, mode, n_prefill=1, n_decode=1)
        out = run_scenario(rt)
        against_fixture = params_digest(rt.params) == fix["params_digest"]
        if against_fixture:
            ref = fix["outputs"]
        else:
            # CI-sized reference model (digest mismatch): the pinned
            # tokens don't apply, so this degrades to a determinism/
            # facade-consistency check against a live 1x1 ServingRuntime
            # — which shares the ClusterRuntime code path, so it can NOT
            # catch a regression vs the PR-1 tokens.  The real parity
            # gate is the fixture branch (runs wherever the full
            # reference model is available, e.g. locally and in the
            # pinned-fixture tests).
            ref = run_scenario(build(ServingRuntime, mode))
        assert set(out) == set(ref)
        for rid, rec in ref.items():
            assert out[rid]["pool_hit"] == rec["pool_hit"], (mode, rid)
            assert out[rid]["tokens"] == rec["tokens"], (mode, rid)
        emit(f"cluster_1x1_parity_{mode}",
             (time.perf_counter() - t0) * 1e6,
             f"requests={len(out)} "
             + ("token_exact=True vs=pinned_fixture" if against_fixture
                else "consistent=True vs=live_1x1 (fixture digest "
                     "mismatch: parity not provable here)"))


# ---------------------------------------------------------------------------
def run(smoke: bool = False) -> None:
    n_requests = 8 if smoke else 16
    seq = 48 if smoke else 96
    run_scaling(n_requests, seq)
    run_routing(6 if smoke else 12, seq)
    run_parity()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings; crash = fail")
    ap.add_argument("--json", default="",
                    help="archive emitted rows to this JSON path")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
