"""Theorems 6.1 / 6.2: property tests against brute force."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.controller import (
    bandwidth_threshold,
    baseline_latency,
    brute_force_optimal,
    build_envelope,
    normalized_latency,
    predicted_latency,
    ServiceContext,
)
from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.core.strategy import StrategyConfig


def _mk_profiles(seed, n):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        cr = float(rng.uniform(1.01, 12.0))
        s = float(rng.uniform(1e7, 1e11))
        out.append(Profile(StrategyConfig(key_bits=(i % 7) + 2,
                                          group_size=(32, 64, 128)[i % 3],
                                          delta_group=16 if i % 2 else 64),
                           cr=cr, s_enc=2 * s, s_dec=2 * s))
    return out


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 50),
       logx=st.floats(-12, -6))
def test_envelope_matches_brute_force(seed, n, logx):
    """Theorem 6.2: lower-envelope lookup == O(n) argmin, for any B."""
    profiles = _mk_profiles(seed, n)
    env = build_envelope(profiles)
    x = 10.0 ** logx
    got = env.optimal(x)
    want = brute_force_optimal(profiles, x)
    assert abs(normalized_latency(got, x) - normalized_latency(want, x)) < 1e-15


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), logb=st.floats(6.5, 11.5))
def test_bandwidth_threshold_theorem(seed, logb):
    """Theorem 6.1: T_p < T_0  <=>  B < B*_p, independent of V."""
    p = _mk_profiles(seed, 1)[0]
    b = 10.0 ** logb
    bstar = bandwidth_threshold(p)
    for v in (1e6, 1e9):
        ctx = ServiceContext("qalike", b, 0.0, 0.0, t_model=0.01, kv_bytes=v)
        beneficial = predicted_latency(p, ctx) < baseline_latency(ctx)
        if abs(b - bstar) / bstar > 1e-9:  # away from the knife edge
            assert beneficial == (b < bstar)


def test_envelope_includes_identity():
    """At very high bandwidth the envelope must select no-compression."""
    profiles = _mk_profiles(0, 20)
    env = build_envelope(profiles, include_identity=True)
    p = env.optimal(1e-30)  # x -> 0 means B -> inf
    assert p.cr == 1.0 and p.s_eff == float("inf")


def test_candidates_are_neighbors():
    profiles = _mk_profiles(1, 30)
    env = build_envelope(profiles)
    if len(env.lines) >= 3:
        x = (env.breaks[0] + env.breaks[1]) / 2 if len(env.breaks) >= 2 \
            else env.breaks[0] * 1.5
        cands = env.candidates(x, n_neighbors=1)
        assert 1 <= len(cands) <= 3
        assert env.optimal(x) in cands


def test_breaks_sorted():
    env = build_envelope(_mk_profiles(5, 40))
    assert all(env.breaks[i] < env.breaks[i + 1]
               for i in range(len(env.breaks) - 1))
