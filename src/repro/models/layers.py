"""Pure-JAX transformer layers: GQA attention (qk-norm, softcap, sliding
window, chunked online-softmax), RoPE / M-RoPE, gated MLP, and sorted
capacity-based MoE.

Everything is functional: ``init_*`` builds Pm-annotated param trees,
``apply_*`` consumes plain value trees.  Compute dtype is bf16 with fp32
softmax/normalisation, matching TPU practice.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.axes import Initializer, Pm

COMPUTE_DTYPE = jnp.bfloat16
ATTN_CHUNK = 1024  # KV chunk for the online-softmax path


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(ini: Initializer, d: int) -> Dict[str, Pm]:
    return {"scale": ini.ones((d,), (None,))}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def rmsnorm_headdim(scale, x, eps: float = 1e-6):
    """qk-norm: rmsnorm over the head_dim axis of (B, S, H, D)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float):
    """M-RoPE (qwen2-vl): positions3 (3, B, S); freq slots split 2:1:1 over
    (temporal, height, width) position streams."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_frequencies(d, theta)  # (half,)
    t_sec = half // 2
    h_sec = (half - t_sec) // 2
    sec_of = jnp.concatenate([
        jnp.zeros((t_sec,), jnp.int32),
        jnp.ones((h_sec,), jnp.int32),
        jnp.full((half - t_sec - h_sec,), 2, jnp.int32),
    ])  # (half,) -> which position stream each freq slot uses
    # pos_per_slot: (B, S, half)
    pos = jnp.transpose(positions3, (1, 2, 0)).astype(jnp.float32)  # (B,S,3)
    pos_slot = pos[..., sec_of]
    angles = pos_slot * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(ini: Initializer, cfg: ModelConfig) -> Dict[str, Pm]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p = {
        "wq": ini.normal((d, cfg.num_heads, hd), ("embed", "heads", "head_dim"),
                         scale=1.0 / math.sqrt(d)),
        "wk": ini.normal((d, cfg.kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                         scale=1.0 / math.sqrt(d)),
        "wv": ini.normal((d, cfg.kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                         scale=1.0 / math.sqrt(d)),
        "wo": ini.normal((cfg.num_heads, hd, d), ("heads", "head_dim", "embed"),
                         scale=1.0 / math.sqrt(cfg.num_heads * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = ini.ones((hd,), (None,))
        p["k_norm"] = ini.ones((hd,), (None,))
    return p


def _softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _mask_value():
    return jnp.finfo(jnp.float32).min


def attention_scores_mask(q_pos, k_pos, causal: bool, window: int,
                          kv_valid: Optional[jnp.ndarray]):
    """Boolean validity mask from position vectors.

    Unbatched ``q_pos`` (Sq,) with scalar ``kv_valid`` yields (Sq, Sk); a
    batched ``q_pos`` (B, Sq) or per-row ``kv_valid`` (B,) yields
    (B, Sq, Sk) — the slot-arena decode path, where every slot sits at its
    own position in its own cache row.
    """
    q = jnp.asarray(q_pos)
    batched = q.ndim == 2 or (kv_valid is not None
                              and jnp.ndim(kv_valid) == 1)
    if batched and q.ndim == 1:
        q = q[None]
    qp = q[..., :, None]                                   # (..., Sq, 1)
    kp = k_pos[None, None, :] if batched else k_pos[None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape[:-1] + (k_pos.shape[-1],),
                                      kp.shape), dtype=bool)
    if causal:
        m = m & (kp <= qp)
    if window and window > 0:
        m = m & (kp > (qp - window))
    if kv_valid is not None:
        kv = jnp.asarray(kv_valid)
        m = m & (kp < (kv[:, None, None] if kv.ndim == 1 else kv))
    return m


def multihead_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    q_positions: jnp.ndarray,  # (Sq,) int32
    k_positions: jnp.ndarray,  # (Sk,) int32
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_valid: Optional[jnp.ndarray] = None,  # scalar: #valid cache slots
    chunk: int = ATTN_CHUNK,
    return_stats: bool = False,
):
    """GQA attention with chunked online softmax over the KV axis.

    The chunked path bounds the score temporaries to (B,H,Sq,chunk) — the
    XLA-side analogue of flash attention (the Pallas kernel in
    ``repro.kernels`` is the TPU hot-path; this is the portable lowering the
    dry-run compiles)."""
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    qg = q.reshape(b, sq, hkv, g, dh).astype(COMPUTE_DTYPE)
    k = k.astype(COMPUTE_DTYPE)
    v = v.astype(COMPUTE_DTYPE)

    # Direct path for short KV and for single-query decode: with sq == 1 the
    # score tensor is tiny, and the un-chunked einsum lets GSPMD keep a
    # sequence-sharded KV cache sharded (flash-decoding style partial
    # softmax) instead of "involuntary full rematerialization" of the cache
    # to head sharding (EXPERIMENTS.md §Perf hillclimb #3, iteration 3).
    if sk <= chunk or sq == 1:
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
        scores = _softcap(scores, softcap)
        mask = attention_scores_mask(q_positions, k_positions, causal, window, kv_valid)
        mask = mask if mask.ndim == 3 else mask[None]  # (B|1, Sq, Sk)
        scores = jnp.where(mask[:, None, None], scores, _mask_value())
        if return_stats:
            m = scores.max(axis=-1)
            l = jnp.exp(scores - m[..., None]).sum(axis=-1)
            probs = jnp.exp(scores - m[..., None])
            out = jnp.einsum("bhgqk,bkhd->bhgqd",
                             probs.astype(COMPUTE_DTYPE), v)
            return out, m, l  # out UNNORMALISED (b,h,g,q,dh)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(COMPUTE_DTYPE), v)
        return out.reshape(b, sq, hq, dh)

    # ---- chunked online softmax over KV ----
    # Chunks are read via dynamic_slice inside the loop body (NOT pre-split
    # scan xs): a moveaxis'd xs materialises a transposed full-KV copy per
    # layer, which doubled decode HBM traffic (EXPERIMENTS.md §Perf).
    n_chunks = sk // chunk
    assert sk % chunk == 0, (sk, chunk)

    def body(carry, i):
        m_prev, l_prev, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(k_positions, i * chunk, chunk, 0)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc).astype(jnp.float32) * scale
        s = _softcap(s, softcap)
        mask = attention_scores_mask(q_positions, kp, causal, window, kv_valid)
        mask = mask if mask.ndim == 3 else mask[None]
        s = jnp.where(mask[:, None, None], s, _mask_value())
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(COMPUTE_DTYPE), vc)
        acc = acc * alpha[..., None].astype(COMPUTE_DTYPE) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dh), COMPUTE_DTYPE)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), jnp.arange(n_chunks, dtype=jnp.int32))
    if return_stats:
        # acc is scaled relative to exp(m); hand back raw stats
        return acc, m, l  # (b,h,g,q,dh), (b,h,g,q), (b,h,g,q)
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(COMPUTE_DTYPE)
    out = jnp.moveaxis(out, 3, 1)  # (b, sq, hkv, g, dh)
    return out.reshape(b, sq, hq, dh)


def apply_attention(
    params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    *,
    positions,  # (B, S) or (3, B, S) for mrope
    causal: bool = True,
    local: bool = False,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_pos: Optional[jnp.ndarray] = None,  # scalar position for decode
    xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full attention sublayer. Returns (out, new_cache).

    Modes:
      - training / prefill: cache None -> self attention over x
      - decode: cache {"k","v"} (B, Smax, Hkv, D), cache_pos scalar
      - cross-attention: xattn_kv provides precomputed (k, v)
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    xc = x.astype(COMPUTE_DTYPE)
    q = jnp.einsum("bsd,dhk->bshk", xc, params["wq"].astype(COMPUTE_DTYPE))

    if cfg.qk_norm:
        q = rmsnorm_headdim(params["q_norm"], q, cfg.rmsnorm_eps)

    window = cfg.sliding_window if local else 0

    if xattn_kv is not None:
        k, v = xattn_kv
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        q_pos = jnp.arange(s, dtype=jnp.int32)
        out = multihead_attention(
            q, k, v, q_positions=q_pos, k_positions=k_pos, causal=False,
            softcap=cfg.attn_softcap,
        )
        new_cache = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", xc, params["wk"].astype(COMPUTE_DTYPE))
        v = jnp.einsum("bsd,dhk->bshk", xc, params["wv"].astype(COMPUTE_DTYPE))
        if cfg.qk_norm:
            k = rmsnorm_headdim(params["k_norm"], k, cfg.rmsnorm_eps)
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

        if cache is None:
            pos1 = jnp.arange(s, dtype=jnp.int32)
            out = multihead_attention(
                q, k, v, q_positions=pos1, k_positions=pos1, causal=causal,
                window=window, softcap=cfg.attn_softcap,
            )
            new_cache = {"k": k, "v": v}
        else:
            # Decode: the cache is READ-ONLY here; the new tokens' (k, v)
            # merge in closed form via online-softmax statistics, and the
            # cache update happens once, post-scan, as a single stacked
            # dynamic-update-slice (EXPERIMENTS.md §Perf hillclimb #3 —
            # rewriting the cache through scan ys churned full-cache copies
            # every block iteration).  s == 1 is the plain decode step;
            # s > 1 is the speculative multi-token verify step, where the
            # s new tokens sit at consecutive positions cache_pos..+s-1 and
            # attend to each other under an intra-block causal mask.
            hkv = k.shape[2]
            g = cfg.num_heads // cfg.kv_heads
            smax = cache["k"].shape[1]
            k_pos = jnp.arange(smax, dtype=jnp.int32)
            offs = jnp.arange(s, dtype=jnp.int32)
            if jnp.ndim(cache_pos) == 1:
                # Slot-arena decode: every row sits at its own position.
                q_pos = cache_pos.astype(jnp.int32)[:, None] + offs[None, :]
            else:
                q_pos = jnp.asarray(cache_pos, jnp.int32) + offs  # (S,)
            out_old, m_old, l_old = multihead_attention(
                q, cache["k"], cache["v"], q_positions=q_pos,
                k_positions=k_pos, causal=True, window=window,
                softcap=cfg.attn_softcap, kv_valid=cache_pos,
                return_stats=True,
            )  # (b,h,g,S,dh), (b,h,g,S), (b,h,g,S)
            qg = q.reshape(b, s, hkv, g, hd)
            scale = 1.0 / math.sqrt(hd)
            if s == 1:
                s_new = jnp.einsum("bqhgd,bqhd->bhgq",
                                   qg.astype(COMPUTE_DTYPE),
                                   k.astype(COMPUTE_DTYPE)).astype(jnp.float32)
                s_new = _softcap(s_new * scale, cfg.attn_softcap)
                m_new = jnp.maximum(m_old, s_new)
                alpha = jnp.exp(m_old - m_new)
                p_new = jnp.exp(s_new - m_new)
                v_b = v.reshape(b, 1, hkv, 1, hd).transpose(0, 2, 3, 1, 4)
                num = (out_old.astype(jnp.float32) * alpha[..., None]
                       + p_new[..., None] * v_b.astype(jnp.float32))
                den = l_old * alpha + p_new
                out = (num / jnp.maximum(den, 1e-30)[..., None])
            else:
                # Intra-block attention of the s new tokens over themselves:
                # query row i sees new token j iff j <= i (positions are
                # consecutive, so the sliding window reduces to j > i - w).
                s_blk = jnp.einsum("bqhgd,bjhd->bhgqj",
                                   qg.astype(COMPUTE_DTYPE),
                                   k.astype(COMPUTE_DTYPE)).astype(jnp.float32)
                s_blk = _softcap(s_blk * scale, cfg.attn_softcap)
                blk_ok = offs[None, :] <= offs[:, None]           # (Sq, Sj)
                if window and window > 0:
                    blk_ok = blk_ok & (offs[None, :] > offs[:, None] - window)
                s_blk = jnp.where(blk_ok[None, None, None], s_blk,
                                  _mask_value())
                m_new = jnp.maximum(m_old, s_blk.max(axis=-1))
                alpha = jnp.exp(m_old - m_new)
                p_blk = jnp.exp(s_blk - m_new[..., None])
                pv = jnp.einsum("bhgqj,bjhd->bhgqd", p_blk,
                                v.astype(jnp.float32))
                num = out_old.astype(jnp.float32) * alpha[..., None] + pv
                den = l_old * alpha + p_blk.sum(axis=-1)
                out = num / jnp.maximum(den, 1e-30)[..., None]
            out = jnp.moveaxis(out.astype(COMPUTE_DTYPE), 3, 1)  # (b,S,h,g,dh)
            out = out.reshape(b, s, hkv * g, hd)
            new_cache = {"k_new": k.astype(cache["k"].dtype),
                         "v_new": v.astype(cache["v"].dtype)}

    y = jnp.einsum("bshk,hkd->bsd", out.astype(COMPUTE_DTYPE),
                   params["wo"].astype(COMPUTE_DTYPE))
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------
def init_mlp(ini: Initializer, d: int, d_ff: int) -> Dict[str, Pm]:
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "wi_gate": ini.normal((d, d_ff), ("embed", "mlp"), scale=s_in),
        "wi_up": ini.normal((d, d_ff), ("embed", "mlp"), scale=s_in),
        "wo": ini.normal((d_ff, d), ("mlp", "embed"), scale=s_out),
    }


def apply_mlp(params, x):
    xc = x.astype(COMPUTE_DTYPE)
    g = jnp.einsum("bsd,df->bsf", xc, params["wi_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("bsd,df->bsf", xc, params["wi_up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    y = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(COMPUTE_DTYPE))
    return y.astype(x.dtype)


def init_moe(ini: Initializer, cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": ini.normal((d, e), ("embed", "experts"), scale=s_in),
        "wi_gate": ini.normal((e, d, f), ("experts", "embed", "expert_mlp"), scale=s_in),
        "wi_up": ini.normal((e, d, f), ("experts", "embed", "expert_mlp"), scale=s_in),
        "wo": ini.normal((e, f, d), ("experts", "expert_mlp", "embed"), scale=s_out),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = init_mlp(ini, d, f * cfg.num_shared_experts)
    return p


def apply_moe(params, cfg: ModelConfig, x):
    """Top-k MoE with capacity. Two implementations:

    - "einsum" (default): GShard-style one-hot dispatch/combine einsums.
      GSPMD partitions these cleanly — dispatch is local per data shard and
      the only collective is one model-axis all-reduce of the combined
      output (EXPERIMENTS.md §Perf hillclimb #2: the sort/scatter path made
      GSPMD replicate + all-reduce the full (T·k, d) token tensor, 169.8s
      of collective time for deepseek train; einsum dispatch removes it).
    - "sort": capacity-sorted scatter/gather (kept for comparison and
      single-device use).

    Returns (y, aux_loss)."""
    if getattr(cfg, "moe_impl", "einsum") == "einsum":
        return _apply_moe_einsum(params, cfg, x)
    return _apply_moe_sort(params, cfg, x)


def _apply_moe_einsum(params, cfg: ModelConfig, x):
    from repro.utils.shard_hint import shard_hint

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = max(int(math.ceil(s * k / e * cfg.capacity_factor)), 1)
    cap = min(cap, s)

    logits = (x.astype(COMPUTE_DTYPE)
              @ params["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    top_w, top_ids = jax.lax.top_k(probs, k)  # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # (B, S, E) membership and gate weights (experts distinct within top-k)
    onehot = jax.nn.one_hot(top_ids, e, dtype=jnp.float32)  # (B, S, k, E)
    mask = onehot.sum(axis=2)                               # (B, S, E)
    gates_e = (onehot * top_w[..., None]).sum(axis=2)       # (B, S, E)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))
    ce = mask.mean(axis=(0, 1)) / k
    aux = (me * ce).sum() * e

    # position of each token in its expert's queue (earlier tokens win)
    pos = jnp.cumsum(mask, axis=1) - mask                   # (B, S, E)
    keep = (pos < cap) & (mask > 0)

    disp = (keep[..., None]
            * jax.nn.one_hot(pos.astype(jnp.int32), cap,
                             dtype=COMPUTE_DTYPE))          # (B, S, E, C)
    disp = shard_hint(disp, ("pod", "data"), None, "model", None)

    xb = x.astype(COMPUTE_DTYPE)
    buf = jnp.einsum("bsec,bsd->becd", disp, xb)            # (B, E, C, d)
    buf = shard_hint(buf, ("pod", "data"), "model", None, None)

    g = jnp.einsum("becd,edf->becf", buf, params["wi_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("becd,edf->becf", buf, params["wi_up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    yb = jnp.einsum("becf,efd->becd", h, params["wo"].astype(COMPUTE_DTYPE))
    yb = shard_hint(yb, ("pod", "data"), "model", None, None)

    combine = (disp.astype(jnp.float32)
               * gates_e[..., None]).astype(COMPUTE_DTYPE)  # (B, S, E, C)
    y = jnp.einsum("becd,bsec->bsd", yb, combine)

    if "shared" in params:
        y = y + apply_mlp(params["shared"], xb).astype(COMPUTE_DTYPE)
    return y.astype(x.dtype), aux


def _apply_moe_sort(params, cfg: ModelConfig, x):
    """Sorted capacity-based top-k MoE (drop on overflow)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(cap, 1)

    xt = x.reshape(t, d)
    logits = (xt.astype(COMPUTE_DTYPE)
              @ params["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_w, top_ids = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (t * k)
    aux = (me * ce).sum() * e

    flat_ids = top_ids.reshape(-1)  # (T*k,)
    sort_idx = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[sort_idx]
    counts = jnp.bincount(flat_ids, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(t * k) - starts[sorted_ids]
    keep = pos_in_expert < cap
    slot = sorted_ids * cap + jnp.minimum(pos_in_expert, cap - 1)  # (T*k,)

    token_of = sort_idx // k
    gathered = xt[token_of].astype(COMPUTE_DTYPE) * keep[:, None]
    buf = jnp.zeros((e * cap, d), COMPUTE_DTYPE).at[slot].add(
        jnp.where(keep[:, None], gathered, 0)
    )
    # Expert parallelism: pin the dispatch buffer and expert compute to the
    # model axis so GSPMD lowers the token exchange as an all-to-all instead
    # of replicating/all-gathering the full token set (EXPERIMENTS.md §Perf).
    from repro.utils.shard_hint import shard_hint
    buf = shard_hint(buf.reshape(e, cap, d), "model", None, None)

    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    yb = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(COMPUTE_DTYPE))
    yb = shard_hint(yb, "model", None, None)

    y_flat = yb.reshape(e * cap, d)[slot] * keep[:, None]  # (T*k, d)
    w_sorted = top_w.reshape(-1)[sort_idx]
    contrib = (y_flat.astype(jnp.float32) * w_sorted[:, None]).astype(COMPUTE_DTYPE)
    y = jnp.zeros((t, d), COMPUTE_DTYPE).at[token_of].add(contrib)

    if "shared" in params:
        y = y + apply_mlp(params["shared"], xt[None])[0].astype(COMPUTE_DTYPE)
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(ini: Initializer, cfg: ModelConfig) -> Dict[str, Pm]:
    p = {"tok": ini.normal((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = ini.normal((cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"),
                                  scale=1.0 / math.sqrt(cfg.d_model))
    return p


def embed_tokens(params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        w = params["tok"].T
    else:
        w = params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x.astype(COMPUTE_DTYPE),
                        w.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    return _softcap(logits, cfg.final_softcap)
