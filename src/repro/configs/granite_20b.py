"""Config alias for --arch granite-20b (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("granite-20b")
