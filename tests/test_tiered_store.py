"""TieredKVStore: placement cascade, promotion, demotion (with
re-compression), per-tier serialized fetch links, SLO protection."""
import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import (
    BandwidthTrace,
    PrefixKVStore,
    TierSpec,
    TieredKVStore,
)
from repro.serving.network import GoodputEstimator


def _toks(i, n=16):
    return tuple(range(i * 1000, i * 1000 + n))


def _profile(cr=8.0):
    return Profile(StrategyConfig(key_bits=4, value_bits=4), cr=cr,
                   s_enc=1e9, s_dec=1e9)


def _recompress(entry, profile):
    """Simulator-style byte-accounting re-compression."""
    wire = int(entry.kv_bytes / profile.cr)
    return (profile, wire) if wire < entry.wire_bytes else None


def _store(hot=1000, dram=2000, remote=10_000, remote_bw=1e6,
           profile=None, recompress=None, estimator=None):
    specs = [
        TierSpec("hbm", hot, bandwidth=1e9),
        TierSpec("dram", dram, bandwidth=1e8, fetch_overhead=1e-3,
                 profile=profile),
        TierSpec("remote", remote, bandwidth=remote_bw, fetch_overhead=2e-3,
                 profile=profile, observe_goodput=True),
    ]
    return TieredKVStore(specs, block=8, recompress=recompress,
                         estimator=estimator)


def test_put_lands_hot_and_pressure_demotes_not_drops():
    ts = _store()
    for i in range(3):       # 3 x 600B into a 1000B hot tier
        assert ts.put(_toks(i), f"p{i}", 600, kv_bytes=600.0,
                      now=float(i)) == 0
    assert len(ts) == 3 and ts.stats.demotions == 2
    assert ts.stats.evictions == 0          # nothing dropped
    assert len(ts.tiers[0].store) == 1 and len(ts.tiers[1].store) == 2
    # capacity invariant holds per tier
    for t in ts.tiers:
        assert t.store.used_bytes <= t.store.capacity_bytes


def test_only_last_tier_truly_evicts():
    ts = _store(hot=600, dram=600, remote=600)
    for i in range(4):
        ts.put(_toks(i), f"p{i}", 600, kv_bytes=600.0, now=float(i))
    assert ts.stats.evictions == 1          # one fell off the bottom
    # the drop is NOT double-counted as a demotion (5 victims landed)
    assert ts.stats.demotions == 5
    assert len(ts) == 3                     # one per tier
    assert ts.lookup(_toks(0), now=99.0) is None   # the oldest was dropped


def test_zero_capacity_hot_tier_degrades_gracefully():
    """A disabled (0-byte) hot tier must cascade puts down, not crash."""
    ts = _store(hot=0, dram=0)
    assert ts.put(_toks(0), "p", 600, kv_bytes=600.0, now=0.0) == 2
    hit = ts.lookup(_toks(0), now=1.0)
    assert hit is not None and hit.tier.name == "remote"
    # fetch works; promotion is skipped (it can never fit the hot tier)
    tr = ts.fetch(hit, ready=1.0)
    assert tr.t_comm > 0 and ts.stats.promotions == 0
    assert ts.lookup(_toks(0), now=9.0).tier.name == "remote"


def test_promotion_on_access():
    ts = _store()
    ts.put(_toks(0), "a", 400, kv_bytes=400.0, now=0.0)
    ts.put(_toks(1), "b", 400, kv_bytes=400.0, now=1.0)
    ts.put(_toks(2), "c", 400, kv_bytes=400.0, now=2.0)   # demotes "a"
    hit = ts.lookup(_toks(0), now=10.0)
    assert hit.tier.name == "dram"
    ts.fetch(hit, ready=10.0)
    assert ts.stats.promotions == 1
    hit2 = ts.lookup(_toks(0), now=20.0)
    assert hit2.tier.name == "hbm"          # hot again after access
    assert len(ts) == 3                     # promotion displaced a victim


def test_promotion_keeps_entry_visible_at_the_same_instant():
    """Regression: promotion used to re-stamp `created` to the fetch's
    end, so a second identical request looking up at the SAME instant
    missed and recomputed.  The entry has been servable since its
    original write — only recency moves on promotion."""
    ts = _store()
    ts.put(_toks(0), "a", 400, kv_bytes=400.0, now=0.0)
    ts.put(_toks(1), "b", 400, kv_bytes=400.0, now=1.0)
    ts.put(_toks(2), "c", 400, kv_bytes=400.0, now=2.0)   # "a" -> dram
    h1 = ts.lookup(_toks(0), now=10.0)
    assert h1.tier.name == "dram"
    ts.fetch(h1, ready=10.0)              # promotes "a" to hbm
    h2 = ts.lookup(_toks(0), now=10.0)    # same instant, second requester
    assert h2 is not None and h2.tier.name == "hbm"


def test_rejected_refresh_restores_old_copy():
    """Regression: a refresh rejected at every tier (SLO-protected) used
    to silently drop the previously stored entry — the tiered path now
    rolls back like the flat store does."""
    ts = _store(hot=800, dram=0, remote=0)
    ts.put(_toks(0), "int", 500, kv_bytes=500.0, slo_class="interactive",
           now=0.0)
    ts.put(_toks(1), "b_v1", 300, kv_bytes=300.0, slo_class="batch", now=1.0)
    # refreshing the batch key with a bigger payload would have to evict
    # the interactive entry -> rejected everywhere -> v1 must survive
    placed = ts.put(_toks(1), "b_v2", 600, kv_bytes=600.0,
                    slo_class="batch", now=2.0)
    assert placed is None and ts.stats.rejected_puts == 1
    hit = ts.lookup(_toks(1), now=3.0)
    assert hit is not None and hit.entry.payload == "b_v1"
    assert ts.used_bytes == 800


def test_demotion_recompresses_with_tier_profile():
    prof = _profile(cr=8.0)
    ts = _store(profile=prof, recompress=_recompress)
    ts.put(_toks(0), "big", 800, kv_bytes=4000.0, now=0.0)
    ts.put(_toks(1), "newer", 800, kv_bytes=4000.0, now=1.0)  # demotes 0
    hit = ts.lookup(_toks(0), now=5.0)
    assert hit.tier.name == "dram"
    assert hit.entry.wire_bytes == int(4000.0 / 8.0)   # re-encoded smaller
    assert hit.entry.payload is prof
    assert ts.tiers[1].store.used_bytes == hit.entry.wire_bytes


def test_concurrent_fetches_contend_on_tier_wire():
    """Two fetches from the same tier serialize: the second books a
    nonzero queueing wait."""
    ts = _store(hot=0, dram=0, remote_bw=1000.0)   # 1 KB/s remote link
    ts.put(_toks(0), "a", 500, kv_bytes=500.0, now=0.0)
    ts.put(_toks(1), "b", 500, kv_bytes=500.0, now=0.0)
    h0 = ts.lookup(_toks(0), now=10.0)
    h1 = ts.lookup(_toks(1), now=10.0)
    tr0 = ts.fetch(h0, ready=10.0)
    tr1 = ts.fetch(h1, ready=10.0)
    assert tr0.t_wait == 0.0 and tr0.t_comm == pytest.approx(0.5)
    assert tr1.t_wait == pytest.approx(0.5)   # queued behind tr0
    assert tr1.start >= tr0.end


def test_write_routes_through_link_and_visibility():
    """A pool write occupies the target tier's link and the entry only
    becomes visible at the transfer's completion (no time-travel hits)."""
    ts = _store(remote_bw=1000.0)
    tr = ts.write(_toks(0), "a", 500, kv_bytes=500.0, ready=0.0, tier=2)
    assert tr.t_comm == pytest.approx(0.5)
    assert ts.lookup(_toks(0), now=0.1) is None       # still in flight
    h = ts.lookup(_toks(0), now=tr.end)
    assert h is not None and h.tier.name == "remote"
    # a fetch right behind the write queues on the same serialized link
    ts.write(_toks(1), "b", 500, kv_bytes=500.0, ready=tr.end, tier=2)
    h = ts.lookup(_toks(0), now=tr.end)
    tr2 = ts.fetch(h, ready=tr.end)
    assert tr2.t_wait > 0.0

    # a write cascading past a disabled hot tier still lands (visibility
    # then follows the demotion hop's transfer on the landing tier)
    ts0 = _store(hot=0, dram=0, remote_bw=1000.0)
    ts0.write(_toks(2), "c", 500, kv_bytes=500.0, ready=0.0, tier=0)
    assert ts0.lookup(_toks(2), now=0.1) is None
    hit = ts0.lookup(_toks(2), now=2.0)
    assert hit is not None and hit.tier.name == "remote"


def test_demoted_entry_invisible_until_transfer_lands():
    ts = _store(hot=600, dram=600, remote=10_000, remote_bw=1000.0)
    ts.put(_toks(0), "a", 500, kv_bytes=500.0, now=0.0)
    ts.put(_toks(1), "b", 500, kv_bytes=500.0, now=0.0)   # demotes "a"->dram
    ts.put(_toks(2), "c", 500, kv_bytes=500.0, now=0.0)   # "a"->remote
    hit = ts.lookup(_toks(0), now=1e-6)
    assert hit is None            # demotion transfer (0.5 s) still in flight
    hit = ts.lookup(_toks(0), now=10.0)
    assert hit is not None and hit.tier.name == "remote"


def test_slo_protected_insert_demotes_instead_of_evicting():
    """A batch insert that would evict an interactive entry at a tier
    demotes ITSELF down the hierarchy instead."""
    ts = _store(hot=1000)
    ts.put(_toks(0), "i", 800, kv_bytes=800.0, slo_class="interactive",
           now=0.0)
    placed = ts.put(_toks(1), "b", 800, kv_bytes=800.0, slo_class="batch",
                    now=1.0)
    assert placed == 1                       # landed in dram, not rejected
    assert ts.stats.slo_protected == 1 and ts.stats.evictions == 0
    assert ts.lookup(_toks(0), now=5.0).tier.name == "hbm"  # untouched


def test_wrap_flat_adopts_existing_store():
    flat = PrefixKVStore(capacity_bytes=2000, block=8)
    ts = TieredKVStore.wrap_flat(flat, bandwidth=1e6, fetch_overhead=1e-3)
    ts.put(_toks(0), "a", 500, kv_bytes=500.0, now=0.0)
    assert len(flat) == 1 and flat.used_bytes == 500   # same backing store
    hit = ts.lookup(_toks(0), now=1.0)
    assert hit is not None and flat.stats.hits == 1
    tr = ts.fetch(hit, ready=1.0)
    assert tr.t_comm == pytest.approx(500 / 1e6)


def test_only_remote_tier_feeds_goodput_estimator():
    est = GoodputEstimator(alpha=1.0, initial=777.0)
    ts = _store(remote_bw=1000.0, estimator=est)
    ts.put(_toks(0), "hot", 500, kv_bytes=500.0, now=0.0)
    ts.fetch(ts.lookup(_toks(0), now=1.0), ready=1.0)   # hbm fetch
    assert est.estimate == 777.0            # local tiers don't pollute B
    ts2 = _store(hot=0, dram=0, remote_bw=1000.0, estimator=est)
    ts2.put(_toks(1), "cold", 500, kv_bytes=500.0, now=0.0)
    ts2.fetch(ts2.lookup(_toks(1), now=10.0), ready=10.0)
    assert est.estimate == pytest.approx(1000.0)        # remote observed


def test_summary_aggregates_and_per_tier_detail():
    ts = _store()
    ts.put(_toks(0), "a", 400, kv_bytes=400.0, now=0.0)
    ts.lookup(_toks(0), now=1.0)
    ts.lookup(_toks(9), now=2.0)
    s = ts.summary()
    assert s["entries"] == 1 and s["hits"] == 1 and s["misses"] == 1
    assert s["hit_rate"] == pytest.approx(0.5)
    assert s["capacity_bytes"] == 13_000
    assert s["tier0_hbm_entries"] == 1 and s["tier0_hbm_hits"] == 1
    assert "tier2_remote_used_bytes" in s


def test_promotion_out_of_a_shared_tier_copies_not_moves():
    """Cluster-shared pool tier (ISSUE 5): several workers' hierarchies
    end in ONE remote KVTier.  A fetch that promotes the entry into the
    fetching worker's private HBM must COPY it — moving it would silently
    remove the prefix from the disaggregated pool and every OTHER
    worker's next lookup would cold-miss."""
    from repro.serving import KVTier

    shared = KVTier(TierSpec("remote", 10_000, bandwidth=1e6,
                             fetch_overhead=2e-3, observe_goodput=True),
                    block=8)
    shared.shared = True
    mk = lambda: TieredKVStore(
        [TierSpec("hbm", 1000, bandwidth=1e9), shared], block=8)
    d0, d1 = mk(), mk()
    # the prefix lands in the shared pool (e.g. demoted / written through)
    d0.put(_toks(0), "payload", 400, kv_bytes=400.0, now=0.0, tier=1)
    assert d1.contains(_toks(0), now=1.0)

    # d0 fetch-hits and promotes into ITS hbm...
    hit = d0.lookup(_toks(0), now=1.0)
    assert hit.tier.name == "remote"
    d0.fetch(hit, ready=1.0)
    assert d0.stats.promotions == 1
    assert d0.tiers[0].store.contains(_toks(0), now=2.0)
    # ... and the shared pool copy is STILL there for d1
    assert shared.store.contains(_toks(0), now=2.0)
    hit1 = d1.lookup(_toks(0), now=2.0)
    assert hit1 is not None and hit1.tier is shared
    # capacity accounting: both copies are billed where they live
    assert d0.tiers[0].store.used_bytes == 400
    assert shared.store.used_bytes == 400

    # an UNshared tier keeps the exclusive-hierarchy move semantics
    d2 = _store()
    d2.put(_toks(1), "p", 400, kv_bytes=400.0, now=0.0, tier=2)
    d2.fetch(d2.lookup(_toks(1), now=1.0), ready=1.0)
    assert d2.tiers[0].store.contains(_toks(1), now=2.0)
    assert not d2.tiers[2].store.contains(_toks(1), now=2.0)
