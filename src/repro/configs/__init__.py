"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeSpec,
    reduce_config,
    supported_shapes,
)

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name.endswith("-reduced"):
        return reduce_config(get_config(name[: -len("-reduced")]))
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = [
    "qwen3-4b", "gemma2-9b", "granite-20b", "minicpm-2b", "jamba-v0.1-52b",
    "whisper-small", "qwen2-vl-72b", "llama4-scout-17b-a16e",
    "deepseek-moe-16b", "falcon-mamba-7b",
]


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from repro.configs import archs  # noqa: F401  (registers everything)


__all__ = [
    "ModelConfig", "ShapeSpec", "SHAPES", "SHAPES_BY_NAME", "get_config",
    "list_archs", "register", "reduce_config", "supported_shapes",
    "ASSIGNED_ARCHS",
]
