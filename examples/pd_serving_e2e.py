"""End-to-end driver: serve the (trained) tiny reference model through the
REAL disaggregated pipeline with batched requests — prefill worker, actual
compressed bytes on a simulated link, decode worker — with the full KVServe
stack (offline profiles -> controller -> bandit feedback).

    PYTHONPATH=src python examples/pd_serving_e2e.py
"""
import numpy as np

from repro.controller import ServiceAwareController
from repro.core.strategy import BASELINES, StrategyConfig
from repro.data.synthetic import WORKLOADS
from repro.launch.profile_offline import build_profiles
from repro.serving.engine import DisaggregatedEngine
from repro.serving.network import GBPS, BandwidthTrace


def main():
    print("== offline profiling (measured CR/throughput/quality) ==")
    profiles = build_profiles(
        [BASELINES["kivi"], BASELINES["cachegen"], BASELINES["mixhq"],
         StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                        granularity="per_channel"),
         StrategyConfig(quantizer="uniform", key_bits=4, value_bits=4,
                        granularity="per_channel", codec="zstd3")],
        quality_kwargs={"n_prompts": 4, "decode_tokens": 12}, verbose=True)

    controller = ServiceAwareController({w: profiles for w in WORKLOADS})
    engine = DisaggregatedEngine(controller=controller, batch=4,
                                 decode_tokens=16)

    # bandwidth drops mid-run: watch the controller switch profiles
    trace = BandwidthTrace.steps(
        [(0.0, 0.2 * GBPS), (6.0, 0.002 * GBPS), (14.0, 0.2 * GBPS)],
        jitter=0.1, seed=0)

    print("\n== serving batched requests across the bandwidth drop ==")
    print(f"{'t':>5s} {'workload':10s} {'chosen profile':42s} {'jct':>7s} "
          f"{'comm':>7s} {'agree':>6s}")
    rng = np.random.default_rng(0)
    now = 0.0
    for i in range(12):
        w = list(WORKLOADS)[int(rng.integers(0, 4))]
        res = engine.serve(w, trace, now=now, q_min=0.3,
                           seed=i)
        print(f"{now:5.1f} {w:10s} {res.profile:42s} {res.jct:7.3f} "
              f"{res.t_comm:7.3f} {res.agreement:6.3f}")
        now += max(res.jct, 1.5)

    print("\ngenerated samples (decode-worker output):")
    print(" ", repr(res.text[0][:60]))


if __name__ == "__main__":
    main()
