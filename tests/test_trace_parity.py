"""Runtime-vs-simulator parity on one pinned trace (ISSUE 6).

The event-driven simulator and the real-execution runtime model the SAME
serving pipeline (prefill -> compress -> transfer -> decompress ->
decode) at different granularities.  This test replays one hand-crafted
sparse trace through both and checks they agree:

* The runtime's replay is pinned in ``tests/fixtures/trace_parity.json``
  (regenerate with ``PYTHONPATH=src python tests/test_trace_parity.py``)
  and must reproduce bit-for-bit — the regression pin.  Skipped when the
  cached reference model differs from the fixture's ``params_digest``
  (e.g. CI trains a smaller ``REPRO_REF_STEPS`` model).
* The simulator, configured with the SAME node speeds, bandwidth,
  profile, and the runtime's measured on-wire KV bytes, must land within
  ``REL_TOL`` of the runtime's TTFT/JCT per request.

Tolerance: with sparse arrivals (no queueing) both backends reduce to
the same closed-form latency; the residual gap is the runtime's
step-quantized virtual clock (decode billed per step, stalls rounded to
step boundaries).  Observed gap on the pinned trace is < 1%%; REL_TOL is
5%% to absorb step-granularity drift without hiding real regressions.
The documented fidelity gap remains ``ctx_tokens``: the runtime prefills
its fixed ``seq`` window, so the trace pins ``ctx_tokens == seq``
(DESIGN.md §11).
"""
import json
from pathlib import Path

import pytest

from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig
from repro.serving import BandwidthTrace, GBPS, SchedulerConfig
from repro.serving.request import Request
from repro.serving.simulator import SimConfig, Simulator, StaticPolicy
from repro.workloads import replay_runtime
from repro.workloads.trace import Trace, TraceEvent

FIXTURE = Path(__file__).parent / "fixtures" / "trace_parity.json"
REL_TOL = 0.05
SEQ = 48
WORKLOADS = ("qalike", "codelike", "mathlike", "summlike")


def _profile():
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel"),
                   cr=2.0, s_enc=5e8, s_dec=5e8)


def _trace() -> Trace:
    """Eight sparse arrivals (1.5 s apart — no queueing on either
    backend), ctx pinned to the runtime's seq window, decode budgets
    within the runtime's arena."""
    events = [TraceEvent(rid=i, t=1.5 * i, tenant="parity",
                         scenario="chat", workload=WORKLOADS[i % 4],
                         ctx_tokens=SEQ, out_tokens=2 + (i % 3),
                         prefix_group=100 + i, slo_class="standard",
                         slo_metric="jct", t_slo=5.0)
              for i in range(8)]
    return Trace(events, seed=0)


def _runtime(reference_model):
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    rt = ServingRuntime(
        static_profile=_profile(),
        config=RuntimeConfig(seq=SEQ, decode_tokens=6,
                             prefill_tok_s=2000.0, decode_tok_s=500.0,
                             mode="pd"),
        trace=BandwidthTrace.constant(1 * GBPS),
        scheduler=SchedulerConfig(max_slots=4, max_prefills_per_step=2,
                                  max_queue=32))
    rt.model_cfg, rt.params = reference_model
    return rt


def _run_runtime(rt):
    done = replay_runtime(rt, _trace())
    return {str(r.rid): {"ttft": r.ttft, "jct": r.jct,
                         "kv_bytes": float(r.kv_bytes)}
            for r in done}


def _run_simulator(kv_bytes_by_rid):
    """The simulator twin: identical rates/bandwidth/profile, payloads
    taken from the runtime's measured on-wire bytes."""
    reqs = [Request(rid=e.rid, workload=e.workload, arrival=e.t,
                    ctx_tokens=e.ctx_tokens, out_tokens=e.out_tokens,
                    kv_bytes=kv_bytes_by_rid[str(e.rid)],
                    t_slo=e.t_slo, slo_metric=e.slo_metric,
                    slo_class=e.slo_class)
            for e in _trace().events]
    sim = Simulator(SimConfig(scenario="pd", n_prefill=1, n_decode=1,
                              prefill_tok_s=2000.0, decode_tok_s=500.0,
                              straggler_sigma=0.0, seed=0),
                    StaticPolicy(_profile(), "u8"),
                    BandwidthTrace.constant(1 * GBPS), reqs)
    return {str(r.rid): {"ttft": r.ttft, "jct": r.jct}
            for r in sim.run().completed()}


@pytest.mark.slow
def test_runtime_matches_pinned_fixture(reference_model):
    from _runtime_scenario import params_digest
    fix = json.loads(FIXTURE.read_text())
    rt = _runtime(reference_model)
    if params_digest(rt.params) != fix["params_digest"]:
        pytest.skip("reference model differs from the fixture's")
    out = _run_runtime(rt)
    assert set(out) == set(fix["runtime"])
    for rid, rec in fix["runtime"].items():
        assert out[rid]["ttft"] == pytest.approx(rec["ttft"], rel=1e-9)
        assert out[rid]["jct"] == pytest.approx(rec["jct"], rel=1e-9)
        assert out[rid]["kv_bytes"] == rec["kv_bytes"]


def test_simulator_matches_runtime_fixture():
    """Pure-simulator side: no model run needed — the fixture carries the
    runtime's measured latencies and payload sizes."""
    fix = json.loads(FIXTURE.read_text())
    kv = {rid: rec["kv_bytes"] for rid, rec in fix["runtime"].items()}
    sim = _run_simulator(kv)
    assert set(sim) == set(fix["runtime"])
    for rid, rec in fix["runtime"].items():
        assert sim[rid]["ttft"] == pytest.approx(rec["ttft"], rel=REL_TOL), \
            (rid, sim[rid], rec)
        assert sim[rid]["jct"] == pytest.approx(rec["jct"], rel=REL_TOL), \
            (rid, sim[rid], rec)


if __name__ == "__main__":           # fixture (re)capture
    from _runtime_scenario import params_digest
    from repro.core.quality import get_reference_model
    rt = _runtime(get_reference_model())
    payload = {"params_digest": params_digest(rt.params),
               "runtime": _run_runtime(rt),
               "trace_digest": _trace().digest()}
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE.write_text(json.dumps(payload, indent=1, sort_keys=True))
    print(f"wrote {FIXTURE} ({len(payload['runtime'])} requests, "
          f"digest {payload['params_digest']})")
